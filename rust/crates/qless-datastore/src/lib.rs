//! # qless-datastore — QLESS persistence + scoring layer
//!
//! The middle crate of the QLESS workspace (see the workspace
//! `ARCHITECTURE.md` for the crate map). It owns everything that touches
//! quantized gradient features at rest and in bulk:
//!
//! * [`datastore`] — the QLDS on-disk format (`FORMAT.md` in this crate
//!   is compiled into its rustdoc), the random-access store, the
//!   streaming multi-precision writer, the append-only live store with
//!   generation manifests;
//! * [`influence`] — the fused multi-query influence scan over a
//!   datastore: integer-domain kernels, the XLA Pallas tile, and the
//!   row-range scan API (`MultiScan::try_new_range`) the distributed
//!   coordinator partitions on;
//! * [`fixtures`] — the shared seeded-datastore test fixture the
//!   datastore / influence / service suites build on.
//!
//! Only `qless-core` (and the vendored `anyhow`/`xla`) sit below this
//! crate; the serving layer and the pipeline sit above it.
#![warn(missing_docs)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod datastore;
pub mod fixtures;
pub mod influence;

pub use qless_core::{corpus, grads, quant, runtime, select};
pub use qless_core::{debug, info, prop_assert, warn_, DEFAULT_MEM_BUDGET_MB};

/// The `qless-core` util substrate, re-exported so intra-workspace code
/// and downstream crates address one `util` namespace, with the
/// property-test module widened to include this crate's on-disk fixture.
pub mod util {
    pub use qless_core::util::*;

    /// Property-test harness plus the shared test fixtures: everything
    /// from `qless_core::util::prop`, widened with the on-disk
    /// [`seeded_datastore`](crate::fixtures::seeded_datastore) fixture.
    pub mod prop {
        pub use crate::fixtures::seeded_datastore;
        pub use qless_core::util::prop::*;
    }
}

pub use anyhow::{anyhow, bail, Context, Result};
