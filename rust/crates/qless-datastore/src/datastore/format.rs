//! On-disk header + primitive (de)serialization for the gradient datastore.
//!
//! The normative byte-level spec is `rust/crates/qless-datastore/FORMAT.md` — included verbatim
//! below, so its worked hex-dump example runs as a doctest and the spec
//! can never drift from this code. Edit the markdown file, not this
//! header.
#![doc = include_str!("../../FORMAT.md")]

use anyhow::{bail, Result};

use crate::quant::{Precision, Scheme};

/// File magic, first four bytes of every datastore.
pub const MAGIC: [u8; 4] = *b"QLDS";
/// On-disk format version accepted by [`Header::decode`].
pub const VERSION: u32 = 1;

/// The datastore file header: storage precision plus the geometry every
/// offset computation derives from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    /// Storage precision of the gradient rows (bits + scheme).
    pub precision: Precision,
    /// Sample rows per checkpoint block.
    pub n_samples: u64,
    /// Codes per row (the projection dimension).
    pub k: u64,
    /// Checkpoint blocks in the file.
    pub n_checkpoints: u32,
    /// Bytes per packed row (derived from `k` and the precision).
    pub row_stride: u32,
}

impl Header {
    /// Build a header for the given geometry, deriving `row_stride`.
    pub fn new(precision: Precision, n_samples: usize, k: usize, n_checkpoints: usize) -> Header {
        let row_stride = match precision.bits {
            16 => (k * 2) as u32,
            b => ((k * b as usize).div_ceil(8)) as u32,
        };
        Header {
            precision,
            n_samples: n_samples as u64,
            k: k as u64,
            n_checkpoints: n_checkpoints as u32,
            row_stride,
        }
    }

    /// Encoded header size in bytes (fixed-width little-endian fields).
    pub const BYTES: usize = 4 + 4 + 1 + 1 + 2 + 8 + 8 + 4 + 4;

    /// Serialize the header to its on-disk byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(Self::BYTES);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.precision.bits);
        out.push(scheme_tag(self.precision.scheme));
        out.extend_from_slice(&[0u8; 2]);
        out.extend_from_slice(&self.n_samples.to_le_bytes());
        out.extend_from_slice(&self.k.to_le_bytes());
        out.extend_from_slice(&self.n_checkpoints.to_le_bytes());
        out.extend_from_slice(&self.row_stride.to_le_bytes());
        debug_assert_eq!(out.len(), Self::BYTES);
        out
    }

    /// Parse and validate an encoded header (magic, version, scheme tag
    /// and `row_stride` consistency).
    pub fn decode(b: &[u8]) -> Result<Header> {
        if b.len() < Self::BYTES {
            bail!("datastore header truncated ({} bytes)", b.len());
        }
        if b[0..4] != MAGIC {
            bail!("bad datastore magic {:?}", &b[0..4]);
        }
        let version = u32::from_le_bytes(b[4..8].try_into()?);
        if version != VERSION {
            bail!("datastore version {version} != {VERSION}");
        }
        let bits = b[8];
        let scheme = scheme_from_tag(b[9])?;
        let precision = Precision::new(bits, scheme)?;
        let n_samples = u64::from_le_bytes(b[12..20].try_into()?);
        let k = u64::from_le_bytes(b[20..28].try_into()?);
        let n_checkpoints = u32::from_le_bytes(b[28..32].try_into()?);
        let row_stride = u32::from_le_bytes(b[32..36].try_into()?);
        let expect = Header::new(precision, n_samples as usize, k as usize, n_checkpoints as usize);
        if expect.row_stride != row_stride {
            bail!("row_stride {row_stride} inconsistent with bits/k (expect {})", expect.row_stride);
        }
        Ok(expect)
    }

    /// Bytes of one checkpoint block (η + scales + rows). 16-bit blocks
    /// carry no scales section (bf16 rows are self-describing).
    pub fn block_bytes(&self) -> u64 {
        4 + self.scales_bytes() + self.row_stride as u64 * self.n_samples
    }

    /// Bytes of the per-row scale section (absent at 16-bit).
    pub fn scales_bytes(&self) -> u64 {
        if self.precision.bits == 16 {
            0
        } else {
            4 * self.n_samples
        }
    }

    /// Total file size this header implies.
    pub fn file_bytes(&self) -> u64 {
        Self::BYTES as u64 + self.block_bytes() * self.n_checkpoints as u64
    }

    // -- shard geometry -----------------------------------------------------
    //
    // A shard is a contiguous row range of one checkpoint block. The on-disk
    // layout is unchanged (shards are a read-side view), so shard readers and
    // the whole-block reader are interchangeable byte-for-byte.

    /// Byte offset of checkpoint `c`'s block (its η word).
    pub fn block_offset(&self, c: usize) -> u64 {
        Self::BYTES as u64 + self.block_bytes() * c as u64
    }

    /// Byte offset of the scales section of checkpoint `c` (just after η).
    /// At 16-bit the section is empty, so rows begin here
    /// ([`Self::row_offset`] of row 0).
    pub fn scales_offset(&self, c: usize) -> u64 {
        self.block_offset(c) + 4
    }

    /// Byte offset of row `row`'s packed bytes within checkpoint `c`.
    pub fn row_offset(&self, c: usize, row: u64) -> u64 {
        self.scales_offset(c) + self.scales_bytes() + self.row_stride as u64 * row
    }

    /// Resident bytes one streamed row costs a shard buffer (packed row
    /// plus its f32 scale; 16-bit rows carry no scale).
    pub fn resident_row_bytes(&self) -> u64 {
        self.row_stride as u64 + if self.precision.bits == 16 { 0 } else { 4 }
    }

    /// Largest shard (in rows) whose resident buffers fit `budget_bytes`,
    /// clamped to `[1, n_samples]` so tiny budgets still make progress.
    pub fn shard_rows_for_budget(&self, budget_bytes: u64) -> usize {
        let per_row = self.resident_row_bytes().max(1);
        let rows = (budget_bytes / per_row).max(1);
        (rows.min(self.n_samples.max(1)) as usize).max(1)
    }
}

fn scheme_tag(s: Scheme) -> u8 {
    match s {
        Scheme::Absmax => 0,
        Scheme::Absmean => 1,
        Scheme::Sign => 2,
    }
}

fn scheme_from_tag(t: u8) -> Result<Scheme> {
    Ok(match t {
        0 => Scheme::Absmax,
        1 => Scheme::Absmean,
        2 => Scheme::Sign,
        _ => bail!("bad scheme tag {t}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hdr(bits: u8) -> Header {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        Header::new(Precision::new(bits, scheme).unwrap(), 1000, 512, 4)
    }

    #[test]
    fn encode_decode_roundtrip() {
        for bits in [1u8, 2, 4, 8, 16] {
            let h = hdr(bits);
            let d = Header::decode(&h.encode()).unwrap();
            assert_eq!(h, d, "{bits}-bit");
        }
    }

    #[test]
    fn row_strides() {
        assert_eq!(hdr(16).row_stride, 1024);
        assert_eq!(hdr(8).row_stride, 512);
        assert_eq!(hdr(4).row_stride, 256);
        assert_eq!(hdr(2).row_stride, 128);
        assert_eq!(hdr(1).row_stride, 64);
    }

    #[test]
    fn rejects_corruption() {
        let mut b = hdr(8).encode();
        b[0] = b'X';
        assert!(Header::decode(&b).is_err());
        let mut b2 = hdr(8).encode();
        b2[4] = 99; // version
        assert!(Header::decode(&b2).is_err());
        let mut b3 = hdr(8).encode();
        b3[9] = 7; // scheme tag
        assert!(Header::decode(&b3).is_err());
        assert!(Header::decode(&[0u8; 4]).is_err());
    }

    #[test]
    fn shard_geometry_tiles_the_block() {
        for bits in [1u8, 2, 4, 8, 16] {
            let h = hdr(bits);
            for c in 0..h.n_checkpoints as usize {
                assert_eq!(h.scales_offset(c), h.block_offset(c) + 4);
                assert_eq!(h.row_offset(c, 0), h.scales_offset(c) + h.scales_bytes());
                // the last row ends exactly at the next block's offset
                let end = h.row_offset(c, h.n_samples - 1) + h.row_stride as u64;
                assert_eq!(end, h.block_offset(c) + h.block_bytes(), "{bits}-bit ckpt {c}");
            }
        }
    }

    #[test]
    fn budget_to_shard_rows() {
        let h = hdr(8); // row_stride 512 + 4-byte scale
        assert_eq!(h.resident_row_bytes(), 516);
        assert_eq!(h.shard_rows_for_budget(516 * 10), 10);
        assert_eq!(h.shard_rows_for_budget(0), 1); // floor at one row
        assert_eq!(h.shard_rows_for_budget(u64::MAX), 1000); // cap at n
        let h16 = hdr(16);
        assert_eq!(h16.resident_row_bytes(), 1024); // no scales at 16-bit
    }

    #[test]
    fn file_size_matches_quant_accounting() {
        // The header's implied file size must track quant::datastore_bytes
        // up to the per-block η and header overhead.
        let h = hdr(1);
        let payload = crate::quant::datastore_bytes(h.precision, 1000, 512, 4);
        let overhead = Header::BYTES as u64 + 4 * 4; // header + 4 η
        // datastore_bytes counts 4-byte scales per row; so does the file.
        assert_eq!(h.file_bytes(), payload + overhead);
    }
}
