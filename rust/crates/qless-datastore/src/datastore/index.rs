//! Hamming-clustered IVF index over the quantized codes — sub-linear
//! queries for the influence scan.
//!
//! QLESS is similarity *search*: every query ranks train rows by quantized
//! inner product, yet the exhaustive scan pays O(n) rows per task. This
//! module clusters the row space by **k-majority Hamming clustering** over
//! the rows' 1-bit sign bitmaps (Lloyd-style iterations whose distance is
//! XOR+popcount through the PR 9 SIMD kernels, and whose centroid update
//! is a per-bit majority vote), then persists the grouping as a versioned
//! sidecar (`<stem>.qidx`, spec'd in `FORMAT.md` §Index sidecar) next to
//! the store it indexes. A query probes every centroid (C ≪ n rows),
//! selects the top-P clusters per task, and scans only those clusters'
//! rows via the cascade's contiguous-run seek machinery
//! (`influence::index`) — O(n·P/C) rows instead of O(n).
//!
//! Design invariants the property harness (`tests/index.rs`) locks in:
//!
//! * **Rows are never moved.** The sidecar stores a permutation of row
//!   ids grouped by cluster (ascending within each cluster); the `.qlds`
//!   bytes are untouched, so every existing scan path — and the
//!   exhaustive ground truth — keeps working verbatim.
//! * **Exact at full coverage.** Clusters partition the row space, so
//!   probing all of them makes the candidate set every row and the index
//!   scan byte-identical to the exhaustive scan (DESIGN.md §12).
//! * **Corruption is detected, never served.** [`QuantIndex::open_for`]
//!   validates magic, version, geometry against the store header, offset
//!   monotonicity and the row-id permutation; any failure warns, bumps
//!   `index_open_failures_total`, and returns `None` — callers fall back
//!   to the exhaustive scan. `repair_run_dir` deliberately leaves the
//!   sidecar alone (it only matches `.qlds`/`.qlds.tmp` segment names);
//!   a stale or damaged sidecar is `qless reindex`'s job.
//! * **Ingest stays live.** New generations are *not* re-clustered:
//!   [`QuantIndex::refresh`] assigns rows past the indexed prefix to
//!   their nearest existing centroid in memory, and the count of such
//!   rows is the staleness the serving layer surfaces in `stats`.
//!
//! Padding bits: every packed sign row and every centroid zero-pads the
//! byte tail, so the XNOR agreement over whole bytes counts each padding
//! position as an agreement — a per-store constant added to every
//! (row, centroid) pair, hence rank-invariant for nearest-centroid
//! assignment (DESIGN.md §12).

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Result};

use crate::datastore::LiveStore;
use crate::influence::simd;
use crate::quant::pack::packed_bytes;
use crate::util::bits::{accumulate_bits, majority_bitmap};
use crate::util::cpu::{self, Kernel};
use crate::util::obs;
use crate::{warn_, DEFAULT_MEM_BUDGET_MB};

/// Sidecar magic, first four bytes of every `.qidx` file.
pub const QIDX_MAGIC: [u8; 4] = *b"QIDX";
/// Sidecar format version accepted by [`QuantIndex::decode`].
pub const QIDX_VERSION: u32 = 1;
/// Encoded sidecar header size (fixed-width little-endian fields).
pub const QIDX_HEADER_BYTES: usize = 4 + 4 + 8 + 4 + 4 + 8 + 8 + 4 + 4;

/// Default Lloyd iteration cap — assignments converge or go stable well
/// before this on clustered data; the cap bounds build time on noise.
pub const DEFAULT_INDEX_ITERS: usize = 8;

/// Cluster count heuristic when `--nclusters` is 0/absent: √n clamped to
/// `[1, 4096]`, the classic IVF balance point (probe cost ≈ C, scan cost
/// ≈ n·P/C; √n equalizes them at P = 1).
pub fn auto_nclusters(n_rows: usize) -> usize {
    ((n_rows as f64).sqrt().ceil() as usize).clamp(1, 4096)
}

/// Default probe width when `--nprobe` is 0/absent: an eighth of the
/// clusters (≥ 1), targeting ~8× fewer rows scanned at balanced sizes
/// while keeping recall@k high on clustered data (`tests/index.rs` pins
/// both at paper scale).
pub fn default_nprobe(n_clusters: usize) -> usize {
    (n_clusters / 8).max(1)
}

/// Sidecar path for a store path: `<stem>.qidx` next to the store (and
/// the manifest). `datastore_1b_sign.qlds` → `datastore_1b_sign.qidx`.
pub fn index_path(store_path: &Path) -> PathBuf {
    store_path.with_extension("qidx")
}

/// Build knobs for [`build_index`] / `qless reindex`.
#[derive(Debug, Clone, Copy)]
pub struct IndexBuildOpts {
    /// Cluster count; 0 derives [`auto_nclusters`]`(n_rows)`.
    pub n_clusters: usize,
    /// Lloyd iteration cap; 0 derives [`DEFAULT_INDEX_ITERS`].
    pub max_iters: usize,
}

impl Default for IndexBuildOpts {
    fn default() -> Self {
        IndexBuildOpts { n_clusters: 0, max_iters: 0 }
    }
}

/// The in-memory IVF index: per-checkpoint packed sign centroids plus the
/// row-id permutation grouped into per-cluster ranges, exactly as encoded
/// in the `.qidx` sidecar — plus the in-memory nearest-centroid
/// assignments of rows ingested after the build ([`QuantIndex::refresh`]).
#[derive(Debug, Clone)]
pub struct QuantIndex {
    k: usize,
    n_checkpoints: usize,
    n_clusters: usize,
    n_rows: u64,
    generation: u64,
    row_stride: usize,
    /// Packed sign centroids, `[ckpt][cluster][row_stride]`.
    centroids: Vec<u8>,
    /// Per-cluster ranges into `row_ids`: cluster `c` owns
    /// `row_ids[offsets[c] .. offsets[c+1]]`. `n_clusters + 1` entries.
    offsets: Vec<u64>,
    /// The row-id permutation, grouped by cluster, strictly ascending
    /// within each cluster.
    row_ids: Vec<u64>,
    /// Rows past the indexed prefix, assigned in memory per cluster
    /// (ascending; every id ≥ `n_rows`). Never persisted — `reindex`
    /// folds them in.
    stale: Vec<Vec<u64>>,
}

impl QuantIndex {
    /// Projection dimension the centroids were built at.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Checkpoint count (one centroid bitmap per cluster per checkpoint).
    pub fn n_checkpoints(&self) -> usize {
        self.n_checkpoints
    }

    /// Number of clusters.
    pub fn n_clusters(&self) -> usize {
        self.n_clusters
    }

    /// Rows covered by the persisted grouping (the indexed prefix).
    pub fn n_rows(&self) -> u64 {
        self.n_rows
    }

    /// Manifest generation the index was built at.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Packed bytes per centroid bitmap (`⌈k/8⌉`).
    pub fn row_stride(&self) -> usize {
        self.row_stride
    }

    /// Rows assigned in memory since the build — the staleness counter
    /// `stats` surfaces; `qless reindex` resets it to 0.
    pub fn stale_rows(&self) -> u64 {
        self.stale.iter().map(|s| s.len() as u64).sum()
    }

    /// Total rows the index can answer for (indexed prefix + stale tail).
    pub fn covered_rows(&self) -> u64 {
        self.n_rows + self.stale_rows()
    }

    /// All centroid bitmaps of checkpoint `ci`, concatenated — the data
    /// plane of the probe's virtual 1-bit "centroid store".
    pub fn centroids_ckpt(&self, ci: usize) -> &[u8] {
        let per_ckpt = self.n_clusters * self.row_stride;
        &self.centroids[ci * per_ckpt..(ci + 1) * per_ckpt]
    }

    /// One centroid's packed sign bitmap.
    pub fn centroid(&self, ci: usize, cluster: usize) -> &[u8] {
        let base = (ci * self.n_clusters + cluster) * self.row_stride;
        &self.centroids[base..base + self.row_stride]
    }

    /// Cluster `c`'s rows: the persisted ids followed by the in-memory
    /// stale tail — ascending overall, because every stale id is ≥
    /// `n_rows` and both halves are sorted.
    pub fn cluster_rows(&self, c: usize) -> impl Iterator<Item = u64> + '_ {
        let lo = self.offsets[c] as usize;
        let hi = self.offsets[c + 1] as usize;
        self.row_ids[lo..hi].iter().copied().chain(self.stale[c].iter().copied())
    }

    /// Persisted rows in cluster `c` (excludes the stale tail).
    pub fn cluster_len(&self, c: usize) -> usize {
        (self.offsets[c + 1] - self.offsets[c]) as usize
    }

    /// Serialize to the on-disk sidecar layout (see `FORMAT.md` §Index
    /// sidecar). The stale tail is **not** encoded — it's recomputable
    /// from the live store.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.file_bytes());
        out.extend_from_slice(&QIDX_MAGIC);
        out.extend_from_slice(&QIDX_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.k as u64).to_le_bytes());
        out.extend_from_slice(&(self.n_checkpoints as u32).to_le_bytes());
        out.extend_from_slice(&(self.n_clusters as u32).to_le_bytes());
        out.extend_from_slice(&self.n_rows.to_le_bytes());
        out.extend_from_slice(&self.generation.to_le_bytes());
        out.extend_from_slice(&(self.row_stride as u32).to_le_bytes());
        out.extend_from_slice(&[0u8; 4]);
        debug_assert_eq!(out.len(), QIDX_HEADER_BYTES);
        out.extend_from_slice(&self.centroids);
        for &o in &self.offsets {
            out.extend_from_slice(&o.to_le_bytes());
        }
        for &r in &self.row_ids {
            out.extend_from_slice(&r.to_le_bytes());
        }
        debug_assert_eq!(out.len(), self.file_bytes());
        out
    }

    /// Exact sidecar size this index implies — decode rejects any other
    /// length, so truncated or padded files can't half-parse.
    pub fn file_bytes(&self) -> usize {
        QIDX_HEADER_BYTES
            + self.n_checkpoints * self.n_clusters * self.row_stride
            + (self.n_clusters + 1) * 8
            + self.n_rows as usize * 8
    }

    /// Parse and structurally validate an encoded sidecar: magic, version,
    /// stride consistency with `k`, exact file length, offset
    /// monotonicity ending at `n_rows`, and the row-id permutation
    /// property (every id in `0..n_rows` exactly once, strictly ascending
    /// within each cluster). Geometry against the *store* is a separate
    /// step ([`QuantIndex::validate_against`]) — decode can't know which
    /// store the caller means.
    pub fn decode(b: &[u8]) -> Result<QuantIndex> {
        ensure!(b.len() >= QIDX_HEADER_BYTES, "index sidecar truncated ({} bytes)", b.len());
        ensure!(b[0..4] == QIDX_MAGIC, "bad index sidecar magic {:?}", &b[0..4]);
        let version = u32::from_le_bytes(b[4..8].try_into()?);
        ensure!(version == QIDX_VERSION, "index sidecar version {version} != {QIDX_VERSION}");
        let k = u64::from_le_bytes(b[8..16].try_into()?) as usize;
        let n_checkpoints = u32::from_le_bytes(b[16..20].try_into()?) as usize;
        let n_clusters = u32::from_le_bytes(b[20..24].try_into()?) as usize;
        let n_rows = u64::from_le_bytes(b[24..32].try_into()?);
        let generation = u64::from_le_bytes(b[32..40].try_into()?);
        let row_stride = u32::from_le_bytes(b[40..44].try_into()?) as usize;
        ensure!(k >= 1 && n_checkpoints >= 1 && n_clusters >= 1, "degenerate index geometry");
        ensure!(
            row_stride == packed_bytes(k, 1),
            "index row_stride {row_stride} inconsistent with k {k} (expect {})",
            packed_bytes(k, 1)
        );
        let mut idx = QuantIndex {
            k,
            n_checkpoints,
            n_clusters,
            n_rows,
            generation,
            row_stride,
            centroids: Vec::new(),
            offsets: Vec::new(),
            row_ids: Vec::new(),
            stale: vec![Vec::new(); n_clusters],
        };
        ensure!(
            b.len() == idx.file_bytes(),
            "index sidecar is {} bytes, header implies {}",
            b.len(),
            idx.file_bytes()
        );
        let mut at = QIDX_HEADER_BYTES;
        let cb = n_checkpoints * n_clusters * row_stride;
        idx.centroids = b[at..at + cb].to_vec();
        at += cb;
        idx.offsets = (0..=n_clusters)
            .map(|i| u64::from_le_bytes(b[at + i * 8..at + i * 8 + 8].try_into().unwrap()))
            .collect();
        at += (n_clusters + 1) * 8;
        idx.row_ids =
            (0..n_rows as usize)
                .map(|i| u64::from_le_bytes(b[at + i * 8..at + i * 8 + 8].try_into().unwrap()))
                .collect();
        ensure!(idx.offsets[0] == 0, "index offsets must start at 0");
        for w in idx.offsets.windows(2) {
            ensure!(w[0] <= w[1], "index offsets must be monotone non-decreasing");
        }
        ensure!(
            *idx.offsets.last().unwrap() == n_rows,
            "index offsets end at {} but the index covers {n_rows} rows",
            idx.offsets.last().unwrap()
        );
        let mut seen = vec![false; n_rows as usize];
        for c in 0..n_clusters {
            let lo = idx.offsets[c] as usize;
            let hi = idx.offsets[c + 1] as usize;
            for (j, &r) in idx.row_ids[lo..hi].iter().enumerate() {
                ensure!(r < n_rows, "index row id {r} out of range (covers {n_rows} rows)");
                ensure!(!seen[r as usize], "index row id {r} appears twice");
                seen[r as usize] = true;
                ensure!(
                    j == 0 || idx.row_ids[lo + j - 1] < r,
                    "cluster {c} row ids not strictly ascending"
                );
            }
        }
        // offsets summing to n_rows + no duplicates ⇒ every row id covered
        Ok(idx)
    }

    /// Validate the index against the store it claims to cover: same
    /// projection dim and checkpoint count, indexed prefix within the
    /// live row space, and a build generation the manifest has actually
    /// reached (a sidecar from the *future* means the run directory was
    /// rolled back under it — e.g. by `repair_run_dir` — so its grouping
    /// may reference rows that no longer exist).
    pub fn validate_against(&self, live: &LiveStore) -> Result<()> {
        let h = live.header();
        ensure!(
            self.k as u64 == h.k,
            "index k {} != store k {}",
            self.k,
            h.k
        );
        ensure!(
            self.n_checkpoints as u32 == h.n_checkpoints,
            "index has {} checkpoints, store has {}",
            self.n_checkpoints,
            h.n_checkpoints
        );
        ensure!(
            self.n_rows <= live.n_rows() as u64,
            "index covers {} rows but the store only has {}",
            self.n_rows,
            live.n_rows()
        );
        ensure!(
            self.generation <= live.generation(),
            "index built at generation {} but the store is at {}",
            self.generation,
            live.generation()
        );
        Ok(())
    }

    /// Write the sidecar atomically: encode to `<path>.tmp`, fsync,
    /// rename into place — a crash mid-write leaves either the old
    /// sidecar or an orphan `.tmp`, never a torn `.qidx`.
    pub fn write_atomic(&self, path: &Path) -> Result<()> {
        use std::io::Write as _;
        let tmp = path.with_extension("qidx.tmp");
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&self.encode())?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Strict open: read `path`, decode, validate against `live`, then
    /// [`QuantIndex::refresh`] the stale tail. Errors are for callers
    /// that *demand* an index (tests, `reindex` verification); the
    /// serving path wants [`QuantIndex::open_for`].
    pub fn open(path: &Path, live: &LiveStore) -> Result<QuantIndex> {
        let bytes = std::fs::read(path)?;
        let mut idx = Self::decode(&bytes)?;
        idx.validate_against(live)?;
        idx.refresh(live)?;
        Ok(idx)
    }

    /// The serving path's open: resolve `<stem>.qidx` next to
    /// `store_path`; a missing sidecar is simply `None` (no index built),
    /// while a present-but-invalid one **warns**, bumps the
    /// `index_open_failures_total` counter, and returns `None` — the
    /// caller falls back to the exhaustive scan, never serving a
    /// corrupted grouping.
    pub fn open_for(store_path: &Path, live: &LiveStore) -> Option<QuantIndex> {
        let path = index_path(store_path);
        if !path.exists() {
            return None;
        }
        match Self::open(&path, live) {
            Ok(idx) => Some(idx),
            Err(e) => {
                warn_!(
                    "index sidecar {} rejected ({e:#}); falling back to exhaustive scans — \
                     run `qless reindex` to rebuild",
                    path.display()
                );
                obs::counter_add("index_open_failures_total", 1);
                None
            }
        }
    }

    /// Assign rows the persisted grouping doesn't cover (live ingest past
    /// the indexed prefix) to their nearest existing centroid, in memory.
    /// No global re-cluster — centroids are frozen at build time; the
    /// staleness counter tells operators when a `qless reindex` is due.
    /// Idempotent: already-assigned stale rows are skipped.
    pub fn refresh(&mut self, live: &LiveStore) -> Result<()> {
        let covered = self.covered_rows() as usize;
        let total = live.n_rows();
        if total <= covered {
            return Ok(());
        }
        let codes = extract_sign_codes(live, covered, total)?;
        let kernel = cpu::active();
        for r in 0..total - covered {
            let best = nearest_centroid(self, &codes, r, kernel);
            self.stale[best].push((covered + r) as u64);
        }
        Ok(())
    }
}

/// One row's packed sign bitmap from `codes` (per-checkpoint planes laid
/// out `[ckpt][row][stride]`).
fn code_row<'a>(codes: &'a [Vec<u8>], ci: usize, row: usize, stride: usize) -> &'a [u8] {
    &codes[ci][row * stride..(row + 1) * stride]
}

/// Nearest centroid for `codes` row `r` under summed per-checkpoint XNOR
/// agreement (max agreement = min Hamming distance; ties break to the
/// lowest cluster id). Padding bits agree on every pair — a constant, so
/// rank-invariant.
fn nearest_centroid(idx: &QuantIndex, codes: &[Vec<u8>], r: usize, kernel: Kernel) -> usize {
    let mut best = 0usize;
    let mut best_agree = 0u64;
    for c in 0..idx.n_clusters {
        let mut agree = 0u64;
        for ci in 0..idx.n_checkpoints {
            agree += simd::xnor_agree(
                kernel,
                code_row(codes, ci, r, idx.row_stride),
                idx.centroid(ci, c),
            ) as u64;
        }
        if c == 0 || agree > best_agree {
            best = c;
            best_agree = agree;
        }
    }
    best
}

/// Extract packed sign bitmaps for global rows `[lo, hi)` of a live
/// store, one plane per checkpoint (`[ckpt][row][stride]`). 1-bit stores
/// contribute their packed bytes directly (they *are* sign bitmaps, zero
/// padded by `quant::pack`); other precisions take the sign of each
/// dequantized value, packed with the same little-endian bit order.
/// Streams member shards under the default memory budget — build memory
/// is O(shard) + the extracted planes, never O(block).
fn extract_sign_codes(live: &LiveStore, lo: usize, hi: usize) -> Result<Vec<Vec<u8>>> {
    let h = *live.header();
    let k = h.k as usize;
    let stride = packed_bytes(k, 1);
    let n = hi - lo;
    let mut codes = vec![vec![0u8; n * stride]; h.n_checkpoints as usize];
    for ci in 0..h.n_checkpoints as usize {
        let plane = &mut codes[ci];
        for member in live.members() {
            let m_lo = member.start_row;
            let m_hi = m_lo + member.ds.n_samples();
            let beg = lo.max(m_lo);
            let end = hi.min(m_hi);
            if beg >= end {
                continue;
            }
            let rps = member.ds.rows_per_shard(0, DEFAULT_MEM_BUDGET_MB);
            let mut reader = member.ds.shard_reader(ci, rps)?;
            reader.seek_to_row(beg - m_lo);
            let mut row = beg - m_lo; // member-local
            while row < end - m_lo {
                let Some(shard) = reader.next_shard()? else {
                    bail!("store ended before row {} while extracting sign codes", end);
                };
                let rows = shard.rows();
                let take = (end - m_lo - shard.start).min(rows.n());
                for j in 0..take {
                    let g = m_lo + shard.start + j - lo; // plane-local
                    let out = &mut plane[g * stride..(g + 1) * stride];
                    if h.precision.bits == 1 {
                        out.copy_from_slice(rows.row_bytes(j));
                    } else {
                        pack_signs_into(&rows.row_f32(j), out);
                    }
                }
                row = shard.start + take;
            }
        }
    }
    Ok(codes)
}

/// Pack `vals[i] > 0` bits little-endian within bytes — the same layout
/// `quant::pack::pack_codes` gives 1-bit sign codes, padding bits 0.
fn pack_signs_into(vals: &[f32], out: &mut [u8]) {
    for (b, chunk) in out.iter_mut().zip(vals.chunks(8)) {
        let mut acc = 0u8;
        for (j, &v) in chunk.iter().enumerate() {
            acc |= u8::from(v > 0.0) << j;
        }
        *b = acc;
    }
}

/// Build an IVF index over a live store (base + all attached segments):
/// extract every row's per-checkpoint sign bitmap, run k-majority Lloyd
/// iterations, and group row ids by final assignment. Deterministic for a
/// given store: evenly-spaced-row seeding, in-order assignment with
/// lowest-id tie-breaks, strict-majority votes (ties → 0) and
/// lowest-farthest-row reseeding of empty clusters.
pub fn build_index(live: &LiveStore, opts: &IndexBuildOpts) -> Result<QuantIndex> {
    let n = live.n_rows();
    ensure!(n >= 1, "cannot index an empty store");
    let h = *live.header();
    let k = h.k as usize;
    let n_checkpoints = h.n_checkpoints as usize;
    let stride = packed_bytes(k, 1);
    let n_clusters =
        if opts.n_clusters == 0 { auto_nclusters(n) } else { opts.n_clusters }.min(n);
    let max_iters = if opts.max_iters == 0 { DEFAULT_INDEX_ITERS } else { opts.max_iters };
    let codes = extract_sign_codes(live, 0, n)?;
    let kernel = cpu::active();

    let mut idx = QuantIndex {
        k,
        n_checkpoints,
        n_clusters,
        n_rows: n as u64,
        generation: live.generation(),
        row_stride: stride,
        centroids: vec![0u8; n_checkpoints * n_clusters * stride],
        offsets: vec![0u64; n_clusters + 1],
        row_ids: Vec::with_capacity(n),
        stale: vec![Vec::new(); n_clusters],
    };
    // deterministic seeding: evenly spaced rows
    for c in 0..n_clusters {
        let seed_row = c * n / n_clusters;
        for ci in 0..n_checkpoints {
            let dst = (ci * n_clusters + c) * stride;
            idx.centroids[dst..dst + stride]
                .copy_from_slice(code_row(&codes, ci, seed_row, stride));
        }
    }

    let mut assign = vec![0u32; n];
    let mut counts = vec![0u32; n_clusters];
    for iter in 0..max_iters {
        // assignment pass (in row order; nearest_centroid ties → low id)
        let mut moved = 0usize;
        counts.iter_mut().for_each(|c| *c = 0);
        for r in 0..n {
            let best = nearest_centroid(&idx, &codes, r, kernel) as u32;
            if best != assign[r] || iter == 0 {
                moved += 1;
            }
            assign[r] = best;
            counts[best as usize] += 1;
        }
        // k-majority centroid update, one bit-count plane per checkpoint
        let mut bit_counts = vec![0u32; k];
        for c in 0..n_clusters {
            if counts[c] == 0 {
                continue; // reseeded below
            }
            for ci in 0..n_checkpoints {
                bit_counts.iter_mut().for_each(|b| *b = 0);
                for r in 0..n {
                    if assign[r] == c as u32 {
                        accumulate_bits(code_row(&codes, ci, r, stride), &mut bit_counts);
                    }
                }
                let maj = majority_bitmap(&bit_counts, counts[c]);
                let dst = (ci * n_clusters + c) * stride;
                idx.centroids[dst..dst + stride].copy_from_slice(&maj);
            }
        }
        // reseed empty clusters with the rows farthest from their
        // centroids (lowest row id on ties), one distinct row each
        let mut reseeded = false;
        let mut taken: Vec<usize> = Vec::new();
        for c in 0..n_clusters {
            if counts[c] > 0 {
                continue;
            }
            let mut far_row = usize::MAX;
            let mut far_agree = u64::MAX;
            for r in 0..n {
                if taken.contains(&r) {
                    continue;
                }
                let home = assign[r] as usize;
                let mut agree = 0u64;
                for ci in 0..n_checkpoints {
                    agree += simd::xnor_agree(
                        kernel,
                        code_row(&codes, ci, r, stride),
                        idx.centroid(ci, home),
                    ) as u64;
                }
                if agree < far_agree {
                    far_agree = agree;
                    far_row = r;
                }
            }
            if far_row == usize::MAX {
                continue; // more clusters than distinct rows left
            }
            taken.push(far_row);
            for ci in 0..n_checkpoints {
                let dst = (ci * n_clusters + c) * stride;
                idx.centroids[dst..dst + stride]
                    .copy_from_slice(code_row(&codes, ci, far_row, stride));
            }
            reseeded = true;
        }
        if moved == 0 && !reseeded {
            break;
        }
    }
    // final assignment under the final centroids, then group by cluster
    counts.iter_mut().for_each(|c| *c = 0);
    for r in 0..n {
        let best = nearest_centroid(&idx, &codes, r, kernel) as u32;
        assign[r] = best;
        counts[best as usize] += 1;
    }
    for c in 0..n_clusters {
        idx.offsets[c + 1] = idx.offsets[c] + counts[c] as u64;
    }
    idx.row_ids = vec![0u64; n];
    let mut cursor: Vec<usize> = idx.offsets[..n_clusters].iter().map(|&o| o as usize).collect();
    for (r, &a) in assign.iter().enumerate() {
        idx.row_ids[cursor[a as usize]] = r as u64;
        cursor[a as usize] += 1;
    }
    Ok(idx)
}

/// Build and atomically persist the sidecar for one precision store of a
/// run directory — the unit of `qless reindex`. Returns the built index
/// (stale count 0 by construction: it covers the store's current rows).
pub fn reindex_store(store_path: &Path, opts: &IndexBuildOpts) -> Result<QuantIndex> {
    let live = LiveStore::open(store_path)?;
    let idx = build_index(&live, opts)?;
    idx.write_atomic(&index_path(store_path))?;
    Ok(idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Precision, Scheme};
    use crate::util::prop::seeded_datastore;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "qless_qidx_{tag}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    fn live(tag: &str, bits: u8, n: usize, k: usize, etas: &[f32]) -> (LiveStore, PathBuf) {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = tmp(tag);
        seeded_datastore(&path, p, n, k, etas, 7);
        (LiveStore::open(&path).unwrap(), path)
    }

    #[test]
    fn build_partitions_the_row_space() {
        for bits in [1u8, 8] {
            let (store, path) = live(&format!("part{bits}"), bits, 37, 96, &[0.9, 0.4]);
            let idx =
                build_index(&store, &IndexBuildOpts { n_clusters: 5, max_iters: 4 }).unwrap();
            assert_eq!(idx.n_clusters(), 5);
            assert_eq!(idx.n_rows(), 37);
            assert_eq!(idx.stale_rows(), 0);
            let mut all: Vec<u64> = (0..5).flat_map(|c| idx.cluster_rows(c)).collect();
            all.sort_unstable();
            assert_eq!(all, (0..37u64).collect::<Vec<_>>(), "{bits}-bit: clusters partition rows");
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn encode_decode_roundtrip_and_atomic_write() {
        let (store, path) = live("codec", 1, 23, 64, &[1.0]);
        let idx = build_index(&store, &IndexBuildOpts { n_clusters: 4, max_iters: 3 }).unwrap();
        let back = QuantIndex::decode(&idx.encode()).unwrap();
        assert_eq!(back.encode(), idx.encode());
        let sidecar = index_path(&path);
        idx.write_atomic(&sidecar).unwrap();
        assert!(!sidecar.with_extension("qidx.tmp").exists(), "tmp renamed away");
        let opened = QuantIndex::open(&sidecar, &store).unwrap();
        assert_eq!(opened.encode(), idx.encode());
        assert!(QuantIndex::open_for(&path, &store).is_some());
        std::fs::remove_file(&sidecar).ok();
        assert!(QuantIndex::open_for(&path, &store).is_none(), "missing sidecar is None");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn clusters_are_not_degenerate_on_clustered_data() {
        // identical rows must land in the same cluster: build over a store
        // whose rows repeat 4 patterns, expect exactly those groups
        use crate::datastore::DatastoreWriter;
        let (n, k) = (16usize, 64usize);
        let path = tmp("groups");
        let p = Precision::new(1, Scheme::Sign).unwrap();
        let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
        w.begin_checkpoint(1.0).unwrap();
        for i in 0..n {
            // 4 well-separated sign patterns
            let row: Vec<f32> =
                (0..k).map(|j| if (j / 16) % 4 == i % 4 { 1.0 } else { -1.0 }).collect();
            w.append_features(&row).unwrap();
        }
        w.end_checkpoint().unwrap();
        w.finalize().unwrap();
        let store = LiveStore::open(&path).unwrap();
        let idx = build_index(&store, &IndexBuildOpts { n_clusters: 4, max_iters: 6 }).unwrap();
        for c in 0..4 {
            let rows: Vec<u64> = idx.cluster_rows(c).collect();
            assert!(!rows.is_empty(), "cluster {c} empty");
            // all members share a pattern (row % 4 constant)
            let first = rows[0] % 4;
            assert!(rows.iter().all(|r| r % 4 == first), "cluster {c} mixes patterns: {rows:?}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn decode_rejects_structural_corruption() {
        let (store, path) = live("corrupt", 1, 12, 64, &[1.0]);
        let idx = build_index(&store, &IndexBuildOpts { n_clusters: 3, max_iters: 2 }).unwrap();
        let good = idx.encode();
        // magic
        let mut b = good.clone();
        b[0] = b'X';
        assert!(QuantIndex::decode(&b).is_err());
        // version
        let mut b = good.clone();
        b[4] = 99;
        assert!(QuantIndex::decode(&b).is_err());
        // truncation (drop the last row id)
        assert!(QuantIndex::decode(&good[..good.len() - 8]).is_err());
        // trailing garbage
        let mut b = good.clone();
        b.extend_from_slice(&[0u8; 8]);
        assert!(QuantIndex::decode(&b).is_err());
        // duplicated row id (first two ids equal)
        let mut b = good.clone();
        let ids_at = good.len() - 12 * 8;
        b.copy_within(ids_at..ids_at + 8, ids_at + 8);
        assert!(QuantIndex::decode(&b).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn open_for_rejects_geometry_mismatch() {
        let (store, path) = live("geom", 1, 10, 64, &[1.0]);
        let idx = build_index(&store, &IndexBuildOpts { n_clusters: 2, max_iters: 2 }).unwrap();
        idx.write_atomic(&index_path(&path)).unwrap();
        // a store with a different k must refuse the sidecar
        let (other, other_path) = live("geom_other", 1, 10, 128, &[1.0]);
        std::fs::copy(index_path(&path), index_path(&other_path)).unwrap();
        assert!(QuantIndex::open_for(&other_path, &other).is_none());
        std::fs::remove_file(index_path(&other_path)).ok();
        std::fs::remove_file(other_path).ok();
        std::fs::remove_file(index_path(&path)).ok();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn defaults_are_sane() {
        assert_eq!(auto_nclusters(0), 1);
        assert_eq!(auto_nclusters(2048), 46);
        assert_eq!(auto_nclusters(100_000_000), 4096);
        assert_eq!(default_nprobe(1), 1);
        assert_eq!(default_nprobe(46), 5);
        assert_eq!(default_nprobe(64), 8);
        assert_eq!(
            index_path(Path::new("/run/datastore_1b_sign.qlds")),
            Path::new("/run/datastore_1b_sign.qidx")
        );
    }
}
