//! The generation manifest — the small JSON sidecar that turns a run
//! directory's frozen datastores into a **live, append-only** store.
//!
//! A freshly built run directory holds one base datastore file per
//! precision and no manifest: that is **generation 0**. Every `qless
//! ingest` appends one *segment* datastore file per precision (same
//! geometry, new rows; see [`crate::datastore::live`]) and bumps the
//! persisted generation counter here, recording the segment's global row
//! range. Readers ([`crate::datastore::LiveStore`], the resident service)
//! poll this file to discover new rows without reopening — or touching —
//! any byte that was already on disk.
//!
//! The manifest is **precision-agnostic**: every precision of a run stores
//! exactly the same rows, so one sidecar describes them all. Writes are
//! atomic (temp file + rename), so a reader never observes a torn
//! manifest; a crash *before* the rename leaves the previous generation in
//! force and the half-written segment files as orphans, which
//! [`crate::datastore::repair_run_dir`] detects and removes.
//!
//! On-disk schema (see `rust/crates/qless-datastore/FORMAT.md` §Generation manifest):
//!
//! ```text
//! {"version":1,"k":512,"n_checkpoints":4,"base_rows":8000,"generation":2,
//!  "segments":[{"generation":1,"start_row":8000,"rows":1000},
//!              {"generation":2,"start_row":9000,"rows":500}]}
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// File name of the generation manifest inside a run directory.
pub const MANIFEST_FILE: &str = "qless.manifest.json";

/// Manifest schema version accepted by [`Manifest::load`].
pub const MANIFEST_VERSION: u64 = 1;

/// One ingested segment: a contiguous global row range appended at one
/// generation. Rows `start_row .. start_row + rows` of the live store live
/// in this segment's per-precision files.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentMeta {
    /// The generation that appended this segment (≥ 1; 0 is the base).
    pub generation: u64,
    /// Global row index of the segment's first row.
    pub start_row: u64,
    /// Rows in the segment (> 0).
    pub rows: u64,
}

/// The persisted generation state of one run directory (see the module
/// docs). `generation` is a monotonically increasing counter: 0 for a
/// frozen base-only store, bumped by one per successful ingest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Projection dimension shared by every member store.
    pub k: u64,
    /// Checkpoint blocks per member store.
    pub n_checkpoints: u32,
    /// Rows in the base (generation-0) datastore files.
    pub base_rows: u64,
    /// Current generation (equals the last segment's generation, or 0).
    pub generation: u64,
    /// Appended segments, in generation order.
    pub segments: Vec<SegmentMeta>,
}

impl Manifest {
    /// A fresh generation-0 manifest for the given base geometry.
    pub fn new(k: usize, n_checkpoints: usize, base_rows: usize) -> Manifest {
        Manifest {
            k: k as u64,
            n_checkpoints: n_checkpoints as u32,
            base_rows: base_rows as u64,
            generation: 0,
            segments: Vec::new(),
        }
    }

    /// The manifest's path inside `dir`.
    pub fn path_in(dir: &Path) -> PathBuf {
        dir.join(MANIFEST_FILE)
    }

    /// Total rows across the base and every segment.
    pub fn total_rows(&self) -> u64 {
        self.base_rows + self.segments.iter().map(|s| s.rows).sum::<u64>()
    }

    /// Append a segment of `rows` rows: bumps the generation and returns
    /// the new segment's metadata (its row range starts at the previous
    /// [`Manifest::total_rows`]).
    pub fn push_segment(&mut self, rows: u64) -> SegmentMeta {
        let seg = SegmentMeta {
            generation: self.generation + 1,
            start_row: self.total_rows(),
            rows,
        };
        self.generation = seg.generation;
        self.segments.push(seg);
        seg
    }

    /// Drop every segment past the first `keep`, rolling the generation
    /// counter back with them — the crash-repair primitive
    /// ([`crate::datastore::repair_run_dir`]).
    pub fn truncate_segments(&mut self, keep: usize) {
        self.segments.truncate(keep);
        self.generation = self.segments.last().map(|s| s.generation).unwrap_or(0);
    }

    /// Check the manifest's internal invariants: segments contiguous from
    /// `base_rows`, generations strictly ascending and ≥ 1, no empty
    /// segments, and the generation counter equal to the last segment's.
    pub fn validate(&self) -> Result<()> {
        let mut next_row = self.base_rows;
        let mut last_gen = 0u64;
        for (i, s) in self.segments.iter().enumerate() {
            if s.rows == 0 {
                bail!("manifest segment {i} is empty");
            }
            if s.start_row != next_row {
                bail!(
                    "manifest segment {i} starts at row {} (expected {next_row})",
                    s.start_row
                );
            }
            if s.generation <= last_gen {
                bail!(
                    "manifest segment {i} has generation {} after {last_gen} \
                     (must be strictly ascending)",
                    s.generation
                );
            }
            next_row += s.rows;
            last_gen = s.generation;
        }
        if self.generation != last_gen {
            bail!(
                "manifest generation {} != last segment generation {last_gen}",
                self.generation
            );
        }
        Ok(())
    }

    /// Serialize to the on-disk JSON schema.
    pub fn to_json(&self) -> Json {
        let segs: Vec<Json> = self
            .segments
            .iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("generation", s.generation as usize)
                    .set("start_row", s.start_row as usize)
                    .set("rows", s.rows as usize);
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("version", MANIFEST_VERSION as usize)
            .set("k", self.k as usize)
            .set("n_checkpoints", self.n_checkpoints as usize)
            .set("base_rows", self.base_rows as usize)
            .set("generation", self.generation as usize)
            .set("segments", Json::Arr(segs));
        o
    }

    /// Parse the on-disk JSON schema (strict: unknown versions rejected,
    /// invariants checked).
    pub fn from_json(j: &Json) -> Result<Manifest> {
        let version = j.req("version")?.as_usize()? as u64;
        if version != MANIFEST_VERSION {
            bail!("manifest version {version} != {MANIFEST_VERSION}");
        }
        let mut m = Manifest {
            k: j.req("k")?.as_usize()? as u64,
            n_checkpoints: j.req("n_checkpoints")?.as_usize()? as u32,
            base_rows: j.req("base_rows")?.as_usize()? as u64,
            generation: j.req("generation")?.as_usize()? as u64,
            segments: Vec::new(),
        };
        for s in j.req("segments")?.as_arr()? {
            m.segments.push(SegmentMeta {
                generation: s.req("generation")?.as_usize()? as u64,
                start_row: s.req("start_row")?.as_usize()? as u64,
                rows: s.req("rows")?.as_usize()? as u64,
            });
        }
        m.validate()?;
        Ok(m)
    }

    /// Load the manifest of `dir`, if one exists. `Ok(None)` means a
    /// frozen generation-0 store; any unreadable or invalid manifest is an
    /// error, never silently ignored.
    pub fn load(dir: &Path) -> Result<Option<Manifest>> {
        let path = Self::path_in(dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("reading manifest {path:?}")),
        };
        let j = Json::parse(&text).with_context(|| format!("parsing manifest {path:?}"))?;
        Ok(Some(Self::from_json(&j).with_context(|| format!("validating manifest {path:?}"))?))
    }

    /// Persist atomically into `dir` (temp file + rename): a concurrent
    /// reader sees either the previous or the new generation, never a torn
    /// file.
    pub fn save(&self, dir: &Path) -> Result<()> {
        self.validate()?;
        let path = Self::path_in(dir);
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().encode_pretty())
            .with_context(|| format!("writing manifest {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing manifest {path:?}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "qless_manifest_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrips_through_disk() {
        let dir = tmpdir("rt");
        assert!(Manifest::load(&dir).unwrap().is_none(), "no manifest yet");
        let mut m = Manifest::new(64, 2, 100);
        assert_eq!(m.generation, 0);
        assert_eq!(m.total_rows(), 100);
        let s1 = m.push_segment(10);
        assert_eq!((s1.generation, s1.start_row, s1.rows), (1, 100, 10));
        let s2 = m.push_segment(5);
        assert_eq!((s2.generation, s2.start_row), (2, 110));
        assert_eq!(m.total_rows(), 115);
        m.save(&dir).unwrap();
        let back = Manifest::load(&dir).unwrap().unwrap();
        assert_eq!(back, m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncate_rolls_back_generation() {
        let mut m = Manifest::new(8, 1, 50);
        m.push_segment(10);
        m.push_segment(20);
        m.truncate_segments(1);
        assert_eq!(m.generation, 1);
        assert_eq!(m.total_rows(), 60);
        m.validate().unwrap();
        m.truncate_segments(0);
        assert_eq!(m.generation, 0);
        assert_eq!(m.total_rows(), 50);
        m.validate().unwrap();
    }

    #[test]
    fn validate_rejects_corruption() {
        let mut m = Manifest::new(8, 1, 50);
        m.push_segment(10);
        let mut bad = m.clone();
        bad.segments[0].start_row = 51; // gap
        assert!(bad.validate().is_err());
        let mut bad = m.clone();
        bad.segments[0].rows = 0; // empty
        assert!(bad.validate().is_err());
        let mut bad = m.clone();
        bad.generation = 7; // counter out of sync
        assert!(bad.validate().is_err());
        let mut bad = m.clone();
        bad.segments.push(SegmentMeta { generation: 1, start_row: 60, rows: 2 });
        assert!(bad.validate().is_err(), "non-ascending generation");
        // a corrupt file on disk is an error, not a silent None
        let dir = tmpdir("bad");
        std::fs::write(Manifest::path_in(&dir), "{not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(Manifest::path_in(&dir), "{\"version\":99}").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
