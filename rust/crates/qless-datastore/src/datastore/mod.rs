//! The gradient datastore — QLESS's central artifact (paper §3.1).
//!
//! One file per (run × precision): a header, then one block per warmup
//! checkpoint holding the learning-rate weight η_i, per-row scales, and the
//! bit-packed gradient codes for every training sample. The measured file
//! size *is* the storage column of Table 1 (the accounting formula
//! [`crate::quant::datastore_bytes`] reproduces the paper's GB figures at
//! the paper's scale).
//!
//! Layout (little-endian):
//! ```text
//! magic "QLDS" | version u32 | bits u8 | scheme u8 | pad u16
//! n_samples u64 | k u64 | n_checkpoints u32 | row_stride u32
//! per checkpoint:
//!   eta f32 | scales [n_samples × f32] | rows [n_samples × row_stride u8]
//! ```
//! 16-bit blocks store bf16 codes and omit the scales section entirely
//! (bf16 rows are self-describing). Sub-byte rows are packed little-endian
//! within bytes (`quant::pack`).
//!
//! Two read paths over the same layout:
//!
//! * [`Datastore::load_checkpoint`] — materialize one whole block
//!   (`O(n × row_stride)` resident), the original reader.
//! * [`Datastore::shard_reader`] — stream the block in fixed-size row
//!   shards under a memory budget (`O(rows_per_shard × row_stride)`
//!   resident); byte-identical rows, so scores match the block path
//!   exactly.
//!
//! Two write paths, also byte-identical:
//!
//! * [`DatastoreWriter`] — one precision, row-by-row or pre-packed
//!   windows, `O(window)` resident (positioned flushes).
//! * [`MultiWriter`] — the streaming builder's fan-out: one feature-row
//!   stream quantized at **every** requested precision in one pass
//!   (pool-parallel windows), peak memory independent of the corpus size.
//!
//! A run directory becomes **live** (append-only ingest) through the
//! generation layer: [`SegmentWriter`] appends new rows as self-contained
//! segment files and bumps the [`Manifest`]; [`LiveStore`] serves base +
//! segments as one row space and picks up new generations in place. The
//! byte-level spec of all of it is `rust/crates/qless-datastore/FORMAT.md` (included as the
//! [`format`] module's rustdoc, so its hex example runs as a doctest).
//!
//! Next to a store there may also be an IVF **index sidecar**
//! (`<stem>.qidx`, the [`index`] module): k-majority Hamming clusters over
//! the rows' sign bitmaps that let `influence::index` scan only the
//! probed clusters' rows instead of the whole store. The sidecar is
//! derived data — validated on open, rebuilt by `qless reindex`, and
//! never required for correctness (every reader falls back to the
//! exhaustive scan without it).

pub mod format;
pub mod index;
pub mod live;
pub mod manifest;
pub mod multi;
pub mod store;

pub use format::{Header, MAGIC, VERSION};
pub use index::{
    auto_nclusters, build_index, default_nprobe, index_path, reindex_store, IndexBuildOpts,
    QuantIndex, QIDX_MAGIC, QIDX_VERSION,
};
pub use live::{
    repair_run_dir, run_dir_precisions, segment_store_path, LiveMember, LiveStore, SegmentWriter,
};
pub use manifest::{Manifest, SegmentMeta, MANIFEST_FILE, MANIFEST_VERSION};
pub use multi::MultiWriter;
pub use store::{
    CheckpointBlock, Datastore, DatastoreWriter, OwnedShard, RowsView, Shard, ShardReader,
};

use std::path::{Path, PathBuf};

use crate::quant::Precision;

/// Canonical datastore path for a run directory and precision —
/// `<run_dir>/datastore_<bits>b_<scheme>.qlds`. The single source of the
/// naming shared by the pipeline's builder (`Pipeline::build_datastore`)
/// and `qless serve`'s default store lookup, so the two can't drift apart.
pub fn default_store_path(run_dir: &Path, precision: Precision) -> PathBuf {
    run_dir.join(format!("datastore_{}b_{}.qlds", precision.bits, precision.scheme))
}
