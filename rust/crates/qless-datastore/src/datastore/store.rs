//! Datastore writer / readers over the `format` layout.
//!
//! The writer streams rows checkpoint-by-checkpoint under a **bounded
//! staging window**: rows (and their scales) are buffered up to
//! `window_rows`, then flushed with positioned writes to their final
//! offsets — the scales section precedes the rows on disk, but seeks let
//! both stream out incrementally, so peak writer memory is `O(window)`,
//! never `O(n)`. [`DatastoreWriter::append_packed_window`] additionally
//! lets the multi-precision builder ([`crate::datastore::MultiWriter`])
//! write pre-quantized windows straight through. Two readers share the
//! layout: the whole-block loader ([`Datastore::load_checkpoint`],
//! `O(block)` resident) and the streaming [`ShardReader`] the influence
//! scan uses — fixed-size row shards under a memory budget, still
//! sequential within a checkpoint, `O(shard)` resident. Both decode rows
//! through [`RowsView`], so they are byte- and score-identical.

use std::fs::File;
use std::io::{BufReader, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::format::Header;
use crate::quant::pack::{pack_codes, PackedRow};
use crate::quant::scheme::{try_quantize_row, QuantizedRow};
use crate::quant::Precision;
use crate::util::bits::{bf16_to_f32, f32_to_bf16};

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

/// Default staging-window size for the per-row append path (bytes of
/// packed rows buffered before a positioned flush).
const DEFAULT_WINDOW_BYTES: u64 = 4 << 20;

/// Streaming datastore writer: header up front, then one block per
/// checkpoint (`begin_checkpoint` → `append_features`× → `end_checkpoint`),
/// validated against the header's geometry at `finalize`. Peak resident
/// memory is one staging window (see [`Self::set_window_rows`]), not the
/// checkpoint block.
pub struct DatastoreWriter {
    file: File,
    path: PathBuf,
    header: Header,
    ckpt_open: bool,
    rows_in_ckpt: u64,
    ckpts_done: u32,
    /// Scales staged for the buffered rows (bits < 16 only).
    scales: Vec<f32>,
    /// Row bytes staged since the last flush.
    row_buf: Vec<u8>,
    /// Global row index of the first staged row.
    win_start: u64,
    /// Staged rows per flush (the memory bound).
    window_rows: usize,
}

impl DatastoreWriter {
    /// Create a datastore file at `path` for the given geometry (parents
    /// are created as needed) and write its header.
    pub fn create(
        path: &Path,
        precision: Precision,
        n_samples: usize,
        k: usize,
        n_checkpoints: usize,
    ) -> Result<DatastoreWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let header = Header::new(precision, n_samples, k, n_checkpoints);
        let mut file = File::create(path).with_context(|| format!("creating datastore {path:?}"))?;
        file.write_all(&header.encode())?;
        let window_rows = (DEFAULT_WINDOW_BYTES / header.resident_row_bytes().max(1))
            .clamp(1, (n_samples as u64).max(1)) as usize;
        Ok(DatastoreWriter {
            file,
            path: path.to_path_buf(),
            header,
            ckpt_open: false,
            rows_in_ckpt: 0,
            ckpts_done: 0,
            scales: Vec::new(),
            row_buf: Vec::new(),
            win_start: 0,
            window_rows,
        })
    }

    /// Bound the staging window to `rows` rows (floored at 1). The default
    /// stages ~4 MiB of packed rows between flushes; callers appending
    /// row-by-row under a tighter memory budget shrink it here. Flush
    /// cadence is invisible on disk — every window size produces identical
    /// bytes (`window_size_does_not_change_bytes`).
    pub fn set_window_rows(&mut self, rows: usize) {
        self.window_rows = rows.max(1);
    }

    /// Start the block for the next checkpoint with its LR weight η_i.
    pub fn begin_checkpoint(&mut self, eta: f32) -> Result<()> {
        if self.ckpt_open {
            bail!("begin_checkpoint: previous checkpoint not finished");
        }
        if self.ckpts_done >= self.header.n_checkpoints {
            bail!("too many checkpoints");
        }
        self.file.seek(SeekFrom::Start(self.header.block_offset(self.ckpts_done as usize)))?;
        self.file.write_all(&eta.to_le_bytes())?;
        self.scales.clear();
        self.row_buf.clear();
        self.win_start = 0;
        self.ckpt_open = true;
        self.rows_in_ckpt = 0;
        Ok(())
    }

    /// Append one sample's feature row. Rows must arrive in sample order.
    /// For bits < 16 the row is quantized with the datastore's scheme; at
    /// 16-bit features are stored as bf16 verbatim (the LESS baseline).
    ///
    /// Non-finite features are rejected here with a clear error — at every
    /// bitwidth — so a NaN gradient can never be laundered into valid-
    /// looking codes (sign path) or a NaN score that only explodes in
    /// `select::topk` checkpoints later.
    pub fn append_features(&mut self, features: &[f32]) -> Result<()> {
        if features.len() != self.header.k as usize {
            bail!("feature dim {} != k {}", features.len(), self.header.k);
        }
        let p = self.header.precision;
        if p.bits == 16 {
            if let Some(i) = features.iter().position(|x| !x.is_finite()) {
                bail!(
                    "non-finite gradient feature {} at index {i} (sample {} of checkpoint {}): \
                     rejected at datastore-write time",
                    features[i],
                    self.rows_in_ckpt,
                    self.ckpts_done
                );
            }
            self.append_row_raw(None, features)
        } else {
            let q = try_quantize_row(features, p.bits, p.scheme).with_context(|| {
                format!(
                    "quantizing sample {} of checkpoint {}",
                    self.rows_in_ckpt, self.ckpts_done
                )
            })?;
            self.append_quantized(&q)
        }
    }

    /// Append an already-quantized row (the XLA quantization path). The
    /// scale is checked for finiteness — an external quantizer fed a NaN
    /// gradient produces valid-looking ±codes with a NaN scale, which
    /// must not reach disk.
    pub fn append_quantized(&mut self, q: &QuantizedRow) -> Result<()> {
        let p = self.header.precision;
        if p.bits == 16 {
            bail!("append_quantized on a 16-bit datastore");
        }
        if q.codes.len() != self.header.k as usize {
            bail!("code dim {} != k {}", q.codes.len(), self.header.k);
        }
        if !q.scale.is_finite() {
            bail!(
                "non-finite quantization scale {} (sample {} of checkpoint {}): \
                 rejected at datastore-write time",
                q.scale,
                self.rows_in_ckpt,
                self.ckpts_done
            );
        }
        let packed = pack_codes(&q.codes, p.bits, q.scale)?;
        self.append_packed_bytes(q.scale, &packed.bytes)
    }

    fn append_row_raw(&mut self, _scale: Option<f32>, features: &[f32]) -> Result<()> {
        // 16-bit: bf16 codes straight to the row section (no scales section).
        if !self.ckpt_open {
            bail!("append before begin_checkpoint");
        }
        let mut buf = Vec::with_capacity(features.len() * 2);
        for &f in features {
            buf.extend_from_slice(&f32_to_bf16(f).to_le_bytes());
        }
        self.write_row_bytes(&buf)
    }

    fn append_packed_bytes(&mut self, scale: f32, bytes: &[u8]) -> Result<()> {
        if !self.ckpt_open {
            bail!("append before begin_checkpoint");
        }
        if self.rows_in_ckpt >= self.header.n_samples {
            bail!("too many rows in checkpoint");
        }
        self.scales.push(scale);
        self.write_row_bytes(bytes)
    }

    fn write_row_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        if bytes.len() != self.header.row_stride as usize {
            bail!("row stride {} != {}", bytes.len(), self.header.row_stride);
        }
        if self.rows_in_ckpt >= self.header.n_samples {
            bail!("too many rows in checkpoint");
        }
        self.row_buf.extend_from_slice(bytes);
        self.rows_in_ckpt += 1;
        if self.row_buf.len() >= self.window_rows * self.header.row_stride as usize {
            self.flush_window()?;
        }
        Ok(())
    }

    /// Positioned write of one window — `scales` to the block's scales
    /// section, `bytes` to the row section, both at their final offsets
    /// starting at `win_start` — advancing `win_start` past it. The single
    /// offset-math site behind both the staged flush and the pre-packed
    /// window path.
    fn write_window_at(&mut self, scales: &[f32], bytes: &[u8]) -> Result<()> {
        let rows = bytes.len() / (self.header.row_stride as usize).max(1);
        if rows == 0 {
            return Ok(());
        }
        let c = self.ckpts_done as usize;
        if self.header.precision.bits != 16 {
            self.file
                .seek(SeekFrom::Start(self.header.scales_offset(c) + 4 * self.win_start))?;
            let mut sb = Vec::with_capacity(4 * scales.len());
            for s in scales {
                sb.extend_from_slice(&s.to_le_bytes());
            }
            self.file.write_all(&sb)?;
        }
        self.file.seek(SeekFrom::Start(self.header.row_offset(c, self.win_start)))?;
        self.file.write_all(bytes)?;
        self.win_start += rows as u64;
        Ok(())
    }

    /// Flush the staged window through [`Self::write_window_at`], keeping
    /// the buffers' capacity for the next window.
    fn flush_window(&mut self) -> Result<()> {
        let scales = std::mem::take(&mut self.scales);
        let row_buf = std::mem::take(&mut self.row_buf);
        let res = self.write_window_at(&scales, &row_buf);
        self.scales = scales;
        self.scales.clear();
        self.row_buf = row_buf;
        self.row_buf.clear();
        res
    }

    /// Append a pre-quantized window of rows: `bytes` holds
    /// `n × row_stride` packed rows and `scales` their `n` row scales
    /// (empty at 16-bit). The window is written through at its final
    /// offsets — no staging copy — which is the multi-precision builder's
    /// fan-out path ([`crate::quant::batch::quantize_rows_into`] produces
    /// exactly this layout, byte-identical to the per-row
    /// [`Self::append_features`] loop).
    pub fn append_packed_window(&mut self, scales: &[f32], bytes: &[u8]) -> Result<()> {
        if !self.ckpt_open {
            bail!("append before begin_checkpoint");
        }
        let stride = self.header.row_stride as usize;
        if stride == 0 || bytes.len() % stride != 0 {
            bail!("window of {} bytes is not a whole number of {stride}-byte rows", bytes.len());
        }
        let n = bytes.len() / stride;
        let expect_scales = if self.header.precision.bits == 16 { 0 } else { n };
        if scales.len() != expect_scales {
            bail!("window has {} scales for {n} rows (expected {expect_scales})", scales.len());
        }
        if self.rows_in_ckpt + n as u64 > self.header.n_samples {
            bail!("too many rows in checkpoint");
        }
        self.flush_window()?; // anything staged goes first, in row order
        self.write_window_at(scales, bytes)?;
        self.rows_in_ckpt += n as u64;
        Ok(())
    }

    /// Finish the current checkpoint block (flushes the staged window).
    pub fn end_checkpoint(&mut self) -> Result<()> {
        if !self.ckpt_open {
            bail!("end_checkpoint without begin");
        }
        if self.rows_in_ckpt != self.header.n_samples {
            bail!("checkpoint has {} rows, expected {}", self.rows_in_ckpt, self.header.n_samples);
        }
        self.flush_window()?;
        self.ckpt_open = false;
        self.ckpts_done += 1;
        Ok(())
    }

    /// Flush and validate the finished datastore; returns the file size.
    pub fn finalize(mut self) -> Result<u64> {
        if self.ckpt_open {
            bail!("finalize with open checkpoint");
        }
        if self.ckpts_done != self.header.n_checkpoints {
            bail!("wrote {} checkpoints, expected {}", self.ckpts_done, self.header.n_checkpoints);
        }
        self.file.flush()?;
        let size = std::fs::metadata(&self.path)?.len();
        let expect = self.header.file_bytes();
        if size != expect {
            bail!("datastore size {size} != expected {expect}");
        }
        Ok(size)
    }
}

// ---------------------------------------------------------------------------
// reader
// ---------------------------------------------------------------------------

/// A borrowed view over a contiguous run of packed feature rows — the
/// common currency of the scoring kernels. Both the whole-block reader
/// ([`CheckpointBlock::rows`]) and the streaming shard reader
/// ([`ShardReader`]) hand out this same view, which is what makes the two
/// paths bit-identical: the decode logic lives here, once.
#[derive(Debug, Clone, Copy)]
pub struct RowsView<'a> {
    /// Storage precision of the rows (bits + scheme).
    pub precision: Precision,
    /// Codes per row (the projection dimension).
    pub k: usize,
    /// Bytes per packed row on disk and in `data`.
    pub row_stride: usize,
    /// Per-row scales (empty at 16-bit).
    pub scales: &'a [f32],
    /// Packed row data, `n × row_stride` bytes.
    pub data: &'a [u8],
}

impl<'a> RowsView<'a> {
    /// Number of rows in the view.
    pub fn n(&self) -> usize {
        self.data.len() / self.row_stride
    }

    /// Raw packed bytes of row `i` (the on-disk layout, `row_stride` long).
    pub fn row_bytes(&self, i: usize) -> &'a [u8] {
        &self.data[i * self.row_stride..(i + 1) * self.row_stride]
    }

    /// Borrow the sub-view of rows `a .. b` (view-local indices). Rows are
    /// byte-aligned (`row_stride` bytes each), so this is a pure slice —
    /// no decode, no copy. The scatter-gather serving path clips cached
    /// whole shards to a worker's row range with it; scoring a clipped
    /// view is bit-identical to scoring those rows inside the full shard
    /// because per-row kernels only read the row's own bytes and scale.
    pub fn slice(&self, a: usize, b: usize) -> RowsView<'a> {
        debug_assert!(a <= b && b <= self.n());
        RowsView {
            precision: self.precision,
            k: self.k,
            row_stride: self.row_stride,
            scales: if self.scales.is_empty() { self.scales } else { &self.scales[a..b] },
            data: &self.data[a * self.row_stride..b * self.row_stride],
        }
    }

    /// Unpack row `i`'s lanes as zero-extended **stored** values
    /// (offset-binary `code + α`; the raw sign bit at 1-bit) into `out` —
    /// the integer scoring engine's code-layout accessor: no sign
    /// extension, no dequantization, no per-element float math. At 8-bit
    /// the lanes are the row bytes themselves, so hot paths can borrow
    /// [`Self::row_bytes`] directly instead.
    pub fn row_stored_into(&self, i: usize, out: &mut Vec<u8>) {
        assert!(self.precision.bits < 16, "stored lanes exist only for packed rows");
        crate::quant::pack::unpack_stored_into(self.row_bytes(i), self.precision.bits, self.k, out)
    }

    /// Dequantize row `i` to f32 features.
    pub fn row_f32(&self, i: usize) -> Vec<f32> {
        let raw = self.row_bytes(i);
        if self.precision.bits == 16 {
            raw.chunks(2)
                .map(|b| bf16_to_f32(u16::from_le_bytes([b[0], b[1]])))
                .collect()
        } else {
            let packed = PackedRow {
                bits: self.precision.bits,
                len: self.k,
                bytes: raw.to_vec(),
                scale: self.scales[i],
            };
            crate::quant::pack::unpack_dequant(&packed)
        }
    }

    /// Integer codes of row `i` (bits < 16).
    pub fn row_codes(&self, i: usize) -> Vec<i8> {
        assert!(self.precision.bits < 16);
        let packed = PackedRow {
            bits: self.precision.bits,
            len: self.k,
            bytes: self.row_bytes(i).to_vec(),
            scale: 0.0,
        };
        crate::quant::pack::unpack_codes(&packed)
    }
}

/// One checkpoint's worth of features, resident in memory.
#[derive(Debug, Clone)]
pub struct CheckpointBlock {
    /// Storage precision of the rows (bits + scheme).
    pub precision: Precision,
    /// Number of sample rows in the block.
    pub n: usize,
    /// Codes per row (the projection dimension).
    pub k: usize,
    /// The checkpoint's learning-rate weight η_i (Eq. 7).
    pub eta: f32,
    /// Per-row scales (empty at 16-bit).
    pub scales: Vec<f32>,
    /// Packed row data, `n × row_stride` bytes.
    pub data: Vec<u8>,
    /// Bytes per packed row.
    pub row_stride: usize,
}

impl CheckpointBlock {
    /// Borrow the block's rows as the scoring kernels' common view.
    pub fn rows(&self) -> RowsView<'_> {
        RowsView {
            precision: self.precision,
            k: self.k,
            row_stride: self.row_stride,
            scales: &self.scales,
            data: &self.data,
        }
    }

    /// Dequantize row `i` to f32 features.
    pub fn row_f32(&self, i: usize) -> Vec<f32> {
        self.rows().row_f32(i)
    }

    /// Integer codes of row `i` (bits < 16).
    pub fn row_codes(&self, i: usize) -> Vec<i8> {
        self.rows().row_codes(i)
    }

    /// Raw packed bytes of row `i` (the on-disk layout).
    pub fn row_bytes(&self, i: usize) -> &[u8] {
        &self.data[i * self.row_stride..(i + 1) * self.row_stride]
    }
}

/// A validated datastore file handle: the parsed [`Header`] plus the path,
/// read lazily by [`Datastore::load_checkpoint`] / [`Datastore::shard_reader`].
pub struct Datastore {
    /// The file's parsed, size-validated header.
    pub header: Header,
    path: PathBuf,
}

impl Datastore {
    /// Open and validate a datastore file (header decode + exact file-size
    /// check, so truncated stores fail here, not mid-scan).
    pub fn open(path: &Path) -> Result<Datastore> {
        let mut f = File::open(path).with_context(|| format!("opening datastore {path:?}"))?;
        let mut hdr = [0u8; Header::BYTES];
        f.read_exact(&mut hdr)?;
        let header = Header::decode(&hdr)?;
        let size = f.metadata()?.len();
        if size != header.file_bytes() {
            bail!("datastore {path:?} truncated: {size} != {}", header.file_bytes());
        }
        Ok(Datastore { header, path: path.to_path_buf() })
    }

    /// Number of checkpoint blocks in the store.
    pub fn n_checkpoints(&self) -> usize {
        self.header.n_checkpoints as usize
    }

    /// True when the store's header matches the given geometry exactly —
    /// the cache-reuse guard: a `run_dir` left over from a different
    /// corpus size, projection dim, checkpoint count or precision must be
    /// rebuilt, not silently served
    /// (`Pipeline::build_datastores` checks this before reusing a file).
    pub fn matches_geometry(
        &self,
        precision: Precision,
        n_samples: usize,
        k: usize,
        n_checkpoints: usize,
    ) -> bool {
        self.header.precision == precision
            && self.header.n_samples == n_samples as u64
            && self.header.k == k as u64
            && self.header.n_checkpoints == n_checkpoints as u32
    }

    /// Number of sample rows per checkpoint block.
    pub fn n_samples(&self) -> usize {
        self.header.n_samples as usize
    }

    /// Total file size implied by the header (validated at open).
    pub fn file_bytes(&self) -> u64 {
        self.header.file_bytes()
    }

    /// Resolve the effective rows-per-shard for a scan: an explicit
    /// `shard_rows` wins; otherwise the largest shard that fits
    /// `mem_budget_mb` of resident buffer. Always in `[1, n_samples]`.
    pub fn rows_per_shard(&self, shard_rows: usize, mem_budget_mb: usize) -> usize {
        let n = self.n_samples().max(1);
        if shard_rows > 0 {
            return shard_rows.min(n);
        }
        let budget = (mem_budget_mb.max(1) as u64) << 20;
        self.header.shard_rows_for_budget(budget)
    }

    /// Open a streaming reader over checkpoint `c`, yielding shards of at
    /// most `rows_per_shard` rows. Peak resident memory is the shard
    /// buffers (`rows_per_shard × (row_stride + 4)` bytes), not the block.
    pub fn shard_reader(&self, c: usize, rows_per_shard: usize) -> Result<ShardReader> {
        if c >= self.n_checkpoints() {
            bail!("checkpoint {c} out of range");
        }
        let mut file = File::open(&self.path)
            .with_context(|| format!("opening datastore {:?}", self.path))?;
        file.seek(SeekFrom::Start(self.header.block_offset(c)))?;
        let mut eta_b = [0u8; 4];
        file.read_exact(&mut eta_b)?;
        Ok(ShardReader {
            file,
            header: self.header,
            ckpt: c,
            eta: f32::from_le_bytes(eta_b),
            rows_per_shard: rows_per_shard.max(1),
            next_row: 0,
            scales: Vec::new(),
            data: Vec::new(),
        })
    }

    /// Load checkpoint block `c` into memory.
    pub fn load_checkpoint(&self, c: usize) -> Result<CheckpointBlock> {
        if c >= self.n_checkpoints() {
            bail!("checkpoint {c} out of range");
        }
        let h = &self.header;
        let mut f = BufReader::new(File::open(&self.path)?);
        let off = Header::BYTES as u64 + h.block_bytes() * c as u64;
        f.seek(SeekFrom::Start(off))?;
        let mut eta_b = [0u8; 4];
        f.read_exact(&mut eta_b)?;
        let n = h.n_samples as usize;
        let mut scales = Vec::new();
        if h.precision.bits != 16 {
            let mut sb = vec![0u8; 4 * n];
            f.read_exact(&mut sb)?;
            scales = sb
                .chunks(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
        }
        let mut data = vec![0u8; h.row_stride as usize * n];
        f.read_exact(&mut data)?;
        Ok(CheckpointBlock {
            precision: h.precision,
            n,
            k: h.k as usize,
            eta: f32::from_le_bytes(eta_b),
            scales,
            data,
            row_stride: h.row_stride as usize,
        })
    }
}

// ---------------------------------------------------------------------------
// streaming shard reader
// ---------------------------------------------------------------------------

/// One streamed shard: a contiguous row range `[start, start + rows.n())`
/// of one checkpoint, borrowing the reader's reusable buffers.
#[derive(Debug)]
pub struct Shard<'a> {
    /// Checkpoint index this shard belongs to.
    pub ckpt: usize,
    /// Global row index of the shard's first row.
    pub start: usize,
    /// The checkpoint's LR weight η.
    pub eta: f32,
    rows: RowsView<'a>,
}

impl<'a> Shard<'a> {
    /// The shard's rows as the scoring kernels' common view.
    pub fn rows(&self) -> RowsView<'a> {
        self.rows
    }

    /// Number of rows in the shard.
    pub fn len(&self) -> usize {
        self.rows.n()
    }

    /// True when the shard holds no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.n() == 0
    }

    /// Copy this borrowed shard into a self-contained [`OwnedShard`].
    /// The borrowed form aliases the reader's reusable buffers (overwritten
    /// by the next read); the owned form is what a shard cache can pin in
    /// RAM across scans. Same bytes, same [`RowsView`] decode — scores over
    /// either are bit-identical.
    pub fn to_owned_shard(&self) -> OwnedShard {
        OwnedShard {
            ckpt: self.ckpt,
            start: self.start,
            eta: self.eta,
            precision: self.rows.precision,
            k: self.rows.k,
            row_stride: self.rows.row_stride,
            scales: self.rows.scales.to_vec(),
            data: self.rows.data.to_vec(),
        }
    }
}

/// A self-contained copy of one shard — the unit the serving layer's
/// byte-budgeted cache pins in RAM so repeat scans skip the disk. Built by
/// [`Shard::to_owned_shard`]; hands out the same [`RowsView`] the streamed
/// and whole-block readers do, so cached scans stay bit-identical.
#[derive(Debug, Clone)]
pub struct OwnedShard {
    /// Checkpoint index this shard belongs to.
    pub ckpt: usize,
    /// Global row index of the shard's first row.
    pub start: usize,
    /// The checkpoint's LR weight η.
    pub eta: f32,
    /// Storage precision of the rows (bits + scheme).
    pub precision: Precision,
    /// Codes per row (the projection dimension).
    pub k: usize,
    /// Bytes per packed row.
    pub row_stride: usize,
    /// Per-row scales (empty at 16-bit).
    pub scales: Vec<f32>,
    /// Packed row data, `len() × row_stride` bytes.
    pub data: Vec<u8>,
}

impl OwnedShard {
    /// The shard's rows as the scoring kernels' common view.
    pub fn rows(&self) -> RowsView<'_> {
        RowsView {
            precision: self.precision,
            k: self.k,
            row_stride: self.row_stride,
            scales: &self.scales,
            data: &self.data,
        }
    }

    /// Number of rows in the shard.
    pub fn len(&self) -> usize {
        self.data.len() / self.row_stride
    }

    /// True when the shard holds no rows.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Heap bytes this shard pins — the weight a byte-budgeted cache
    /// charges for it (row bytes + scale bytes + the struct itself).
    pub fn byte_weight(&self) -> usize {
        self.data.len() + 4 * self.scales.len() + std::mem::size_of::<OwnedShard>()
    }
}

/// Streams one checkpoint's rows in fixed-size shards. Buffers are
/// allocated once at the shard size and reused, so a full scan's peak
/// allocation is `O(rows_per_shard × row_stride)` — the `--mem-budget-mb`
/// contract — instead of `O(n × row_stride)` like [`Datastore::load_checkpoint`].
pub struct ShardReader {
    file: File,
    header: Header,
    ckpt: usize,
    eta: f32,
    rows_per_shard: usize,
    next_row: usize,
    scales: Vec<f32>,
    data: Vec<u8>,
}

impl ShardReader {
    /// The checkpoint's LR weight η (read once at open).
    pub fn eta(&self) -> f32 {
        self.eta
    }

    /// Reposition the reader so the next [`Self::next_shard`] starts at
    /// global row `row` (clamped to the checkpoint's row count — seeking
    /// to or past the end makes `next_shard` return `None`). Every shard
    /// read seeks to its exact file offset anyway, so random access costs
    /// nothing extra; this is the hook the serving layer's shard cache
    /// uses to skip over ranges it already holds in RAM.
    pub fn seek_to_row(&mut self, row: usize) {
        self.next_row = row.min(self.header.n_samples as usize);
    }

    /// Rows per full shard (the final shard may be shorter).
    pub fn rows_per_shard(&self) -> usize {
        self.rows_per_shard
    }

    /// Peak resident buffer bytes this reader will ever hold.
    pub fn resident_bytes(&self) -> u64 {
        self.rows_per_shard as u64 * self.header.resident_row_bytes()
    }

    /// Read the next shard, or `None` when the checkpoint is exhausted.
    /// The returned shard borrows the reader's internal buffers.
    pub fn next_shard(&mut self) -> Result<Option<Shard<'_>>> {
        let n = self.header.n_samples as usize;
        if self.next_row >= n {
            return Ok(None);
        }
        let start = self.next_row;
        let rows = self.rows_per_shard.min(n - start);
        let h = &self.header;
        if h.precision.bits != 16 {
            // the row buffer doubles as the scale-read scratch (scales are
            // parsed out before the rows overwrite it), so peak resident
            // stays at the documented row_stride + 4 bytes per row
            self.file.seek(SeekFrom::Start(h.scales_offset(self.ckpt) + 4 * start as u64))?;
            self.data.resize(4 * rows, 0);
            self.file.read_exact(&mut self.data)?;
            self.scales.clear();
            self.scales.extend(
                self.data.chunks(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])),
            );
        }
        self.file.seek(SeekFrom::Start(h.row_offset(self.ckpt, start as u64)))?;
        self.data.resize(h.row_stride as usize * rows, 0);
        self.file.read_exact(&mut self.data)?;
        self.next_row = start + rows;
        Ok(Some(Shard {
            ckpt: self.ckpt,
            start,
            eta: self.eta,
            rows: RowsView {
                precision: h.precision,
                k: h.k as usize,
                row_stride: h.row_stride as usize,
                scales: &self.scales,
                data: &self.data,
            },
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Scheme;
    use crate::util::Rng;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "qless_ds_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn features(n: usize, k: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..k).map(|_| rng.normal() as f32).collect()).collect()
    }

    fn roundtrip(bits: u8, scheme: Scheme) {
        let dir = tmpdir();
        let path = dir.join(format!("ds_{bits}.qlds"));
        let (n, k, c) = (10usize, 96usize, 3usize);
        let p = Precision::new(bits, scheme).unwrap();
        let mut w = DatastoreWriter::create(&path, p, n, k, c).unwrap();
        let all: Vec<Vec<Vec<f32>>> = (0..c).map(|ci| features(n, k, ci as u64)).collect();
        for (ci, rows) in all.iter().enumerate() {
            w.begin_checkpoint(0.1 * (ci + 1) as f32).unwrap();
            for row in rows {
                w.append_features(row).unwrap();
            }
            w.end_checkpoint().unwrap();
        }
        let size = w.finalize().unwrap();
        let ds = Datastore::open(&path).unwrap();
        assert_eq!(ds.file_bytes(), size);
        assert_eq!(ds.n_samples(), n);
        assert_eq!(ds.n_checkpoints(), c);
        for ci in 0..c {
            let block = ds.load_checkpoint(ci).unwrap();
            assert!((block.eta - 0.1 * (ci + 1) as f32).abs() < 1e-7);
            for (i, orig) in all[ci].iter().enumerate() {
                let got = block.row_f32(i);
                if bits == 16 {
                    for (a, b) in orig.iter().zip(&got) {
                        assert!((a - b).abs() <= a.abs() / 128.0 + 1e-6, "bf16 {a} {b}");
                    }
                } else {
                    // must equal quantize→dequantize exactly
                    let q = quantize_row(orig, bits, p.scheme);
                    let want = crate::quant::dequantize_row(&q);
                    assert_eq!(got, want, "bits {bits} row {i}");
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_16bit() {
        roundtrip(16, Scheme::Absmax);
    }

    #[test]
    fn roundtrip_8bit() {
        roundtrip(8, Scheme::Absmax);
    }

    #[test]
    fn roundtrip_4bit_absmean() {
        roundtrip(4, Scheme::Absmean);
    }

    #[test]
    fn roundtrip_2bit() {
        roundtrip(2, Scheme::Absmax);
    }

    #[test]
    fn roundtrip_1bit() {
        roundtrip(1, Scheme::Sign);
    }

    #[test]
    fn storage_ratio_matches_paper() {
        // The whole point: 16-bit ≈ 16× the 1-bit file (paper Table 1).
        let dir = tmpdir();
        let (n, k, c) = (64usize, 512usize, 2usize);
        let mut sizes = std::collections::BTreeMap::new();
        for bits in [16u8, 8, 4, 2, 1] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            let path = dir.join(format!("r_{bits}.qlds"));
            let mut w = DatastoreWriter::create(&path, p, n, k, c).unwrap();
            let rows = features(n, k, 1);
            for ci in 0..c {
                w.begin_checkpoint(0.1 * ci as f32).unwrap();
                for row in &rows {
                    w.append_features(row).unwrap();
                }
                w.end_checkpoint().unwrap();
            }
            sizes.insert(bits, w.finalize().unwrap() as f64);
        }
        let r = sizes[&16] / sizes[&1];
        assert!(r > 14.0 && r <= 16.0, "16/1 ratio {r}");
        let r84 = sizes[&8] / sizes[&4];
        assert!(r84 > 1.8 && r84 < 2.1, "8/4 ratio {r84}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_rejects_non_finite_rows_at_every_bitwidth() {
        let dir = tmpdir();
        for bits in [16u8, 8, 4, 2, 1] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            let path = dir.join(format!("nan_{bits}.qlds"));
            let mut w = DatastoreWriter::create(&path, p, 2, 8, 1).unwrap();
            w.begin_checkpoint(1.0).unwrap();
            let mut row = [0.25f32; 8];
            row[3] = f32::NAN;
            let err = w.append_features(&row).unwrap_err();
            assert!(
                format!("{err:#}").contains("non-finite"),
                "{bits}-bit NaN not rejected: {err:#}"
            );
            row[3] = f32::INFINITY;
            assert!(w.append_features(&row).is_err(), "{bits}-bit Inf not rejected");
            // the pre-quantized path must reject a NaN scale too
            if bits != 16 {
                let q = QuantizedRow { codes: vec![0i8; 8], scale: f32::NAN };
                let err = w.append_quantized(&q).unwrap_err();
                assert!(format!("{err:#}").contains("non-finite"), "{bits}-bit: {err:#}");
            }
            // clean rows still accepted after a rejected one
            w.append_features(&[0.5; 8]).unwrap();
            w.append_features(&[-0.5; 8]).unwrap();
            w.end_checkpoint().unwrap();
            w.finalize().unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_enforces_protocol() {
        let dir = tmpdir();
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let path = dir.join("proto.qlds");
        let mut w = DatastoreWriter::create(&path, p, 2, 8, 1).unwrap();
        assert!(w.append_features(&[0.0; 8]).is_err()); // before begin
        w.begin_checkpoint(1.0).unwrap();
        assert!(w.begin_checkpoint(1.0).is_err()); // double begin
        w.append_features(&[0.0; 8]).unwrap();
        assert!(w.end_checkpoint().is_err()); // missing rows
        w.append_features(&[1.0; 8]).unwrap();
        assert!(w.append_features(&[1.0; 8]).is_err()); // too many
        w.end_checkpoint().unwrap();
        w.finalize().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_reader_matches_block_bytes() {
        // Streamed shards must reproduce the whole-block reader's bytes and
        // scales exactly, for every bitwidth and a shard size that does NOT
        // divide n (final short shard).
        let dir = tmpdir();
        let (n, k, c) = (13usize, 96usize, 2usize);
        for bits in [16u8, 8, 4, 2, 1] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            let path = dir.join(format!("shard_{bits}.qlds"));
            let mut w = DatastoreWriter::create(&path, p, n, k, c).unwrap();
            for ci in 0..c {
                w.begin_checkpoint(0.5 * (ci + 1) as f32).unwrap();
                for row in features(n, k, ci as u64) {
                    w.append_features(&row).unwrap();
                }
                w.end_checkpoint().unwrap();
            }
            w.finalize().unwrap();
            let ds = Datastore::open(&path).unwrap();
            for ci in 0..c {
                let block = ds.load_checkpoint(ci).unwrap();
                for shard_rows in [1usize, 4, 5, n, n + 3] {
                    let mut r = ds.shard_reader(ci, shard_rows).unwrap();
                    assert_eq!(r.eta(), block.eta, "{bits}-bit eta");
                    let mut seen = 0usize;
                    while let Some(shard) = r.next_shard().unwrap() {
                        assert_eq!(shard.start, seen);
                        assert_eq!(shard.ckpt, ci);
                        let rows = shard.rows();
                        for j in 0..rows.n() {
                            let g = shard.start + j;
                            assert_eq!(
                                rows.row_bytes(j),
                                block.row_bytes(g),
                                "{bits}-bit ckpt {ci} row {g} (shard_rows {shard_rows})"
                            );
                            if bits != 16 {
                                assert_eq!(rows.scales[j], block.scales[g]);
                            }
                        }
                        seen += rows.n();
                    }
                    assert_eq!(seen, n, "{bits}-bit shard_rows {shard_rows}");
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_reader_bounds_resident_memory() {
        let dir = tmpdir();
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let path = dir.join("budget.qlds");
        let (n, k) = (64usize, 128usize);
        let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
        w.begin_checkpoint(1.0).unwrap();
        for row in features(n, k, 0) {
            w.append_features(&row).unwrap();
        }
        w.end_checkpoint().unwrap();
        w.finalize().unwrap();
        let ds = Datastore::open(&path).unwrap();
        // budget for ~8 rows: (128 + 4) bytes/row resident
        let rows = ds.header.shard_rows_for_budget(8 * (128 + 4));
        assert_eq!(rows, 8);
        let mut r = ds.shard_reader(0, rows).unwrap();
        assert!(r.resident_bytes() <= 8 * (128 + 4));
        let mut shards = 0;
        while let Some(shard) = r.next_shard().unwrap() {
            assert!(shard.len() <= 8);
            // the reusable buffers never exceed the shard size
            shards += 1;
        }
        assert_eq!(shards, 8); // 64 rows / 8 per shard
        // explicit shard_rows wins over budget; both clamp to [1, n]
        assert_eq!(ds.rows_per_shard(13, 1), 13);
        assert_eq!(ds.rows_per_shard(10_000, 1), n);
        assert!(ds.rows_per_shard(0, 1) >= 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn seek_to_row_matches_sequential_reads() {
        // Random-access shard reads (the serving layer's cache-fill path)
        // must produce the same bytes as the sequential stream, at every
        // bitwidth, including a seek past the end (→ None) and re-seeks
        // backwards over already-read ranges.
        let dir = tmpdir();
        let (n, k) = (13usize, 96usize);
        for bits in [16u8, 8, 1] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            let path = dir.join(format!("seek_{bits}.qlds"));
            let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
            w.begin_checkpoint(0.25).unwrap();
            for row in features(n, k, 3) {
                w.append_features(&row).unwrap();
            }
            w.end_checkpoint().unwrap();
            w.finalize().unwrap();
            let ds = Datastore::open(&path).unwrap();
            let block = ds.load_checkpoint(0).unwrap();
            let shard_rows = 5usize;
            let n_shards = n.div_ceil(shard_rows);
            let mut r = ds.shard_reader(0, shard_rows).unwrap();
            // visit shards out of order: last, first, middle, first again
            for si in [n_shards - 1, 0, 1, 0] {
                r.seek_to_row(si * shard_rows);
                let shard = r.next_shard().unwrap().unwrap();
                assert_eq!(shard.start, si * shard_rows, "{bits}-bit shard {si}");
                let rows = shard.rows();
                for j in 0..rows.n() {
                    assert_eq!(rows.row_bytes(j), block.row_bytes(shard.start + j));
                    if bits != 16 {
                        assert_eq!(rows.scales[j], block.scales[shard.start + j]);
                    }
                }
            }
            r.seek_to_row(n);
            assert!(r.next_shard().unwrap().is_none(), "{bits}-bit: seek to end");
            r.seek_to_row(n + 100); // clamped
            assert!(r.next_shard().unwrap().is_none());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn owned_shard_preserves_bytes_and_geometry() {
        let dir = tmpdir();
        for bits in [16u8, 4] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            let path = dir.join(format!("owned_{bits}.qlds"));
            let (n, k) = (9usize, 64usize);
            let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
            w.begin_checkpoint(0.5).unwrap();
            for row in features(n, k, 4) {
                w.append_features(&row).unwrap();
            }
            w.end_checkpoint().unwrap();
            w.finalize().unwrap();
            let ds = Datastore::open(&path).unwrap();
            let mut r = ds.shard_reader(0, 4).unwrap();
            let mut seen = 0usize;
            while let Some(shard) = r.next_shard().unwrap() {
                let owned = shard.to_owned_shard();
                assert_eq!(owned.ckpt, shard.ckpt);
                assert_eq!(owned.start, shard.start);
                assert_eq!(owned.eta, shard.eta);
                assert_eq!(owned.len(), shard.len());
                assert!(!owned.is_empty());
                let (a, b) = (shard.rows(), owned.rows());
                assert_eq!(a.data, &owned.data[..]);
                for j in 0..a.n() {
                    assert_eq!(a.row_bytes(j), b.row_bytes(j), "{bits}-bit row {j}");
                }
                assert!(owned.byte_weight() >= owned.data.len());
                seen += owned.len();
            }
            assert_eq!(seen, n);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn window_size_does_not_change_bytes() {
        // The staged-window flush cadence is invisible on disk: every
        // window size (including 1 and one that doesn't divide n) must
        // produce the exact bytes of the single-flush path.
        let dir = tmpdir();
        let (n, k, c) = (13usize, 96usize, 2usize);
        for bits in [16u8, 8, 4, 2, 1] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            let write = |path: &Path, window: Option<usize>| -> Vec<u8> {
                let mut w = DatastoreWriter::create(path, p, n, k, c).unwrap();
                if let Some(win) = window {
                    w.set_window_rows(win);
                }
                for ci in 0..c {
                    w.begin_checkpoint(0.3 * (ci + 1) as f32).unwrap();
                    for row in features(n, k, ci as u64) {
                        w.append_features(&row).unwrap();
                    }
                    w.end_checkpoint().unwrap();
                }
                w.finalize().unwrap();
                std::fs::read(path).unwrap()
            };
            let reference = write(&dir.join(format!("win_ref_{bits}.qlds")), None);
            for win in [1usize, 4, 5, n, n + 9] {
                let got = write(&dir.join(format!("win_{bits}_{win}.qlds")), Some(win));
                assert_eq!(got, reference, "{bits}-bit window {win}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_window_append_matches_per_row_path() {
        let dir = tmpdir();
        let (n, k, c) = (11usize, 64usize, 2usize);
        for bits in [16u8, 8, 4, 1] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            let per_row = dir.join(format!("pw_row_{bits}.qlds"));
            let mut w = DatastoreWriter::create(&per_row, p, n, k, c).unwrap();
            for ci in 0..c {
                w.begin_checkpoint(0.2 * (ci + 1) as f32).unwrap();
                for row in features(n, k, ci as u64) {
                    w.append_features(&row).unwrap();
                }
                w.end_checkpoint().unwrap();
            }
            w.finalize().unwrap();

            // same rows through the pre-quantized window path, split into
            // two uneven windows per checkpoint
            let windowed = dir.join(format!("pw_win_{bits}.qlds"));
            let mut w = DatastoreWriter::create(&windowed, p, n, k, c).unwrap();
            for ci in 0..c {
                w.begin_checkpoint(0.2 * (ci + 1) as f32).unwrap();
                let rows: Vec<f32> =
                    features(n, k, ci as u64).into_iter().flatten().collect();
                let (mut bytes, mut scales) = (Vec::new(), Vec::new());
                for (lo, hi) in [(0usize, 7usize), (7, n)] {
                    crate::quant::batch::quantize_rows_into(
                        &rows[lo * k..hi * k],
                        k,
                        p,
                        &mut bytes,
                        &mut scales,
                        0,
                    )
                    .unwrap();
                    w.append_packed_window(&scales, &bytes).unwrap();
                }
                w.end_checkpoint().unwrap();
            }
            w.finalize().unwrap();
            assert_eq!(
                std::fs::read(&per_row).unwrap(),
                std::fs::read(&windowed).unwrap(),
                "{bits}-bit"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_window_validates_shape() {
        let dir = tmpdir();
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let path = dir.join("pw_shape.qlds");
        let mut w = DatastoreWriter::create(&path, p, 4, 8, 1).unwrap();
        assert!(w.append_packed_window(&[1.0], &[0u8; 8]).is_err()); // before begin
        w.begin_checkpoint(1.0).unwrap();
        assert!(w.append_packed_window(&[1.0], &[0u8; 9]).is_err()); // ragged bytes
        assert!(w.append_packed_window(&[1.0, 1.0], &[0u8; 8]).is_err()); // scale count
        assert!(w.append_packed_window(&[1.0; 5], &[0u8; 40]).is_err()); // too many rows
        w.append_packed_window(&[1.0; 4], &[7u8; 32]).unwrap();
        w.end_checkpoint().unwrap();
        w.finalize().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn geometry_match_guards_cache_reuse() {
        let dir = tmpdir();
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let path = dir.join("geom.qlds");
        let (n, k, c) = (6usize, 32usize, 2usize);
        let mut w = DatastoreWriter::create(&path, p, n, k, c).unwrap();
        for ci in 0..c {
            w.begin_checkpoint(1.0).unwrap();
            for row in features(n, k, ci as u64) {
                w.append_features(&row).unwrap();
            }
            w.end_checkpoint().unwrap();
        }
        w.finalize().unwrap();
        let ds = Datastore::open(&path).unwrap();
        assert!(ds.matches_geometry(p, n, k, c));
        assert!(!ds.matches_geometry(p, n + 1, k, c)); // stale corpus size
        assert!(!ds.matches_geometry(p, n, k * 2, c)); // different projection
        assert!(!ds.matches_geometry(p, n, k, c + 1)); // checkpoint count
        let p2 = Precision::new(4, Scheme::Absmean).unwrap();
        assert!(!ds.matches_geometry(p2, n, k, c)); // scheme mismatch
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_rejects_truncated() {
        let dir = tmpdir();
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let path = dir.join("trunc.qlds");
        let mut w = DatastoreWriter::create(&path, p, 2, 8, 1).unwrap();
        w.begin_checkpoint(1.0).unwrap();
        w.append_features(&[0.0; 8]).unwrap();
        w.append_features(&[0.0; 8]).unwrap();
        w.end_checkpoint().unwrap();
        w.finalize().unwrap();
        // chop the file
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(Datastore::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    use crate::quant::scheme::quantize_row;
}
