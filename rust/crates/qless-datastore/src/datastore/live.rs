//! Live (append-only) datastores: base file + ingested segments, stitched
//! by the generation [`Manifest`].
//!
//! The on-disk format interleaves checkpoint blocks, so rows can never be
//! appended to an existing file without rewriting every later block —
//! exactly the bytes an append must *not* touch. Ingest therefore appends
//! **segment files**: each generation writes one fully self-contained
//! datastore per precision (same precision/k/checkpoint geometry, same
//! per-block η, its own row count) next to the base file, then bumps the
//! manifest. Pre-existing bytes are never modified (digest-verified in
//! `tests/ingest.rs`), and append-safety holds trivially at every
//! bitwidth: a segment's packed rows start at byte 0 of its own row
//! section, so the sub-byte code layout of earlier rows cannot shift.
//!
//! * [`LiveStore`] — the read side: base + segments as one logical row
//!   space `0..n_rows()`, refreshable in place when the generation bumps
//!   (new members are *appended*; existing members, and anything cached
//!   against them, stay valid).
//! * [`SegmentWriter`] — the write side: the ingest mechanics (tmp files →
//!   rename → manifest bump) around a [`MultiWriter`], minus feature
//!   extraction, so tests and embedders can drive it with any row source.
//! * [`repair_run_dir`] — crash recovery: roll the manifest back to its
//!   last fully-valid prefix and delete half-written tails, so a crash
//!   mid-append is *rebuilt*, never served.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::manifest::{Manifest, SegmentMeta};
use super::multi::MultiWriter;
use super::store::Datastore;
use super::{default_store_path, Header};
use crate::quant::{Precision, Scheme};

/// Path of generation `generation`'s segment file next to `base` —
/// `<stem>.g<generation>.qlds` (e.g. `datastore_4b_absmax.g2.qlds`).
pub fn segment_store_path(base: &Path, generation: u64) -> PathBuf {
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("datastore");
    base.with_file_name(format!("{stem}.g{generation}.qlds"))
}

/// Precisions that have a **default-named base store** in `run_dir`
/// (`datastore_<bits>b_<scheme>.qlds`; segment files and temp leftovers
/// are not bases) — the set the directory's shared manifest describes.
/// Ingest must cover all of them ([`SegmentWriter::create`] enforces it),
/// and crash repair validates against them, so operating on a precision
/// *subset* can never truncate generations that are intact for the
/// precisions that actually ingested.
pub fn run_dir_precisions(run_dir: &Path) -> Result<Vec<Precision>> {
    let mut found: Vec<Precision> = Vec::new();
    let entries = match std::fs::read_dir(run_dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(found),
        Err(e) => return Err(e).with_context(|| format!("listing {run_dir:?}")),
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(rest) = name.strip_prefix("datastore_") else { continue };
        let Some(rest) = rest.strip_suffix(".qlds") else { continue };
        if rest.contains('.') {
            continue; // `<stem>.g<N>.qlds` segments are not bases
        }
        let Some((bits_s, scheme_s)) = rest.split_once("b_") else { continue };
        let (Ok(bits), Ok(scheme)) = (bits_s.parse::<u8>(), scheme_s.parse::<Scheme>()) else {
            continue;
        };
        if let Ok(p) = Precision::new(bits, scheme) {
            // a coerced scheme (16-bit absmean → absmax) wouldn't round-trip
            // to this file name; only canonical names are run members
            if p.scheme == scheme && !found.contains(&p) {
                found.push(p);
            }
        }
    }
    found.sort_by_key(|p| (p.bits, p.label()));
    Ok(found)
}

/// One member of a live store: the base file (generation 0) or an
/// ingested segment, with its global row offset.
pub struct LiveMember {
    /// Global row index of this member's first row.
    pub start_row: usize,
    /// Generation that wrote this member (0 = the base build).
    pub generation: u64,
    /// The member's own validated datastore file.
    pub ds: Datastore,
}

/// A generation-aware view over one precision's base datastore plus every
/// ingested segment (see the module docs). Opened from the base file's
/// path; the manifest is found next to it.
pub struct LiveStore {
    base_path: PathBuf,
    members: Vec<LiveMember>,
    etas: Vec<f32>,
    generation: u64,
}

impl LiveStore {
    /// Open the base datastore at `path` and attach every segment its
    /// directory's manifest lists. With no manifest this is a frozen
    /// generation-0 store. A manifest that lists missing, truncated or
    /// geometry-mismatched segments is an **error** — a half-ingested run
    /// directory must be repaired ([`repair_run_dir`]), not silently
    /// served short.
    pub fn open(path: &Path) -> Result<LiveStore> {
        let ds = Datastore::open(path)?;
        let mut etas = Vec::with_capacity(ds.n_checkpoints());
        for ci in 0..ds.n_checkpoints() {
            etas.push(ds.shard_reader(ci, 1)?.eta());
        }
        let mut live = LiveStore {
            base_path: path.to_path_buf(),
            members: vec![LiveMember { start_row: 0, generation: 0, ds }],
            etas,
            generation: 0,
        };
        live.refresh()?;
        Ok(live)
    }

    /// Re-read the manifest and attach any newly ingested segments **in
    /// place**: existing members never move or reload, so shard caches
    /// keyed by member index stay valid across a reload. Returns `true`
    /// when the generation advanced. History rewrites (a manifest whose
    /// prefix no longer matches the members already attached) and missing
    /// or mismatched segment files are errors — and they leave the store
    /// exactly as it was (new members are staged and committed only after
    /// the whole manifest validates), so a caller that downgrades the
    /// error keeps serving a consistent generation.
    ///
    /// The manifest binds to the run directory's **default-named** stores
    /// (`datastore_<bits>b_<scheme>.qlds` — segment files derive from
    /// that stem). A base file under any other name is always served
    /// frozen at generation 0, never cross-wired to a manifest that
    /// describes different files.
    pub fn refresh(&mut self) -> Result<bool> {
        let dir = match self.base_path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
            _ => PathBuf::from("."),
        };
        let h = self.members[0].ds.header;
        let expected = default_store_path(&dir, h.precision);
        if self.base_path.file_name() != expected.file_name() {
            return Ok(false);
        }
        let Some(m) = Manifest::load(&dir)? else {
            return Ok(false);
        };
        if m.k != h.k || m.n_checkpoints != h.n_checkpoints || m.base_rows != h.n_samples {
            bail!(
                "manifest in {dir:?} (k={}, {} checkpoints, {} base rows) does not describe \
                 the served base store (k={}, {} checkpoints, {} rows)",
                m.k,
                m.n_checkpoints,
                m.base_rows,
                h.k,
                h.n_checkpoints,
                h.n_samples
            );
        }
        if m.generation < self.generation {
            bail!(
                "manifest generation went backwards ({} -> {}): refusing to un-serve rows",
                self.generation,
                m.generation
            );
        }
        if m.segments.len() + 1 < self.members.len() {
            bail!("manifest dropped segments this store already serves");
        }
        // stage new members; commit only after every segment validates,
        // so an error cannot leave a half-advanced store behind
        let mut staged: Vec<LiveMember> = Vec::new();
        let mut next_row = self.n_rows();
        for (i, seg) in m.segments.iter().enumerate() {
            if let Some(have) = self.members.get(i + 1) {
                if have.generation != seg.generation
                    || have.start_row != seg.start_row as usize
                    || have.ds.n_samples() as u64 != seg.rows
                {
                    bail!(
                        "manifest rewrote history at segment {i} (generation {})",
                        seg.generation
                    );
                }
                continue;
            }
            let path = segment_store_path(&self.base_path, seg.generation);
            let ds = Datastore::open(&path).with_context(|| {
                format!("opening ingested segment (generation {})", seg.generation)
            })?;
            let sh = ds.header;
            if sh.precision != h.precision || sh.k != h.k || sh.n_checkpoints != h.n_checkpoints
            {
                bail!(
                    "segment {path:?} geometry ({}, k={}, {} checkpoints) does not match the \
                     base store ({}, k={}, {} checkpoints)",
                    sh.precision.label(),
                    sh.k,
                    sh.n_checkpoints,
                    h.precision.label(),
                    h.k,
                    h.n_checkpoints
                );
            }
            if ds.n_samples() as u64 != seg.rows {
                bail!(
                    "segment {path:?} holds {} rows, manifest says {}",
                    ds.n_samples(),
                    seg.rows
                );
            }
            if seg.start_row as usize != next_row {
                bail!(
                    "segment {path:?} starts at row {}, expected {next_row}",
                    seg.start_row
                );
            }
            // η parity: Eq. 7's checkpoint weights must be identical in
            // every member, or scores would mix different training runs
            for (ci, &eta) in self.etas.iter().enumerate() {
                let got = ds.shard_reader(ci, 1)?.eta();
                if got.to_bits() != eta.to_bits() {
                    bail!(
                        "segment {path:?} checkpoint {ci} has η {got}, base store has {eta}"
                    );
                }
            }
            let start_row = seg.start_row as usize;
            next_row += ds.n_samples();
            staged.push(LiveMember { start_row, generation: seg.generation, ds });
        }
        self.members.append(&mut staged);
        let advanced = m.generation > self.generation;
        self.generation = m.generation;
        Ok(advanced)
    }

    /// The base store's header. Geometry fields (`precision`, `k`,
    /// `n_checkpoints`, `row_stride`) hold for every member; `n_samples`
    /// is the **base** row count only — use [`LiveStore::n_rows`] for the
    /// live total.
    pub fn header(&self) -> &Header {
        &self.members[0].ds.header
    }

    /// The manifest generation currently attached (0 = frozen base).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Total rows across the base and every attached segment.
    pub fn n_rows(&self) -> usize {
        self.members.iter().map(|m| m.ds.n_samples()).sum()
    }

    /// The member stores in row order (base first, then segments by
    /// ascending generation).
    pub fn members(&self) -> &[LiveMember] {
        &self.members
    }

    /// Per-checkpoint η weights (identical in every member by
    /// construction; validated on attach).
    pub fn etas(&self) -> &[f32] {
        &self.etas
    }

    /// First global row strictly newer than `generation`; `n_rows()` when
    /// nothing is newer. The `since_gen` wire filter resolves through
    /// this.
    pub fn first_row_after(&self, generation: u64) -> usize {
        self.members
            .iter()
            .filter(|m| m.generation > generation)
            .map(|m| m.start_row)
            .min()
            .unwrap_or_else(|| self.n_rows())
    }

    /// True when `row` is a member (generation) boundary or the end of the
    /// store — the only places an incremental tail scan may start.
    pub fn is_generation_boundary(&self, row: usize) -> bool {
        row == self.n_rows() || self.members.iter().any(|m| m.start_row == row)
    }

    /// Generation-aware cache-reuse guard (the live form of
    /// [`Datastore::matches_geometry`]): precision, `k` and checkpoint
    /// count from the base header, plus the **live row total** — so a run
    /// directory whose manifest claims rows a crash never delivered (or
    /// that belongs to a different corpus size) is rebuilt, not served.
    pub fn matches_geometry(
        &self,
        precision: Precision,
        n_total: usize,
        k: usize,
        n_checkpoints: usize,
    ) -> bool {
        let h = self.members[0].ds.header;
        h.precision == precision
            && h.k == k as u64
            && h.n_checkpoints == n_checkpoints as u32
            && self.n_rows() == n_total
    }

    /// Resolve the effective rows-per-shard for scans over this store
    /// (same contract as [`Datastore::rows_per_shard`], applied uniformly
    /// to every member).
    pub fn rows_per_shard(&self, shard_rows: usize, mem_budget_mb: usize) -> usize {
        self.members[0].ds.rows_per_shard(shard_rows, mem_budget_mb)
    }
}

// ---------------------------------------------------------------------------
// ingest write side
// ---------------------------------------------------------------------------

/// Appends one generation's segment to a run directory's datastores: a
/// [`MultiWriter`] over per-precision **temp files**, renamed into place
/// and published by a manifest bump only at [`SegmentWriter::finalize`] —
/// so a crash at any earlier point leaves the previous generation fully
/// intact (the leftovers are orphans [`repair_run_dir`] removes).
///
/// Per-block η weights are taken from the base stores (and must agree
/// across precisions): segments are forced to share the base's checkpoint
/// weighting, which is what keeps Eq. 7 well-defined over the combined
/// row space. Drive it like a [`MultiWriter`], one checkpoint at a time:
/// `begin_checkpoint` / [`SegmentWriter::append_rows`]× /
/// `end_checkpoint`, then `finalize`.
pub struct SegmentWriter {
    dir: PathBuf,
    manifest: Manifest,
    generation: u64,
    rows: usize,
    etas: Vec<f32>,
    next_ckpt: usize,
    tmps: Vec<PathBuf>,
    finals: Vec<PathBuf>,
    mw: MultiWriter,
}

impl SegmentWriter {
    /// Open the run directory's base stores for every precision, validate
    /// their shared geometry against the manifest (created at generation 0
    /// if absent), and stage the next generation's segment files. `rows`
    /// is the number of new rows this segment will hold; `workers` caps
    /// the quantize-stage parallelism (0 = full pool width).
    ///
    /// Call [`repair_run_dir`] first when the directory may hold a crashed
    /// ingest — this constructor trusts the manifest's existing segments.
    pub fn create(
        run_dir: &Path,
        precisions: &[Precision],
        rows: usize,
        workers: usize,
    ) -> Result<SegmentWriter> {
        if rows == 0 {
            bail!("ingest segment needs at least one row");
        }
        if precisions.is_empty() {
            bail!("ingest needs at least one precision");
        }
        // the manifest is shared by every precision of the run, so a
        // generation must append to ALL of them — a subset ingest would
        // leave the uncovered precisions torn by construction
        for p in run_dir_precisions(run_dir)? {
            if !precisions.contains(&p) {
                bail!(
                    "run dir {run_dir:?} also holds a {} base store: ingest must append to \
                     every precision of the run in one pass (add it to --bits)",
                    p.label()
                );
            }
        }
        let mut bases: Vec<(Precision, PathBuf, Datastore)> = Vec::with_capacity(precisions.len());
        for &p in precisions {
            let path = default_store_path(run_dir, p);
            let ds = Datastore::open(&path).with_context(|| {
                format!("ingest needs an existing {} base datastore", p.label())
            })?;
            if ds.header.precision != p {
                bail!("{path:?} stores {}, expected {}", ds.header.precision.label(), p.label());
            }
            bases.push((p, path, ds));
        }
        let h0 = bases[0].2.header;
        let (k, c, n_base) = (h0.k as usize, h0.n_checkpoints as usize, h0.n_samples as usize);
        let mut etas = Vec::with_capacity(c);
        for ci in 0..c {
            etas.push(bases[0].2.shard_reader(ci, 1)?.eta());
        }
        for (p, path, ds) in &bases[1..] {
            if !ds.matches_geometry(*p, n_base, k, c) {
                bail!(
                    "{path:?} geometry does not match the run's other base stores \
                     (expected {n_base} rows × k={k} × {c} checkpoints)"
                );
            }
            for (ci, &eta) in etas.iter().enumerate() {
                let got = ds.shard_reader(ci, 1)?.eta();
                if got.to_bits() != eta.to_bits() {
                    bail!("{path:?} checkpoint {ci} has η {got}, expected {eta}");
                }
            }
        }
        let manifest = match Manifest::load(run_dir)? {
            Some(m) => {
                if m.k != k as u64 || m.n_checkpoints != c as u32 || m.base_rows != n_base as u64
                {
                    bail!(
                        "manifest in {run_dir:?} does not match the base stores \
                         ({n_base} rows × k={k} × {c} checkpoints) — rebuild before ingesting"
                    );
                }
                m
            }
            None => Manifest::new(k, c, n_base),
        };
        let generation = manifest.generation + 1;
        let mut tmps = Vec::with_capacity(bases.len());
        let mut finals = Vec::with_capacity(bases.len());
        let mut targets = Vec::with_capacity(bases.len());
        for (p, base_path, _) in &bases {
            let fin = segment_store_path(base_path, generation);
            let tmp = fin.with_extension("qlds.tmp");
            // stale leftovers from a crashed attempt at this generation
            let _ = std::fs::remove_file(&fin);
            let _ = std::fs::remove_file(&tmp);
            targets.push((*p, tmp.clone()));
            tmps.push(tmp);
            finals.push(fin);
        }
        let mw = MultiWriter::create(&targets, rows, k, c, workers)?;
        Ok(SegmentWriter {
            dir: run_dir.to_path_buf(),
            manifest,
            generation,
            rows,
            etas,
            next_ckpt: 0,
            tmps,
            finals,
            mw,
        })
    }

    /// The generation this writer will publish.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Global row index the segment's first row will get.
    pub fn start_row(&self) -> usize {
        self.manifest.total_rows() as usize
    }

    /// The base stores' per-checkpoint η weights (the segment reuses them
    /// verbatim).
    pub fn etas(&self) -> &[f32] {
        &self.etas
    }

    /// Start the next checkpoint block in every member, with the base
    /// store's η for that checkpoint.
    pub fn begin_checkpoint(&mut self) -> Result<()> {
        let Some(&eta) = self.etas.get(self.next_ckpt) else {
            bail!("segment already holds all {} checkpoints", self.etas.len());
        };
        self.mw.begin_checkpoint(eta)
    }

    /// Append a window of `rows.len() / k` feature rows (in row order) to
    /// the current checkpoint, quantized at every target precision.
    pub fn append_rows(&mut self, rows: &[f32]) -> Result<()> {
        self.mw.append_rows(rows)
    }

    /// Finish the current checkpoint block in every member.
    pub fn end_checkpoint(&mut self) -> Result<()> {
        self.mw.end_checkpoint()?;
        self.next_ckpt += 1;
        Ok(())
    }

    /// Peak builder-resident bytes so far (see
    /// [`MultiWriter::peak_builder_bytes`]).
    pub fn peak_builder_bytes(&self) -> u64 {
        self.mw.peak_builder_bytes()
    }

    /// Validate and publish the segment: finalize every temp file, rename
    /// into place, bump the manifest and save it atomically. Returns the
    /// new segment's metadata, the updated manifest, and the per-precision
    /// segment file sizes (creation order).
    pub fn finalize(mut self) -> Result<(SegmentMeta, Manifest, Vec<u64>)> {
        let sizes = self.mw.finalize()?;
        for (tmp, fin) in self.tmps.iter().zip(&self.finals) {
            std::fs::rename(tmp, fin)
                .with_context(|| format!("publishing segment {fin:?}"))?;
        }
        let seg = self.manifest.push_segment(self.rows as u64);
        self.manifest.save(&self.dir)?;
        Ok((seg, self.manifest, sizes))
    }
}

// ---------------------------------------------------------------------------
// crash repair
// ---------------------------------------------------------------------------

/// Roll a run directory back to its last fully-valid generation: keep the
/// longest manifest prefix whose segment files open cleanly — with the
/// right geometry and row count — for **every precision that has a base
/// store in the directory** ([`run_dir_precisions`], not merely the
/// caller's subset, so repairing one precision can never truncate
/// generations that are intact for the precisions that actually
/// ingested); truncate the manifest there, and delete every orphan —
/// segment files newer than the kept generation and any `.qlds.tmp`
/// leftovers. Returns the (possibly repaired) manifest, or `None` when
/// the directory has none.
///
/// This is what makes a crash mid-append *rebuildable*: the next ingest
/// re-appends from the repaired row count instead of serving a torn tail.
pub fn repair_run_dir(run_dir: &Path, precisions: &[Precision]) -> Result<Option<Manifest>> {
    // validate against the precisions actually present; clean orphans for
    // the union with the caller's (a caller precision with no base may
    // still have tmp leftovers from a crashed first ingest)
    let members = run_dir_precisions(run_dir)?;
    let mut sweep = members.clone();
    for &p in precisions {
        if !sweep.contains(&p) {
            sweep.push(p);
        }
    }
    let loaded = Manifest::load(run_dir)?;
    let last_gen = match &loaded {
        Some(m) => {
            let mut keep = 0usize;
            'segments: for seg in &m.segments {
                for &p in &members {
                    let base = default_store_path(run_dir, p);
                    let path = segment_store_path(&base, seg.generation);
                    let ok = match Datastore::open(&path) {
                        Ok(ds) => {
                            ds.header.precision == p
                                && ds.header.k == m.k
                                && ds.header.n_checkpoints == m.n_checkpoints
                                && ds.n_samples() as u64 == seg.rows
                        }
                        Err(_) => false,
                    };
                    if !ok {
                        break 'segments;
                    }
                }
                keep += 1;
            }
            if keep < m.segments.len() {
                let mut repaired = m.clone();
                repaired.truncate_segments(keep);
                repaired.save(run_dir)?;
                let gen = repaired.generation;
                remove_orphans(run_dir, &sweep, gen)?;
                return Ok(Some(repaired));
            }
            m.generation
        }
        None => 0,
    };
    remove_orphans(run_dir, &sweep, last_gen)?;
    Ok(loaded)
}

/// Delete segment files newer than `last_gen` and all `.qlds.tmp`
/// leftovers for the given precisions in `run_dir`.
fn remove_orphans(run_dir: &Path, precisions: &[Precision], last_gen: u64) -> Result<()> {
    let entries = match std::fs::read_dir(run_dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("listing {run_dir:?}")),
    };
    let prefixes: Vec<String> = precisions
        .iter()
        .filter_map(|&p| {
            let base = default_store_path(run_dir, p);
            let stem = base.file_stem()?.to_str()?.to_string();
            Some(format!("{stem}.g"))
        })
        .collect();
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(prefix) = prefixes.iter().find(|pre| name.starts_with(pre.as_str())) else {
            continue;
        };
        let rest = &name[prefix.len()..];
        let (gen_str, is_tmp) = if let Some(g) = rest.strip_suffix(".qlds.tmp") {
            (g, true)
        } else if let Some(g) = rest.strip_suffix(".qlds") {
            (g, false)
        } else {
            continue;
        };
        let Ok(gen) = gen_str.parse::<u64>() else { continue };
        if is_tmp || gen > last_gen {
            crate::info!("removing orphaned segment file {name} (crash mid-append)");
            std::fs::remove_file(entry.path())
                .with_context(|| format!("removing orphan {name}"))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Scheme;
    use crate::util::prop::{normal_features, seeded_datastore};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "qless_live_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn p4() -> Precision {
        Precision::new(4, Scheme::Absmax).unwrap()
    }

    /// Build a base store in `dir` and ingest one segment of `add` rows
    /// through the SegmentWriter, streaming `normal_features(add, k, seed
    /// + 100·gen + ci)` per checkpoint.
    fn ingest_once(dir: &Path, p: Precision, add: usize, k: usize, etas: &[f32], seed: u64) {
        let mut sw = SegmentWriter::create(dir, &[p], add, 0).unwrap();
        let gen = sw.generation();
        for ci in 0..etas.len() {
            sw.begin_checkpoint().unwrap();
            let f = normal_features(add, k, seed + 100 * gen + ci as u64);
            sw.append_rows(&f.data).unwrap();
            sw.end_checkpoint().unwrap();
        }
        sw.finalize().unwrap();
    }

    #[test]
    fn segment_paths_derive_from_base() {
        let p = segment_store_path(Path::new("/runs/x/datastore_4b_absmax.qlds"), 3);
        assert_eq!(p, Path::new("/runs/x/datastore_4b_absmax.g3.qlds"));
    }

    #[test]
    fn open_refresh_and_boundaries() {
        let dir = tmpdir("open");
        let (n, k) = (10usize, 32usize);
        let etas = [0.5f32, 0.25];
        let base = default_store_path(&dir, p4());
        seeded_datastore(&base, p4(), n, k, &etas, 7);

        // frozen store: generation 0, one member
        let mut live = LiveStore::open(&base).unwrap();
        assert_eq!(live.generation(), 0);
        assert_eq!(live.n_rows(), n);
        assert_eq!(live.members().len(), 1);
        assert_eq!(live.etas(), &etas);

        // ingest 4 rows, then 3 more: refresh attaches in place
        ingest_once(&dir, p4(), 4, k, &etas, 7);
        assert!(live.refresh().unwrap());
        assert_eq!(live.generation(), 1);
        assert_eq!(live.n_rows(), n + 4);
        ingest_once(&dir, p4(), 3, k, &etas, 7);
        assert!(live.refresh().unwrap());
        assert!(!live.refresh().unwrap(), "no change: refresh is a no-op");
        assert_eq!(live.generation(), 2);
        assert_eq!(live.n_rows(), n + 7);
        assert_eq!(live.members().len(), 3);
        assert_eq!(live.members()[1].start_row, n);
        assert_eq!(live.members()[2].start_row, n + 4);

        assert_eq!(live.first_row_after(0), n);
        assert_eq!(live.first_row_after(1), n + 4);
        assert_eq!(live.first_row_after(2), n + 7);
        assert!(live.is_generation_boundary(0));
        assert!(live.is_generation_boundary(n));
        assert!(live.is_generation_boundary(n + 7));
        assert!(!live.is_generation_boundary(1));

        assert!(live.matches_geometry(p4(), n + 7, k, etas.len()));
        assert!(!live.matches_geometry(p4(), n, k, etas.len()), "row total is live");

        // a fresh open sees the same world
        let reopened = LiveStore::open(&base).unwrap();
        assert_eq!(reopened.generation(), 2);
        assert_eq!(reopened.n_rows(), n + 7);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_detected_and_repaired() {
        let dir = tmpdir("repair");
        let (n, k) = (8usize, 32usize);
        let etas = [1.0f32];
        let base = default_store_path(&dir, p4());
        seeded_datastore(&base, p4(), n, k, &etas, 3);
        ingest_once(&dir, p4(), 4, k, &etas, 3);
        ingest_once(&dir, p4(), 5, k, &etas, 3);

        // simulate a crash that corrupted the generation-2 segment
        let seg2 = segment_store_path(&base, 2);
        let bytes = std::fs::read(&seg2).unwrap();
        std::fs::write(&seg2, &bytes[..bytes.len() - 3]).unwrap();
        assert!(LiveStore::open(&base).is_err(), "torn tail must not be served");

        let m = repair_run_dir(&dir, &[p4()]).unwrap().unwrap();
        assert_eq!(m.generation, 1);
        assert_eq!(m.total_rows(), (n + 4) as u64);
        assert!(!seg2.exists(), "torn segment deleted");
        let live = LiveStore::open(&base).unwrap();
        assert_eq!(live.generation(), 1);
        assert_eq!(live.n_rows(), n + 4);

        // the tail can now be re-ingested (generation number reused)
        ingest_once(&dir, p4(), 5, k, &etas, 3);
        let live = LiveStore::open(&base).unwrap();
        assert_eq!(live.generation(), 2);
        assert_eq!(live.n_rows(), n + 9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn repair_removes_unlisted_orphans_and_tmp_files() {
        let dir = tmpdir("orphans");
        let (n, k) = (6usize, 32usize);
        let base = default_store_path(&dir, p4());
        seeded_datastore(&base, p4(), n, k, &[1.0], 9);
        // a segment file the manifest never published + a tmp leftover
        let orphan = segment_store_path(&base, 1);
        std::fs::write(&orphan, b"half-written").unwrap();
        std::fs::write(orphan.with_extension("qlds.tmp"), b"tmp").unwrap();
        assert!(repair_run_dir(&dir, &[p4()]).unwrap().is_none(), "no manifest");
        assert!(!orphan.exists());
        assert!(!orphan.with_extension("qlds.tmp").exists());
        // the base store itself is untouched
        LiveStore::open(&base).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_writer_enforces_protocol() {
        let dir = tmpdir("proto");
        let (n, k) = (5usize, 16usize);
        let base = default_store_path(&dir, p4());
        seeded_datastore(&base, p4(), n, k, &[0.5, 0.5], 1);
        assert!(SegmentWriter::create(&dir, &[p4()], 0, 0).is_err(), "zero rows");
        assert!(SegmentWriter::create(&dir, &[], 2, 0).is_err(), "no precisions");
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        assert!(SegmentWriter::create(&dir, &[p8], 2, 0).is_err(), "missing base");

        let mut sw = SegmentWriter::create(&dir, &[p4()], 2, 0).unwrap();
        assert_eq!(sw.generation(), 1);
        assert_eq!(sw.start_row(), n);
        assert_eq!(sw.etas().len(), 2);
        for _ in 0..2 {
            sw.begin_checkpoint().unwrap();
            sw.append_rows(&normal_features(2, k, 50).data).unwrap();
            sw.end_checkpoint().unwrap();
        }
        assert!(sw.begin_checkpoint().is_err(), "all checkpoints written");
        let (seg, m, sizes) = sw.finalize().unwrap();
        assert_eq!((seg.generation, seg.start_row, seg.rows), (1, n as u64, 2));
        assert_eq!(m.generation, 1);
        assert_eq!(sizes.len(), 1);
        assert!(segment_store_path(&base, 1).exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
