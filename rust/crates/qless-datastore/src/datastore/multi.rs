//! Multi-precision datastore fan-out — one extraction pass, every bitwidth.
//!
//! The Table-1 sweep needs the same gradient features stored at several
//! precisions. The legacy path extracted features into a resident fp32
//! `[n × k]` matrix per checkpoint and re-walked it once per precision —
//! the exact `n`-proportional footprint the paper's storage argument
//! removes. [`MultiWriter`] inverts that dataflow: feature rows stream in
//! as bounded windows, a pool-parallel quantize stage
//! ([`crate::quant::batch::quantize_rows_into`]) packs each window at
//! **every** requested precision, and per-precision [`DatastoreWriter`]s
//! write the packed windows through at their final offsets. Peak builder
//! memory is `O(window × Σ row_stride)` — independent of the corpus size —
//! and every produced file is byte-identical to the per-precision legacy
//! path (`tests/build_stream.rs` locks this in across bitwidth × scheme ×
//! worker count × window size).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::store::DatastoreWriter;
use crate::quant::batch::{quantize_rows_into, window_row_bytes};
use crate::quant::Precision;

/// Streaming fan-out writer: one logical row stream in, one datastore file
/// per precision out. Drives `begin_checkpoint` / [`Self::append_rows`] /
/// `end_checkpoint` across all member writers in lockstep.
pub struct MultiWriter {
    k: usize,
    workers: usize,
    precisions: Vec<Precision>,
    paths: Vec<PathBuf>,
    writers: Vec<DatastoreWriter>,
    /// Reusable per-precision packed-bytes / scales scratch.
    scratch_bytes: Vec<Vec<u8>>,
    scratch_scales: Vec<Vec<f32>>,
    /// High-water mark of builder-resident bytes (incoming fp32 window +
    /// all per-precision scratch), for the pipeline's stage accounting.
    peak_bytes: u64,
}

impl MultiWriter {
    /// Create one datastore per `(precision, path)` pair for the shared
    /// geometry. Duplicate precisions are rejected (they would race on
    /// one path). `workers` caps the quantize-stage parallelism per
    /// window (0 = the persistent pool's full width).
    pub fn create(
        targets: &[(Precision, PathBuf)],
        n_samples: usize,
        k: usize,
        n_checkpoints: usize,
        workers: usize,
    ) -> Result<MultiWriter> {
        if targets.is_empty() {
            bail!("MultiWriter: no target precisions");
        }
        for (i, (p, _)) in targets.iter().enumerate() {
            if targets[..i].iter().any(|(q, _)| q == p) {
                bail!("MultiWriter: duplicate precision {}", p.label());
            }
        }
        let mut writers = Vec::with_capacity(targets.len());
        for (p, path) in targets {
            writers.push(
                DatastoreWriter::create(path, *p, n_samples, k, n_checkpoints)
                    .with_context(|| format!("creating {} datastore", p.label()))?,
            );
        }
        Ok(MultiWriter {
            k,
            workers,
            precisions: targets.iter().map(|(p, _)| *p).collect(),
            paths: targets.iter().map(|(_, path)| path.clone()).collect(),
            writers,
            scratch_bytes: vec![Vec::new(); targets.len()],
            scratch_scales: vec![Vec::new(); targets.len()],
            peak_bytes: 0,
        })
    }

    /// Builder-resident bytes one streamed row costs across the fp32
    /// window and every target's packed window — the divisor that turns a
    /// `--build-mem-budget-mb` into a window row count.
    pub fn bytes_per_row(k: usize, precisions: &[Precision]) -> u64 {
        let packed: usize = precisions.iter().map(|p| window_row_bytes(k, *p)).sum();
        (k * 4 + packed) as u64
    }

    /// Largest window (in rows) whose builder-resident buffers fit
    /// `budget_bytes`, floored at 1 so tiny budgets still make progress.
    pub fn window_rows_for_budget(k: usize, precisions: &[Precision], budget_bytes: u64) -> usize {
        (budget_bytes / Self::bytes_per_row(k, precisions).max(1)).max(1) as usize
    }

    /// The target precisions, in creation order.
    pub fn precisions(&self) -> &[Precision] {
        &self.precisions
    }

    /// The target file paths, in creation order.
    pub fn paths(&self) -> &[PathBuf] {
        &self.paths
    }

    /// Start the next checkpoint block (LR weight η) in every member.
    pub fn begin_checkpoint(&mut self, eta: f32) -> Result<()> {
        for w in &mut self.writers {
            w.begin_checkpoint(eta)?;
        }
        Ok(())
    }

    /// Append a window of `rows.len() / k` feature rows (in sample order):
    /// quantize the window at every precision on the pool, then write each
    /// packed result through its member writer. The caller bounds the
    /// window size; this never buffers beyond one window per precision.
    pub fn append_rows(&mut self, rows: &[f32]) -> Result<()> {
        if rows.len() % self.k != 0 {
            bail!("append_rows: {} floats is not a whole number of k={} rows", rows.len(), self.k);
        }
        let _sp = qless_core::util::obs::span("build.quantize_window");
        qless_core::util::obs::counter_add("build_window_rows_total", (rows.len() / self.k) as u64);
        let mut resident = rows.len() as u64 * 4;
        for (i, p) in self.precisions.iter().enumerate() {
            quantize_rows_into(
                rows,
                self.k,
                *p,
                &mut self.scratch_bytes[i],
                &mut self.scratch_scales[i],
                self.workers,
            )
            .with_context(|| format!("quantizing window for {}", p.label()))?;
            self.writers[i]
                .append_packed_window(&self.scratch_scales[i], &self.scratch_bytes[i])
                .with_context(|| format!("writing window to {}", p.label()))?;
            resident +=
                (self.scratch_bytes[i].capacity() + 4 * self.scratch_scales[i].capacity()) as u64;
        }
        self.peak_bytes = self.peak_bytes.max(resident);
        Ok(())
    }

    /// Finish the current checkpoint block in every member.
    pub fn end_checkpoint(&mut self) -> Result<()> {
        for w in &mut self.writers {
            w.end_checkpoint()?;
        }
        Ok(())
    }

    /// High-water mark of builder-resident bytes (incoming fp32 window +
    /// per-precision packed scratch) across all [`Self::append_rows`]
    /// calls so far.
    pub fn peak_builder_bytes(&self) -> u64 {
        self.peak_bytes
    }

    /// Finalize every member store; returns the file sizes in creation
    /// order.
    pub fn finalize(self) -> Result<Vec<u64>> {
        let mut sizes = Vec::with_capacity(self.writers.len());
        for (w, p) in self.writers.into_iter().zip(&self.precisions) {
            sizes.push(w.finalize().with_context(|| format!("finalizing {}", p.label()))?);
        }
        Ok(sizes)
    }
}

/// Canonical `(precision, path)` targets for a run directory — each
/// precision at its [`super::default_store_path`].
pub fn default_targets(run_dir: &Path, precisions: &[Precision]) -> Vec<(Precision, PathBuf)> {
    precisions.iter().map(|p| (*p, super::default_store_path(run_dir, *p))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::Datastore;
    use crate::quant::Scheme;
    use crate::util::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "qless_multi_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn rows(n: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * k).map(|_| rng.normal() as f32).collect()
    }

    fn sweep() -> Vec<Precision> {
        [16u8, 8, 4, 2, 1]
            .iter()
            .map(|&b| {
                Precision::new(b, if b == 1 { Scheme::Sign } else { Scheme::Absmax }).unwrap()
            })
            .collect()
    }

    #[test]
    fn one_pass_emits_all_precisions_byte_identical_to_legacy() {
        let dir = tmpdir("fanout");
        let (n, k, c) = (17usize, 96usize, 2usize);
        let ps = sweep();
        let targets = default_targets(&dir, &ps);
        let mut mw = MultiWriter::create(&targets, n, k, c, 0).unwrap();
        for ci in 0..c {
            mw.begin_checkpoint(0.4 * (ci + 1) as f32).unwrap();
            let data = rows(n, k, ci as u64);
            // stream in ragged windows (5 + 5 + 7 rows)
            for (lo, hi) in [(0usize, 5usize), (5, 10), (10, n)] {
                mw.append_rows(&data[lo * k..hi * k]).unwrap();
            }
            mw.end_checkpoint().unwrap();
        }
        assert!(mw.peak_builder_bytes() > 0);
        let sizes = mw.finalize().unwrap();
        assert_eq!(sizes.len(), ps.len());

        for (p, path) in &targets {
            let legacy = dir.join(format!("legacy_{}b.qlds", p.bits));
            let mut w = DatastoreWriter::create(&legacy, *p, n, k, c).unwrap();
            for ci in 0..c {
                w.begin_checkpoint(0.4 * (ci + 1) as f32).unwrap();
                let data = rows(n, k, ci as u64);
                for i in 0..n {
                    w.append_features(&data[i * k..(i + 1) * k]).unwrap();
                }
                w.end_checkpoint().unwrap();
            }
            w.finalize().unwrap();
            assert_eq!(
                std::fs::read(path).unwrap(),
                std::fs::read(&legacy).unwrap(),
                "{} file differs from legacy path",
                p.label()
            );
            let ds = Datastore::open(path).unwrap();
            assert!(ds.matches_geometry(*p, n, k, c));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_duplicate_precisions_and_empty_targets() {
        let dir = tmpdir("dup");
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let targets = vec![(p, dir.join("a.qlds")), (p, dir.join("b.qlds"))];
        assert!(MultiWriter::create(&targets, 4, 8, 1, 0).is_err());
        assert!(MultiWriter::create(&[], 4, 8, 1, 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_to_window_rows() {
        let ps = sweep();
        let k = 512usize;
        // fp32 row (2048 B) + Σ packed rows: 1024 + (512+4) + (256+4) +
        // (128+4) + (64+4) per row
        let per_row = MultiWriter::bytes_per_row(k, &ps);
        assert_eq!(per_row, 2048 + 1024 + 516 + 260 + 132 + 68);
        assert_eq!(MultiWriter::window_rows_for_budget(k, &ps, 10 * per_row), 10);
        assert_eq!(MultiWriter::window_rows_for_budget(k, &ps, 0), 1); // floor
    }

    #[test]
    fn lockstep_protocol_is_enforced() {
        let dir = tmpdir("proto");
        let ps = vec![Precision::new(8, Scheme::Absmax).unwrap()];
        let targets = default_targets(&dir, &ps);
        let (n, k) = (3usize, 8usize);
        let mut mw = MultiWriter::create(&targets, n, k, 1, 2).unwrap();
        assert!(mw.append_rows(&rows(1, k, 0)).is_err()); // before begin
        mw.begin_checkpoint(1.0).unwrap();
        assert!(mw.append_rows(&[0.0; 3]).is_err()); // ragged
        mw.append_rows(&rows(n, k, 1)).unwrap();
        assert!(mw.append_rows(&rows(1, k, 2)).is_err()); // too many rows
        mw.end_checkpoint().unwrap();
        let sizes = mw.finalize().unwrap();
        assert_eq!(sizes.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
