//! Shared on-disk datastore test fixtures.
//!
//! The in-memory feature fixture (`normal_features`) lives in
//! `qless_core::util::prop`; this module adds the one fixture that needs
//! the writer: a seeded datastore on disk. Both are re-exported together
//! through [`crate::util::prop`] so test modules keep a single import
//! path.

use std::path::Path;

use crate::datastore::{Datastore, DatastoreWriter};
use crate::quant::Precision;
use crate::util::prop::normal_features;

/// Test fixture: write a datastore at `path` with one checkpoint block per
/// `etas` entry — block `ci` holds [`normal_features`]`(n, k, seed + ci)` —
/// and open it. This is THE shared `DatastoreWriter::create` +
/// `append_features` loop; test modules must not re-roll their own copy.
/// Panics on any I/O or protocol error (it's a fixture, not a path under
/// test). The caller owns the file's lifetime ([`Datastore`] reads lazily,
/// so keep it alive while scanning).
pub fn seeded_datastore(
    path: &Path,
    precision: Precision,
    n: usize,
    k: usize,
    etas: &[f32],
    seed: u64,
) -> Datastore {
    let mut w = DatastoreWriter::create(path, precision, n, k, etas.len()).unwrap();
    for (ci, &eta) in etas.iter().enumerate() {
        let f = normal_features(n, k, seed + ci as u64);
        w.begin_checkpoint(eta).unwrap();
        for i in 0..n {
            w.append_features(f.row(i)).unwrap();
        }
        w.end_checkpoint().unwrap();
    }
    w.finalize().unwrap();
    Datastore::open(path).unwrap()
}
