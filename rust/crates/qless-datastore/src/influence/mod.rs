//! Influence scoring — paper Eq. 7:
//!
//! Inf(z) = Σ_i η_i · mean_{z'∈D_val} ⟨ q̂_{z,i}, q̂_{z',i} ⟩
//!
//! Both sides are quantized-then-normalized (QLESS §3.2); the quantization
//! scale cancels under normalization, so scoring operates on integer codes
//! directly. Three execution paths, all bit-identical in ranking:
//!
//! * [`native`] — the **integer-domain scoring engine**: stored-code dot
//!   products with i32 accumulation plus a per-row scale/zero-point fixup
//!   at 2/4/8-bit, the 1-bit **XNOR+popcount** kernel (its degenerate
//!   case), and the dequantize-to-f32 reference path they are
//!   property-tested against.
//! * [`xla`]    — the L1 Pallas `influence` tile artifact via PJRT, chunked
//!   and padded to the compiled tile shape.
//! * [`aggregate`] — the streaming checkpoint loop: shards of each
//!   datastore block are scored under a memory budget with the chosen
//!   path, weighted by η_i, and accumulated into per-sample totals —
//!   peak resident memory is `O(shard)`, not `O(block)`.
//!
//! Scans are **multi-query**: a [`ValFeatures`] holds a set of validation
//! tasks, every kernel scores all of them during one traversal of the
//! train rows, and [`score_datastore_tasks`] streams the datastore once
//! for Q tasks ([`ScanStats`] proves the single pass). The scan core is
//! the re-entrant [`MultiScan`]: prepared tasks + per-task accumulators
//! that can be fed shards from *any* source — the disk stream here, or
//! the serving layer's RAM shard cache (`service::Session`).
//!
//! Two selective read paths sit on top of the exhaustive scan, both exact
//! in a provable limit: [`cascade`] (cheap 1-bit probe, exact
//! high-precision rerank — exhaustive when the candidate multiplier
//! covers the store) and [`index`] (IVF cluster probing over a
//! `datastore::index` sidecar — byte-identical to the exhaustive scan at
//! `nprobe = nclusters`).

pub mod aggregate;
pub mod cascade;
pub mod index;
pub mod native;
pub(crate) mod simd;
pub mod xla;

pub use aggregate::{
    score_datastore, score_datastore_tasks, score_live_tasks, MultiScan, ScanStats, ScoreOpts,
};
pub use cascade::{
    cascade_datastore_tasks, cascade_live_tasks, CascadeOpts, CascadeOutcome,
    DEFAULT_CASCADE_MULT,
};
pub use index::{
    effective_nprobe, index_cascade_live_tasks, index_scan_live_tasks, index_scan_live_tasks_at,
    merge_index_outcomes, probe_rank_clusters, IndexOpts, IndexOutcome,
};
pub use native::{ValFeatures, ValTask};
