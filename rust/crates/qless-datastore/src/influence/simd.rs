//! Architecture-specific inner kernels for the blocked scan paths: the
//! u8×i8 integer dot (stored offset-binary lanes × validation codes) and
//! the 1-bit XNOR-agree popcount, each in a scalar form plus `cfg`-gated
//! AVX2 (x86_64) and NEON (aarch64) intrinsics.
//!
//! **Exactness contract** (DESIGN.md §11): every variant computes the
//! *identical integer* — dots accumulate in i32/u32 with no rounding, so
//! scalar vs SIMD equality is `==`, not ≤ε, and the f32 score math built
//! on top of these integers is bit-exact across variants by construction.
//!
//! The AVX2 dot deliberately avoids `_mm256_maddubs_epi16` (it saturates:
//! two adjacent 8-bit products reach 2·254·127 = 64 516 > `i16::MAX`) in
//! favor of exact 8→16-bit widening + `_mm256_madd_epi16`. Per-lane i32
//! accumulation is safe under the same `int_dot_fits` bound the scalar
//! engine enforces: each of the 8 lanes sums ⌈k/8⌉ products bounded by
//! 2α², which is ≤ the full-k scalar bound the dispatcher already checks.
//!
//! Dispatch is by **value** ([`Kernel`]) resolved once at process start
//! (`util::cpu::active`), not by function pointer — the match compiles to
//! a predictable branch and keeps the unsafe surface confined to this
//! module. Callers never reach the `unsafe fn`s directly: [`int_dot`] and
//! [`xnor_agree`] re-verify the cfg/feature gate before entering them.

use crate::util::cpu::Kernel;

/// Scalar u8×i8 dot — the reference the SIMD variants must equal exactly.
/// Matches the inner loop of `native::scores_int_rows` verbatim.
#[inline]
pub(crate) fn dot_u8i8_scalar(stored: &[u8], codes: &[i8]) -> i32 {
    let mut dot = 0i32;
    for (&s, &c) in stored.iter().zip(codes.iter()) {
        dot += s as i32 * c as i32;
    }
    dot
}

/// Scalar XNOR-agree count over two equal-length packed byte rows: the
/// number of bit positions where `a` and `b` hold the same bit. Runs on
/// u64 words for throughput with a per-byte tail, matching the word loop
/// of `native::scores_1bit_rows` arithmetic exactly (popcounts are
/// order-independent integers).
#[inline]
pub(crate) fn xnor_agree_scalar(a: &[u8], b: &[u8]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut agree = 0u32;
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (xa, xb) in ac.by_ref().zip(bc.by_ref()) {
        let x = u64::from_le_bytes(xa.try_into().unwrap());
        let y = u64::from_le_bytes(xb.try_into().unwrap());
        agree += (!(x ^ y)).count_ones();
    }
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        agree += (!(x ^ y)).count_ones();
    }
    agree
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// AVX2 u8×i8 dot with exact widening (no saturation — see the module
    /// docs). 32 lanes per iteration; the remainder runs scalar.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 (the [`super::int_dot`]
    /// wrapper re-checks `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_u8i8(stored: &[u8], codes: &[i8]) -> i32 {
        debug_assert_eq!(stored.len(), codes.len());
        let n = stored.len();
        let chunks = n / 32;
        // SAFETY: all pointer arithmetic stays within `stored`/`codes`
        // (`chunks*32 <= n`), and loadu has no alignment requirement.
        unsafe {
            let mut acc = _mm256_setzero_si256();
            for i in 0..chunks {
                let s = _mm256_loadu_si256(stored.as_ptr().add(i * 32) as *const __m256i);
                let c = _mm256_loadu_si256(codes.as_ptr().add(i * 32) as *const __m256i);
                // widen each 16-byte half exactly: u8→i16 (zero-extend,
                // stored lanes are 0..=2α) and i8→i16 (sign-extend)
                let s_lo = _mm256_cvtepu8_epi16(_mm256_castsi256_si128(s));
                let s_hi = _mm256_cvtepu8_epi16(_mm256_extracti128_si256::<1>(s));
                let c_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(c));
                let c_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(c));
                // madd: 16 exact i16×i16 products per half, pair-summed
                // into 8 i32 lanes; lane sums bounded by int_dot_fits
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(s_lo, c_lo));
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(s_hi, c_hi));
            }
            let mut lanes = [0i32; 8];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            let mut dot: i32 = lanes.iter().sum();
            for i in chunks * 32..n {
                dot += stored[i] as i32 * codes[i] as i32;
            }
            dot
        }
    }

    /// AVX2 XNOR-agree via the nibble-LUT popcount (Muła): per-byte
    /// popcounts of `!(a^b)` looked up 32 bytes at a time, horizontally
    /// summed through `_mm256_sad_epu8` into 4 u64 lanes.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 (the [`super::xnor_agree`]
    /// wrapper re-checks `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn xnor_agree(a: &[u8], b: &[u8]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 32;
        // SAFETY: loads stay within the slices (`chunks*32 <= n`); loadu
        // is unaligned-safe.
        unsafe {
            // popcount-per-nibble lookup table, repeated across both lanes
            let lut = _mm256_setr_epi8(
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            );
            let low_mask = _mm256_set1_epi8(0x0f);
            let ones = _mm256_set1_epi8(-1);
            let mut acc = _mm256_setzero_si256();
            for i in 0..chunks {
                let x = _mm256_loadu_si256(a.as_ptr().add(i * 32) as *const __m256i);
                let y = _mm256_loadu_si256(b.as_ptr().add(i * 32) as *const __m256i);
                let xnor = _mm256_xor_si256(_mm256_xor_si256(x, y), ones);
                let lo = _mm256_and_si256(xnor, low_mask);
                let hi = _mm256_and_si256(_mm256_srli_epi32::<4>(xnor), low_mask);
                let pop =
                    _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
                // per-byte popcounts are ≤ 8, so the 8-byte groups sad
                // sums (≤ 64) fit u16 lanes with huge margin
                acc = _mm256_add_epi64(acc, _mm256_sad_epu8(pop, _mm256_setzero_si256()));
            }
            let mut lanes = [0u64; 4];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            let mut agree = lanes.iter().sum::<u64>() as u32;
            for i in chunks * 32..n {
                agree += (!(a[i] ^ b[i])).count_ones();
            }
            agree
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    /// NEON u8×i8 dot with exact widening: 16 lanes per iteration via
    /// u8→u16→i16 / i8→i16 moves and four `vmlal_s16` accumulations.
    ///
    /// # Safety
    /// NEON is baseline on aarch64; the target_feature gate only asserts
    /// what every aarch64 target already guarantees.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_u8i8(stored: &[u8], codes: &[i8]) -> i32 {
        debug_assert_eq!(stored.len(), codes.len());
        let n = stored.len();
        let chunks = n / 16;
        // SAFETY: loads stay within the slices (`chunks*16 <= n`); vld1q
        // has no alignment requirement on aarch64.
        unsafe {
            let mut acc = vdupq_n_s32(0);
            for i in 0..chunks {
                let s = vld1q_u8(stored.as_ptr().add(i * 16));
                let c = vld1q_s8(codes.as_ptr().add(i * 16));
                // widen exactly: stored u8 → i16 (values ≤ 254 fit), codes
                // i8 → i16 (sign-extend); products fit i32 via vmlal_s16
                let s_lo = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(s)));
                let s_hi = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(s)));
                let c_lo = vmovl_s8(vget_low_s8(c));
                let c_hi = vmovl_s8(vget_high_s8(c));
                acc = vmlal_s16(acc, vget_low_s16(s_lo), vget_low_s16(c_lo));
                acc = vmlal_s16(acc, vget_high_s16(s_lo), vget_high_s16(c_lo));
                acc = vmlal_s16(acc, vget_low_s16(s_hi), vget_low_s16(c_hi));
                acc = vmlal_s16(acc, vget_high_s16(s_hi), vget_high_s16(c_hi));
            }
            let mut dot = vaddvq_s32(acc);
            for i in chunks * 16..n {
                dot += stored[i] as i32 * codes[i] as i32;
            }
            dot
        }
    }

    /// NEON XNOR-agree: hardware per-byte popcount (`vcnt`) of the XNOR,
    /// horizontally summed 16 bytes at a time (`vaddlvq_u8` ≤ 128 per
    /// chunk, accumulated in u32).
    ///
    /// # Safety
    /// NEON is baseline on aarch64 (see [`dot_u8i8`]).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn xnor_agree(a: &[u8], b: &[u8]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 16;
        // SAFETY: loads stay within the slices (`chunks*16 <= n`).
        unsafe {
            let mut agree = 0u32;
            for i in 0..chunks {
                let x = vld1q_u8(a.as_ptr().add(i * 16));
                let y = vld1q_u8(b.as_ptr().add(i * 16));
                let pop = vcntq_u8(vmvnq_u8(veorq_u8(x, y)));
                agree += vaddlvq_u8(pop) as u32;
            }
            for i in chunks * 16..n {
                agree += (!(a[i] ^ b[i])).count_ones();
            }
            agree
        }
    }
}

/// The u8×i8 integer dot for `kernel`. Safe: SIMD arms re-verify the CPU
/// feature before entering the `unsafe fn`, and any variant that cannot
/// run here (wrong arch, feature missing) silently computes the identical
/// integer through the scalar loop.
#[inline]
pub(crate) fn int_dot(kernel: Kernel, stored: &[u8], codes: &[i8]) -> i32 {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: AVX2 verified on this CPU one line up.
            unsafe { x86::dot_u8i8(stored, codes) }
        }
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => {
            // SAFETY: NEON is baseline on every aarch64 target.
            unsafe { arm::dot_u8i8(stored, codes) }
        }
        _ => dot_u8i8_scalar(stored, codes),
    }
}

/// The XNOR-agree bit count for `kernel`; same dispatch contract as
/// [`int_dot`].
#[inline]
pub(crate) fn xnor_agree(kernel: Kernel, a: &[u8], b: &[u8]) -> u32 {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: AVX2 verified on this CPU one line up.
            unsafe { x86::xnor_agree(a, b) }
        }
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => {
            // SAFETY: NEON is baseline on every aarch64 target.
            unsafe { arm::xnor_agree(a, b) }
        }
        _ => xnor_agree_scalar(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cpu;
    use crate::util::Rng;

    fn rand_row(rng: &mut Rng, n: usize, alpha: u8) -> (Vec<u8>, Vec<i8>) {
        let stored: Vec<u8> = (0..n).map(|_| rng.below(2 * alpha as usize + 1) as u8).collect();
        let codes: Vec<i8> = (0..n)
            .map(|_| (rng.below(2 * alpha as usize + 1) as i16 - alpha as i16) as i8)
            .collect();
        (stored, codes)
    }

    #[test]
    fn simd_dot_equals_scalar_exactly() {
        // every available variant, many lengths (SIMD chunk boundaries ± 1
        // and long tails), extreme lane values included
        let mut rng = Rng::new(77);
        for kernel in cpu::available() {
            for n in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 96, 127, 255, 513, 4099] {
                let (stored, codes) = rand_row(&mut rng, n, 127);
                assert_eq!(
                    int_dot(kernel, &stored, &codes),
                    dot_u8i8_scalar(&stored, &codes),
                    "kernel {} n={n}",
                    kernel.label()
                );
            }
            // saturation regression: alternating max-magnitude lanes would
            // overflow a maddubs-style i16 pair sum — must still be exact
            let stored = vec![254u8; 1024];
            let codes: Vec<i8> = (0..1024).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect();
            assert_eq!(
                int_dot(kernel, &stored, &codes),
                dot_u8i8_scalar(&stored, &codes),
                "kernel {} saturation pattern",
                kernel.label()
            );
        }
    }

    #[test]
    fn simd_agree_equals_scalar_exactly() {
        let mut rng = Rng::new(78);
        for kernel in cpu::available() {
            for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 31, 32, 33, 64, 65, 127, 512, 1025] {
                let a: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                let b: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                assert_eq!(
                    xnor_agree(kernel, &a, &b),
                    xnor_agree_scalar(&a, &b),
                    "kernel {} n={n}",
                    kernel.label()
                );
            }
            // identical rows agree on every bit; complements on none
            let a = vec![0b1010_1010u8; 100];
            let b: Vec<u8> = a.iter().map(|x| !x).collect();
            assert_eq!(xnor_agree(kernel, &a, &a), 800);
            assert_eq!(xnor_agree(kernel, &a, &b), 0);
        }
    }

    #[test]
    fn scalar_agree_matches_naive_bits() {
        let mut rng = Rng::new(79);
        for n in [1usize, 5, 8, 13, 40] {
            let a: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let b: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let naive: u32 = (0..n * 8)
                .map(|i| {
                    let xa = (a[i / 8] >> (i % 8)) & 1;
                    let xb = (b[i / 8] >> (i % 8)) & 1;
                    u32::from(xa == xb)
                })
                .sum();
            assert_eq!(xnor_agree_scalar(&a, &b), naive, "n={n}");
        }
    }
}
