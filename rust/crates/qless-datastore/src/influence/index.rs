//! IVF-indexed influence queries — the sub-linear read path over a
//! [`QuantIndex`] sidecar (`datastore::index`).
//!
//! An indexed query runs in two stages, both built from machinery that
//! already exists and is already property-tested:
//!
//! 1. **Probe** ([`probe_rank_clusters`]): score every *centroid* against
//!    every task with the ordinary 1-bit influence scan — the centroids
//!    are packed sign bitmaps, so a synthetic 1-bit header plus one
//!    [`RowsView`] per checkpoint turns [`MultiScan`] into a centroid
//!    scorer for free (η-weighted across checkpoints, same Eq. 7
//!    accumulation). Each task gets a deterministic full ranking of the
//!    cluster ids (`score desc, id asc` — the shared selection order).
//! 2. **Scan** ([`index_scan_live_tasks`]): take each task's top-P
//!    clusters (`--nprobe P`), gather their rows (persisted grouping +
//!    the in-memory stale tail), union across tasks, and score exactly
//!    those rows with the cascade's contiguous-run seek machinery
//!    ([`rerank_live_rows`]) — O(rows-in-probed-clusters) instead of
//!    O(n), with [`ScanStats`] proving the reduction.
//!
//! **Exactness at full coverage** (DESIGN.md §12): clusters partition the
//! row space, so `nprobe = nclusters` makes the candidate set every row;
//! `rerank_live_rows` over the full range feeds rows in the exhaustive
//! scan's order (checkpoint → member → run), so the accumulated scores —
//! and therefore the top-k — are **byte-identical** to the exhaustive
//! scan (`tests/index.rs` pins this across the precision grid).
//!
//! The coordinator partitions the **cluster list**, not the row space:
//! every worker derives the same deterministic per-task ranking, and a
//! `clusters: (start, len)` window assigns each worker a disjoint slice
//! of list *positions* ([`index_scan_live_tasks_at`]). Per-row scores are
//! feed-order independent (each row accumulates once per checkpoint in
//! checkpoint order regardless of which runs cover it), so partial
//! results merge with [`merge_top_k`] exactly like row-partitioned scans.
//!
//! [`index_cascade_live_tasks`] composes the index with the precision
//! cascade: the cheap 1-bit probe runs *inside* the probed clusters only,
//! then the exact high-precision rerank touches the `k·mult` survivors.

use anyhow::{ensure, Result};

use qless_core::select::{merge_top_k, sorted_union, top_k_scored, top_k_scored_among};

use crate::datastore::{default_nprobe, Header, LiveStore, QuantIndex, RowsView};
use crate::grads::FeatureMatrix;
use crate::influence::aggregate::{MultiScan, ScanStats, ScoreOpts};
use crate::influence::cascade::{combine_stats, rerank_live_rows, CascadeOpts, CascadeOutcome};
use crate::quant::{Precision, Scheme};

/// Knobs of one indexed query.
#[derive(Debug, Clone, Copy)]
pub struct IndexOpts {
    /// Final selections per task (the `k` of recall@k).
    pub k: usize,
    /// Clusters probed per task; 0 derives
    /// [`default_nprobe`]`(nclusters)`, values past the cluster count
    /// clamp to full coverage (= exhaustive-exact).
    pub nprobe: usize,
    /// Shard/memory knobs for both stages.
    pub scan: ScoreOpts,
}

/// Everything one indexed query produced.
#[derive(Debug, Clone)]
pub struct IndexOutcome {
    /// Per-task final top-`k` `(row, score)` pairs under the shared
    /// `(score desc, index asc)` order — byte-identical to the exhaustive
    /// scan's top-`k` at full coverage.
    pub top: Vec<Vec<(usize, f32)>>,
    /// Each task's full deterministic cluster ranking (probe order). The
    /// coordinator windows positions of these lists across workers.
    pub cluster_order: Vec<Vec<usize>>,
    /// Distinct rows the scan stage actually scored (candidate union).
    pub scanned_rows: usize,
    /// Centroid-probe I/O accounting (C rows per checkpoint, 1-bit).
    pub probe_pass: ScanStats,
    /// Cluster-scan I/O accounting — the `rows_read` the ≥ 4× reduction
    /// claim is asserted on (`tests/index.rs`).
    pub scan_pass: ScanStats,
}

impl IndexOutcome {
    /// Both stages as one [`ScanStats`] — the serving layer's `pass`.
    pub fn combined_pass(&self) -> ScanStats {
        combine_stats(self.probe_pass, self.scan_pass)
    }
}

/// Effective probe width for an index: explicit `nprobe` (0 = the
/// [`default_nprobe`] heuristic) clamped to the cluster count.
pub fn effective_nprobe(idx: &QuantIndex, nprobe: usize) -> usize {
    let nc = idx.n_clusters();
    if nprobe == 0 { default_nprobe(nc) } else { nprobe }.min(nc)
}

/// Stage 1: rank every cluster for every task by scoring the packed sign
/// centroids with the ordinary 1-bit multi-task scan, η-weighted across
/// checkpoints from the live store. Returns each task's **full** cluster
/// ranking (deterministic: score desc, cluster id asc) plus the probe's
/// own [`ScanStats`] — kept separate from the row-scan stats so the
/// sub-linearity claim is measured on row traffic alone.
pub fn probe_rank_clusters(
    idx: &QuantIndex,
    live: &LiveStore,
    tasks: &[&[FeatureMatrix]],
) -> Result<(Vec<Vec<usize>>, ScanStats)> {
    let nc = idx.n_clusters();
    ensure!(
        idx.n_checkpoints() == live.header().n_checkpoints as usize,
        "index/store checkpoint mismatch"
    );
    let precision = Precision::new(1, Scheme::Sign)?;
    // a virtual 1-bit store whose "rows" are the C centroids
    let header = Header::new(precision, nc, idx.k(), idx.n_checkpoints());
    let mut scan = MultiScan::try_new(&header, tasks)?;
    let ones = vec![1.0f32; nc]; // sign scores ignore scales; RowsView wants them
    for ci in 0..idx.n_checkpoints() {
        let view = RowsView {
            precision,
            k: idx.k(),
            row_stride: idx.row_stride(),
            scales: &ones,
            data: idx.centroids_ckpt(ci),
        };
        scan.feed(ci, live.etas()[ci], 0, &view);
    }
    let (totals, stats) = scan.finish();
    let order = totals
        .iter()
        .map(|t| top_k_scored(t, nc).into_iter().map(|(c, _)| c).collect())
        .collect();
    Ok((order, stats))
}

/// Candidate rows for one task: the rows of the clusters at list
/// positions `[at, at + len)` of its ranking, sorted ascending (the shape
/// [`rerank_live_rows`] wants). Stale-tail rows are included — an indexed
/// query covers live ingest as soon as [`QuantIndex::refresh`] ran.
fn cluster_window_rows(idx: &QuantIndex, ranked: &[usize], at: usize, len: usize) -> Vec<usize> {
    let hi = (at + len).min(ranked.len());
    let mut rows: Vec<usize> = ranked[at.min(hi)..hi]
        .iter()
        .flat_map(|&c| idx.cluster_rows(c).map(|r| r as usize))
        .collect();
    rows.sort_unstable();
    rows
}

/// Stage 2 + selection for a cluster-list window: probe, take positions
/// `[window.0, window.0 + window.1)` of **each task's own** ranking
/// (clamped to `nprobe` coverage), scan the union of their rows, and
/// select per-task top-k among that task's own candidates. `window =
/// (0, nprobe)` is the whole query ([`index_scan_live_tasks`]); the
/// coordinator fans out disjoint windows and merges with
/// [`merge_top_k`].
pub fn index_scan_live_tasks_at(
    live: &LiveStore,
    idx: &QuantIndex,
    tasks: &[&[FeatureMatrix]],
    opts: &IndexOpts,
    window: (usize, usize),
) -> Result<IndexOutcome> {
    ensure!(opts.k >= 1, "index scan needs k >= 1");
    ensure!(!tasks.is_empty(), "no validation tasks to score");
    ensure!(
        idx.covered_rows() as usize == live.n_rows(),
        "index covers {} rows but the live store has {} — refresh or `qless reindex` first",
        idx.covered_rows(),
        live.n_rows()
    );
    let nprobe = effective_nprobe(idx, opts.nprobe);
    let (order, probe_pass) = probe_rank_clusters(idx, live, tasks)?;
    let (at, len) = window;
    let per_task: Vec<Vec<usize>> = order
        .iter()
        .map(|ranked| cluster_window_rows(idx, &ranked[..nprobe], at, len))
        .collect();
    let union = sorted_union(&per_task);
    let mut top = vec![Vec::new(); tasks.len()];
    let mut scan_pass = ScanStats::default();
    if !union.is_empty() {
        let (scores, pass) = rerank_live_rows(live, tasks, &union, opts.scan)?;
        scan_pass = pass;
        for (t, cand) in per_task.iter().enumerate() {
            let pairs: Vec<(usize, f32)> = cand
                .iter()
                .map(|&row| {
                    let at = union.binary_search(&row).expect("candidate in union");
                    (row, scores[t][at])
                })
                .collect();
            top[t] = top_k_scored_among(&pairs, opts.k);
        }
    }
    Ok(IndexOutcome { top, cluster_order: order, scanned_rows: union.len(), probe_pass, scan_pass })
}

/// One full indexed query: probe every centroid, scan each task's top-P
/// clusters, return per-task top-k (see the module docs for the exactness
/// and merge arguments).
pub fn index_scan_live_tasks(
    live: &LiveStore,
    idx: &QuantIndex,
    tasks: &[&[FeatureMatrix]],
    opts: &IndexOpts,
) -> Result<IndexOutcome> {
    let nprobe = effective_nprobe(idx, opts.nprobe);
    index_scan_live_tasks_at(live, idx, tasks, opts, (0, nprobe))
}

/// Merge the per-worker outcomes of a cluster-partitioned scatter: task
/// lists concatenate under [`merge_top_k`] (disjoint windows of one
/// deterministic ranking ⇒ disjoint candidate rows per task ⇒ no
/// duplicate ids), traffic counters sum.
pub fn merge_index_outcomes(parts: &[IndexOutcome], k: usize) -> IndexOutcome {
    let q = parts.first().map_or(0, |p| p.top.len());
    let mut top = Vec::with_capacity(q);
    for t in 0..q {
        let per: Vec<Vec<(usize, f32)>> = parts.iter().map(|p| p.top[t].clone()).collect();
        top.push(merge_top_k(&per, k));
    }
    let mut probe_pass = ScanStats::default();
    let mut scan_pass = ScanStats::default();
    let mut scanned_rows = 0;
    for p in parts {
        probe_pass = combine_stats(probe_pass, p.probe_pass);
        scan_pass = combine_stats(scan_pass, p.scan_pass);
        scanned_rows += p.scanned_rows;
    }
    IndexOutcome {
        top,
        cluster_order: parts.first().map_or_else(Vec::new, |p| p.cluster_order.clone()),
        scanned_rows,
        probe_pass,
        scan_pass,
    }
}

/// Compose the index with the precision cascade: the cheap 1-bit probe
/// scan runs **only inside the probed clusters** of the 1-bit store, its
/// per-task top `k·mult` survivors are reranked exactly on the
/// high-precision store. The index must be built over the same row space
/// both stores share (one run directory). At `nprobe = nclusters` this
/// degenerates to the plain cascade, and with `mult` covering the
/// candidate count it is exhaustive-exact — the same two limits the plain
/// cascade's property tests pin.
pub fn index_cascade_live_tasks(
    probe: &LiveStore,
    rerank: &LiveStore,
    idx: &QuantIndex,
    tasks: &[&[FeatureMatrix]],
    opts: &CascadeOpts,
    nprobe: usize,
) -> Result<CascadeOutcome> {
    ensure!(opts.k >= 1 && opts.mult >= 1, "cascade needs k >= 1 and mult >= 1");
    ensure!(
        idx.covered_rows() as usize == probe.n_rows(),
        "index covers {} rows but the probe store has {} — refresh or `qless reindex` first",
        idx.covered_rows(),
        probe.n_rows()
    );
    ensure!(
        probe.n_rows() == rerank.n_rows(),
        "probe/rerank stores disagree on row count ({} vs {})",
        probe.n_rows(),
        rerank.n_rows()
    );
    let nprobe = effective_nprobe(idx, nprobe);
    let (order, centroid_pass) = probe_rank_clusters(idx, probe, tasks)?;
    let per_task_rows: Vec<Vec<usize>> =
        order.iter().map(|ranked| cluster_window_rows(idx, &ranked[..nprobe], 0, nprobe)).collect();
    let cluster_union = sorted_union(&per_task_rows);
    // stage 1: 1-bit probe scores, restricted to the probed clusters
    let (probe_scores, probe_pass) = rerank_live_rows(probe, tasks, &cluster_union, opts.scan)?;
    let ck = opts.k.saturating_mul(opts.mult);
    let mut survivors: Vec<Vec<usize>> = Vec::with_capacity(tasks.len());
    for (t, cand) in per_task_rows.iter().enumerate() {
        let pairs: Vec<(usize, f32)> = cand
            .iter()
            .map(|&row| {
                let at = cluster_union.binary_search(&row).expect("candidate in union");
                (row, probe_scores[t][at])
            })
            .collect();
        let mut keep: Vec<usize> =
            top_k_scored_among(&pairs, ck.min(pairs.len())).into_iter().map(|(r, _)| r).collect();
        keep.sort_unstable();
        survivors.push(keep);
    }
    let rerank_union = sorted_union(&survivors);
    // stage 2: exact rerank of the survivors at the high precision
    let (rerank_scores, rerank_pass) = rerank_live_rows(rerank, tasks, &rerank_union, opts.scan)?;
    let mut top = Vec::with_capacity(tasks.len());
    for (t, keep) in survivors.iter().enumerate() {
        let pairs: Vec<(usize, f32)> = keep
            .iter()
            .map(|&row| {
                let at = rerank_union.binary_search(&row).expect("survivor in union");
                (row, rerank_scores[t][at])
            })
            .collect();
        top.push(top_k_scored_among(&pairs, opts.k));
    }
    Ok(CascadeOutcome {
        top,
        reranked_rows: rerank_union.len(),
        probe_pass: combine_stats(centroid_pass, probe_pass),
        rerank_pass,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::{build_index, IndexBuildOpts};
    use crate::influence::aggregate::score_live_tasks;
    use crate::util::prop::{normal_features, seeded_datastore};
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "qless_iidx_{tag}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    fn fixture(tag: &str, n: usize, k: usize, etas: &[f32]) -> (LiveStore, PathBuf) {
        let p = Precision::new(1, Scheme::Sign).unwrap();
        let path = tmp(tag);
        seeded_datastore(&path, p, n, k, etas, 11);
        (LiveStore::open(&path).unwrap(), path)
    }

    fn tasks_for(k: usize, etas: &[f32], seed: u64) -> Vec<Vec<FeatureMatrix>> {
        vec![(0..etas.len()).map(|ci| normal_features(3, k, seed + ci as u64)).collect()]
    }

    #[test]
    fn full_coverage_matches_exhaustive_topk() {
        let etas = [0.8f32, 0.3];
        let (live, path) = fixture("cover", 64, 96, &etas);
        let idx = build_index(&live, &IndexBuildOpts { n_clusters: 6, max_iters: 4 }).unwrap();
        let owned = tasks_for(96, &etas, 5);
        let tasks: Vec<&[FeatureMatrix]> = owned.iter().map(|t| t.as_slice()).collect();
        let opts = IndexOpts { k: 9, nprobe: 6, scan: ScoreOpts::default() };
        let out = index_scan_live_tasks(&live, &idx, &tasks, &opts).unwrap();
        let (exh, _) = score_live_tasks(&live, &tasks, ScoreOpts::default()).unwrap();
        let want = top_k_scored(&exh[0], 9);
        assert_eq!(out.top[0].len(), want.len());
        for (a, b) in out.top[0].iter().zip(&want) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "byte-identical at full coverage");
        }
        assert_eq!(out.scanned_rows, 64);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn windows_partition_the_query() {
        let etas = [1.0f32];
        let (live, path) = fixture("win", 48, 64, &etas);
        let idx = build_index(&live, &IndexBuildOpts { n_clusters: 6, max_iters: 4 }).unwrap();
        let owned = tasks_for(64, &etas, 9);
        let tasks: Vec<&[FeatureMatrix]> = owned.iter().map(|t| t.as_slice()).collect();
        let opts = IndexOpts { k: 7, nprobe: 4, scan: ScoreOpts::default() };
        let whole = index_scan_live_tasks(&live, &idx, &tasks, &opts).unwrap();
        let a = index_scan_live_tasks_at(&live, &idx, &tasks, &opts, (0, 2)).unwrap();
        let b = index_scan_live_tasks_at(&live, &idx, &tasks, &opts, (2, 2)).unwrap();
        let merged = merge_index_outcomes(&[a, b], 7);
        assert_eq!(format!("{:?}", merged.top), format!("{:?}", whole.top));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn nprobe_zero_uses_default_and_scans_fewer_rows() {
        let etas = [1.0f32];
        let (live, path) = fixture("dflt", 80, 64, &etas);
        let idx = build_index(&live, &IndexBuildOpts { n_clusters: 8, max_iters: 4 }).unwrap();
        assert_eq!(effective_nprobe(&idx, 0), 1);
        assert_eq!(effective_nprobe(&idx, 99), 8);
        let owned = tasks_for(64, &etas, 3);
        let tasks: Vec<&[FeatureMatrix]> = owned.iter().map(|t| t.as_slice()).collect();
        let opts = IndexOpts { k: 4, nprobe: 0, scan: ScoreOpts::default() };
        let out = index_scan_live_tasks(&live, &idx, &tasks, &opts).unwrap();
        assert!(out.scanned_rows < 80, "default nprobe must not scan everything");
        assert!(out.scan_pass.rows_read < etas.len() as u64 * 80);
        assert_eq!(out.top[0].len(), 4);
        std::fs::remove_file(path).ok();
    }
}
