//! Checkpoint aggregation (the outer sum of Eq. 7):
//! Inf(z) = Σ_i η_i · mean_{z'} ⟨q̂_{z,i}, q̂_{z',i}⟩.
//!
//! Prepare every validation task's features once per checkpoint at the
//! datastore's precision, then **stream** each checkpoint's rows in
//! fixed-size shards (`Datastore::shard_reader`), score each shard against
//! *all* tasks with the fastest applicable path (popcount at 1-bit, the
//! integer-domain engine at 2/4/8-bit, dense f32 at 16-bit, or the XLA
//! kernel when requested), weight by η_i, and accumulate the per-shard
//! partials into per-task totals. Q validation tasks therefore cost
//! **one** datastore pass, not Q — [`ScanStats`] records the shard and
//! byte traffic so benches can assert exactly that. The prepared-tasks +
//! accumulators core is the re-entrant [`MultiScan`], which the serving
//! layer also drives with RAM-cached shards.
//!
//! Peak resident memory during a scan is the shard buffers — bounded by
//! `--mem-budget-mb` — instead of the whole `n × row_stride` block the
//! pre-shard reader materialized. Per-sample scores only depend on that
//! sample's row, so the streamed result is bit-identical to a whole-block
//! scan (property-tested in `tests/sharding.rs`), and a fused multi-task
//! scan is bit-identical to Q single-task scans (`tests/int_scoring.rs`).

use anyhow::Result;

use qless_core::util::obs;

use crate::datastore::{Datastore, Header, LiveStore, RowsView};
use crate::grads::FeatureMatrix;
use crate::influence::native::{scores_rows, ValFeatures};
use crate::influence::xla::{pack_val_tiles, scores_xla_rows};
use crate::runtime::{ModelInfo, Runtime};
use crate::{info, warn_};

/// Default scan memory budget when neither `ScoreOpts` nor the config
/// specifies one: comfortably larger than one typical shard of val
/// features, far smaller than paper-scale checkpoint blocks (≈ 4 GB).
/// One constant shared with the top crate's `config::Config` (via
/// `qless-core`, where it lives) so the CLI and library defaults can't
/// diverge.
pub use qless_core::DEFAULT_MEM_BUDGET_MB;

/// Knobs of one influence scan (sharding, memory budget, kernel choice).
#[derive(Debug, Clone, Copy, Default)]
pub struct ScoreOpts {
    /// Route the per-shard scoring through the AOT Pallas kernel.
    pub use_xla: bool,
    /// Fixed rows per shard; 0 = derive from `mem_budget_mb`.
    pub shard_rows: usize,
    /// Scan memory budget in MiB; 0 = [`DEFAULT_MEM_BUDGET_MB`].
    pub mem_budget_mb: usize,
}

impl ScoreOpts {
    /// The memory budget actually in force (resolves the 0 default).
    pub fn effective_budget_mb(&self) -> usize {
        if self.mem_budget_mb == 0 {
            DEFAULT_MEM_BUDGET_MB
        } else {
            self.mem_budget_mb
        }
    }
}

/// I/O accounting of one streamed scan — the proof obligation of the
/// multi-query design: `shards_read`/`bytes_read` must not depend on how
/// many validation tasks rode the pass. Rendered into the pipeline's
/// per-stage cost table (`pipeline::stage`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScanStats {
    /// Checkpoints scanned.
    pub checkpoints: usize,
    /// Validation tasks scored by the pass.
    pub tasks: usize,
    /// Shard reads performed (the scan's I/O unit).
    pub shards_read: usize,
    /// Rows streamed off disk.
    pub rows_read: u64,
    /// Resident bytes streamed (rows × per-row resident cost).
    pub bytes_read: u64,
}

/// One in-progress multi-task scan: per-checkpoint validation features
/// prepared at the datastore's precision, per-task score accumulators, and
/// the pass's I/O accounting. This is the **re-entrant** scan core — feed
/// it shard row views in any order (each row of each checkpoint exactly
/// once) and it produces the same totals as [`score_datastore_tasks`],
/// because per-sample accumulation only depends on that sample's row and
/// the checkpoint order of `feed` calls per row. Two callers share it:
///
/// * the batch pipeline's disk scan (`score_datastore_tasks`), and
/// * the serving layer (`service::Session`), whose shards may come from a
///   RAM cache instead of the file.
pub struct MultiScan {
    /// Prepared validation tasks, one [`ValFeatures`] set per checkpoint.
    vals: Vec<ValFeatures>,
    /// Per-task running totals, `[q][n_rows]`, indexed by `row − base_row`.
    totals: Vec<Vec<f32>>,
    stats: ScanStats,
    q: usize,
    base_row: usize,
    resident_row_bytes: u64,
    bits: u8,
}

impl MultiScan {
    /// Prepare a scan of `tasks` over a store with `header`'s geometry.
    /// `tasks[t]` holds task `t`'s raw (unquantized) per-checkpoint
    /// validation features — quantization to the store's precision happens
    /// here, mirroring §3.2. Rejects an empty task set, per-task checkpoint
    /// counts that don't match the store, dimension mismatches, and
    /// non-finite features, all as recoverable errors.
    pub fn try_new(header: &Header, tasks: &[&[FeatureMatrix]]) -> Result<MultiScan> {
        Self::try_new_range(header, tasks, 0, header.n_samples as usize)
    }

    /// [`MultiScan::try_new`] over an explicit **global row range**
    /// `base_row .. base_row + n_rows`: totals cover exactly that range
    /// (`feed` starts are still global). Two callers need this instead of
    /// the header's own row count: scans over a [`crate::datastore::LiveStore`],
    /// whose live total spans several member files, and the serving
    /// layer's incremental **tail scans**, which re-score only rows newer
    /// than a cached answer after an ingest.
    pub fn try_new_range(
        header: &Header,
        tasks: &[&[FeatureMatrix]],
        base_row: usize,
        n_rows: usize,
    ) -> Result<MultiScan> {
        let c = header.n_checkpoints as usize;
        let k = header.k as usize;
        let q = tasks.len();
        anyhow::ensure!(q > 0, "no validation tasks to score");
        for (t, per_ckpt) in tasks.iter().enumerate() {
            anyhow::ensure!(
                per_ckpt.len() == c,
                "task {t}: validation features for {} checkpoints, datastore has {c}",
                per_ckpt.len()
            );
        }
        let mut vals = Vec::with_capacity(c);
        for ci in 0..c {
            // prepared once per checkpoint, reused by every shard of that
            // checkpoint — val features are never re-read or re-packed
            let per_task: Vec<&FeatureMatrix> = tasks.iter().map(|t| &t[ci]).collect();
            let val = ValFeatures::try_prepare_tasks(&per_task, header.precision)?;
            anyhow::ensure!(val.k == k, "validation feature dim {} != datastore k {k}", val.k);
            vals.push(val);
        }
        Ok(MultiScan {
            vals,
            totals: vec![vec![0f32; n_rows]; q],
            stats: ScanStats { checkpoints: c, tasks: q, ..Default::default() },
            q,
            base_row,
            resident_row_bytes: header.resident_row_bytes(),
            bits: header.precision.bits,
        })
    }

    /// The prepared validation features of checkpoint `ckpt` (the XLA path
    /// packs kernel tiles from these).
    pub fn val(&self, ckpt: usize) -> &ValFeatures {
        &self.vals[ckpt]
    }

    /// Number of validation tasks riding the scan.
    pub fn n_tasks(&self) -> usize {
        self.q
    }

    /// Score one shard of checkpoint `ckpt` (rows starting at global row
    /// `start`) with the fastest native kernel and accumulate into the
    /// per-task totals, weighted by the checkpoint's `eta`.
    pub fn feed(&mut self, ckpt: usize, eta: f32, start: usize, rows: &RowsView<'_>) {
        let scores = scores_rows(rows, &self.vals[ckpt]);
        self.feed_scores(eta, start, rows.n(), &scores);
    }

    /// Accumulate precomputed row-major `[n_rows × Q]` scores for a shard
    /// starting at global row `start` (the XLA path computes scores
    /// externally and feeds them here; [`Self::feed`] is the native form).
    pub fn feed_scores(&mut self, eta: f32, start: usize, n_rows: usize, scores: &[f32]) {
        debug_assert_eq!(scores.len(), n_rows * self.q);
        debug_assert!(start >= self.base_row, "fed shard below the scan's row range");
        for (j, chunk) in scores.chunks_exact(self.q).enumerate() {
            let g = start + j - self.base_row;
            for (total, &s) in self.totals.iter_mut().zip(chunk) {
                total[g] += eta * s;
            }
        }
        self.stats.shards_read += 1;
        self.stats.rows_read += n_rows as u64;
        self.stats.bytes_read += n_rows as u64 * self.resident_row_bytes;
    }

    /// The pass's I/O accounting so far.
    pub fn stats(&self) -> ScanStats {
        self.stats
    }

    /// Finish the scan: per-task score totals (caller order) + the pass's
    /// [`ScanStats`]. Publishes the pass's row/byte traffic to the
    /// calling thread's metrics registry as per-bitwidth counters —
    /// **on this thread only**, so `obs::with_registry` property tests
    /// observe exactly the passes they ran (never inside the
    /// pool-parallel row loops; one map lookup per *pass*, not per row).
    pub fn finish(self) -> (Vec<Vec<f32>>, ScanStats) {
        let r = obs::reg();
        r.counter_add(&format!("scan_passes_total{{bits=\"{}\"}}", self.bits), 1);
        r.counter_add(
            &format!("scan_rows_total{{bits=\"{}\"}}", self.bits),
            self.stats.rows_read,
        );
        r.counter_add(
            &format!("scan_bytes_total{{bits=\"{}\"}}", self.bits),
            self.stats.bytes_read,
        );
        (self.totals, self.stats)
    }
}

/// Score every training sample in `ds` against **Q validation tasks** in a
/// single streamed pass. `tasks[t]` holds task `t`'s raw (unquantized)
/// per-checkpoint validation features — quantization to the datastore's
/// precision happens here, mirroring §3.2. Returns one score vector per
/// task (same order), plus the pass's [`ScanStats`].
///
/// `rt_info` is only needed for the XLA path and may be `None` otherwise.
pub fn score_datastore_tasks(
    ds: &Datastore,
    tasks: &[&[FeatureMatrix]],
    opts: ScoreOpts,
    rt_info: Option<(&Runtime, &ModelInfo)>,
) -> Result<(Vec<Vec<f32>>, ScanStats)> {
    let c = ds.n_checkpoints();
    let q = tasks.len();
    let n = ds.n_samples();
    let precision = ds.header.precision;
    let k = ds.header.k as usize;
    let mut scan = MultiScan::try_new(&ds.header, tasks)?;
    let mut rows_per_shard = ds.rows_per_shard(opts.shard_rows, opts.effective_budget_mb());
    if opts.use_xla {
        if let Some((_, info)) = rt_info {
            // round down to whole kernel tiles so tail padding doesn't add
            // a nearly-empty launch per shard; shards below one tile must
            // round *up* to tile_q, which can exceed a very small budget
            let rounded = (rows_per_shard / info.tile_q).max(1) * info.tile_q;
            if rounded > rows_per_shard {
                warn_!(
                    "XLA scan needs at least one {}-row tile per shard; \
                     resident memory may exceed the requested budget",
                    info.tile_q
                );
            }
            rows_per_shard = rounded;
        }
    } else if n >= 256 {
        // the native kernels keep small jobs serial (pool wakeup costs
        // more than the work: < 256 rows or < 8M inner ops per shard);
        // shards under those thresholds serialize the whole scan — legal,
        // but worth a loud note on a multi-core box
        let nv: usize = tasks.iter().filter_map(|t| t.first()).map(|f| f.n).sum();
        let work_per_row =
            if precision.bits == 1 { nv * k.div_ceil(64) } else { nv * k } as u64;
        let whole_scan_parallel = (n as u64) * work_per_row >= 8_000_000;
        let shard_parallel =
            rows_per_shard >= 256 && (rows_per_shard as u64) * work_per_row >= 8_000_000;
        if whole_scan_parallel && !shard_parallel {
            warn_!(
                "scan shards of {rows_per_shard} rows fall below the parallel threshold; \
                 raise --mem-budget-mb or --shard-rows to parallelize the scan"
            );
        }
    }
    for ci in 0..c {
        let _sp = obs::span("scan.checkpoint");
        let val_tiles = match (opts.use_xla, rt_info) {
            (true, Some((_, info))) => Some(pack_val_tiles(info, scan.val(ci))),
            (true, None) => return Err(anyhow::anyhow!("XLA scoring requires a runtime")),
            _ => None,
        };
        let t0 = std::time::Instant::now();
        let mut reader = ds.shard_reader(ci, rows_per_shard)?;
        let eta = reader.eta();
        let mut shards = 0usize;
        while let Some(shard) = reader.next_shard()? {
            let rows = shard.rows();
            if let Some(tiles) = &val_tiles {
                let (rt, info) = rt_info.expect("checked above");
                let scores = scores_xla_rows(rt, info, &rows, tiles)?;
                scan.feed_scores(eta, shard.start, rows.n(), &scores);
            } else {
                scan.feed(ci, eta, shard.start, &rows);
            }
            shards += 1;
        }
        info!(
            "scored checkpoint {ci} (η={eta:.2e}, {n}×{} vs {} val rows / {} tasks, {shards} shards ≤{rows_per_shard} rows) in {:.2}s",
            ds.header.k,
            scan.val(ci).n(),
            q,
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(scan.finish())
}

/// [`score_datastore_tasks`] over a **live** store: one streamed pass per
/// member (base + every ingested segment), all Q tasks fused, totals over
/// the live row space `0 .. live.n_rows()`. Rows are scored member by
/// member with the member's own η (validated equal to the base's on
/// attach), so the result over `base ++ segments` is bit-identical to a
/// single monolithic store holding the same rows — `tests/ingest.rs`
/// locks that in across bitwidth × scheme × window. Native kernels only
/// (the XLA tile path is not plumbed through live stores).
pub fn score_live_tasks(
    live: &LiveStore,
    tasks: &[&[FeatureMatrix]],
    opts: ScoreOpts,
) -> Result<(Vec<Vec<f32>>, ScanStats)> {
    let mut scan = MultiScan::try_new_range(live.header(), tasks, 0, live.n_rows())?;
    let rows_per_shard = live.rows_per_shard(opts.shard_rows, opts.effective_budget_mb());
    for ci in 0..live.header().n_checkpoints as usize {
        let _sp = obs::span("scan.checkpoint");
        for member in live.members() {
            let mut reader = member.ds.shard_reader(ci, rows_per_shard)?;
            let eta = reader.eta();
            while let Some(shard) = reader.next_shard()? {
                scan.feed(ci, eta, member.start_row + shard.start, &shard.rows());
            }
        }
    }
    Ok(scan.finish())
}

/// Single-task [`score_datastore_tasks`]: score every training sample
/// against per-checkpoint validation features `val_per_ckpt` (raw,
/// unquantized — quantization to the datastore's precision happens here,
/// mirroring §3.2).
///
/// `rt_info` is only needed for the XLA path and may be `None` otherwise.
pub fn score_datastore(
    ds: &Datastore,
    val_per_ckpt: &[FeatureMatrix],
    opts: ScoreOpts,
    rt_info: Option<(&Runtime, &ModelInfo)>,
) -> Result<Vec<f32>> {
    let (mut per_task, _) = score_datastore_tasks(ds, &[val_per_ckpt], opts, rt_info)?;
    Ok(per_task.swap_remove(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Precision, Scheme};
    use crate::util::prop::{normal_features as feats, seeded_datastore};

    /// Build a datastore and keep its file alive (Datastore reads lazily).
    fn build_ds_keep(bits: u8, etas: &[f32], n: usize, k: usize) -> (Datastore, std::path::PathBuf) {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = std::env::temp_dir().join(format!(
            "qless_aggk_{bits}_e{}_c{}_{}_{:?}.qlds",
            etas[0],
            etas.len(),
            std::process::id(),
            std::thread::current().id()
        ));
        // block ci holds normal_features(n, k, ci) — seed base 0
        (seeded_datastore(&path, p, n, k, etas, 0), path)
    }

    #[test]
    fn eta_weights_scale_scores() {
        let (n, k) = (8, 64);
        let (ds1, p1) = build_ds_keep(8, &[1.0], n, k);
        let (ds2, p2) = build_ds_keep(8, &[2.0], n, k);
        let val = vec![feats(4, k, 99)];
        let a = score_datastore(&ds1, &val, ScoreOpts::default(), None).unwrap();
        let b = score_datastore(&ds2, &val, ScoreOpts::default(), None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((2.0 * x - y).abs() < 1e-5, "{x} {y}");
        }
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn multi_checkpoint_sums() {
        let (n, k) = (6, 64);
        let (ds, p) = build_ds_keep(4, &[0.5, 0.25], n, k);
        let vals = vec![feats(3, k, 50), feats(3, k, 51)];
        let s = score_datastore(&ds, &vals, ScoreOpts::default(), None).unwrap();
        assert_eq!(s.len(), n);
        assert!(s.iter().all(|x| x.is_finite() && x.abs() <= 0.75 + 1e-5));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn shard_size_does_not_change_scores() {
        // streaming granularity is an implementation knob, not a semantic:
        // every shard size must give bit-identical totals
        let (n, k) = (11, 64);
        for bits in [16u8, 8, 1] {
            let (ds, p) = build_ds_keep(bits, &[0.7, 0.2], n, k);
            let vals = vec![feats(3, k, 60), feats(3, k, 61)];
            let whole = score_datastore(
                &ds,
                &vals,
                ScoreOpts { shard_rows: n, ..Default::default() },
                None,
            )
            .unwrap();
            for shard_rows in [1usize, 2, 3, 4, 7, n + 5] {
                let s = score_datastore(
                    &ds,
                    &vals,
                    ScoreOpts { shard_rows, ..Default::default() },
                    None,
                )
                .unwrap();
                assert_eq!(s, whole, "bits {bits} shard_rows {shard_rows}");
            }
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn multi_task_scan_reads_datastore_once() {
        // Q tasks, one pass: shard/row/byte traffic must equal a
        // single-task scan's, and per-task scores must equal their
        // individual scans exactly.
        let (n, k) = (32, 64);
        let (ds, p) = build_ds_keep(4, &[0.9, 0.4], n, k);
        let t0 = vec![feats(2, k, 70), feats(2, k, 71)];
        let t1 = vec![feats(5, k, 72), feats(5, k, 73)];
        let t2 = vec![feats(1, k, 74), feats(1, k, 75)];
        let opts = ScoreOpts { shard_rows: 5, ..Default::default() };
        let (fused, stats) = score_datastore_tasks(
            &ds,
            &[&t0, &t1, &t2],
            opts,
            None,
        )
        .unwrap();
        assert_eq!(fused.len(), 3);
        assert_eq!(stats.tasks, 3);
        assert_eq!(stats.checkpoints, 2);
        // 32 rows / 5 per shard = 7 shards per checkpoint, 2 checkpoints
        assert_eq!(stats.shards_read, 14);
        assert_eq!(stats.rows_read, 2 * n as u64);
        let (_, single_stats) =
            score_datastore_tasks(&ds, &[&t0], opts, None).unwrap();
        assert_eq!(stats.shards_read, single_stats.shards_read, "multi-task pass must not re-read");
        assert_eq!(stats.bytes_read, single_stats.bytes_read);
        for (t, task) in [&t0, &t1, &t2].into_iter().enumerate() {
            let alone = score_datastore(&ds, task, opts, None).unwrap();
            assert_eq!(alone, fused[t], "task {t}: fused vs single scan");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn multiscan_feed_matches_streamed_scan() {
        // The re-entrant scan core, fed shards manually and out of order
        // within each checkpoint (the serving layer's cache-hit pattern),
        // must reproduce score_datastore_tasks exactly — totals and stats.
        let (n, k) = (12usize, 64usize);
        let (ds, p) = build_ds_keep(4, &[0.9, 0.4], n, k);
        let t0v = vec![feats(2, k, 80), feats(2, k, 81)];
        let t1v = vec![feats(3, k, 82), feats(3, k, 83)];
        let tasks: Vec<&[FeatureMatrix]> = vec![&t0v, &t1v];
        let shard_rows = 5usize;
        let opts = ScoreOpts { shard_rows, ..Default::default() };
        let (want, want_stats) = score_datastore_tasks(&ds, &tasks, opts, None).unwrap();
        let mut scan = crate::influence::MultiScan::try_new(&ds.header, &tasks).unwrap();
        assert_eq!(scan.n_tasks(), 2);
        for ci in 0..ds.n_checkpoints() {
            let mut r = ds.shard_reader(ci, shard_rows).unwrap();
            let eta = r.eta();
            for si in (0..n.div_ceil(shard_rows)).rev() {
                r.seek_to_row(si * shard_rows);
                let shard = r.next_shard().unwrap().unwrap();
                scan.feed(ci, eta, shard.start, &shard.rows());
            }
        }
        assert_eq!(scan.stats().shards_read, want_stats.shards_read);
        let (got, got_stats) = scan.finish();
        assert_eq!(got, want, "re-entrant feed must be bit-identical");
        assert_eq!(got_stats, want_stats);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn live_scan_matches_monolithic_store() {
        // Scoring base + ingested segment through score_live_tasks must be
        // bit-identical to one monolithic store holding the same rows, and
        // a tail-range MultiScan over just the segment must reproduce the
        // monolithic scores' tail exactly (the serving layer's incremental
        // score-cache extension).
        use crate::datastore::{default_store_path, LiveStore, SegmentWriter};
        let (n0, add, k) = (9usize, 5usize, 64usize);
        let n_total = n0 + add;
        let etas = [0.8f32, 0.3];
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "qless_livescan_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let base = default_store_path(&dir, p);
        // normal_features draws sequentially from one seeded stream, so
        // rows 0..n0 of the monolithic fixture equal the base store's rows
        seeded_datastore(&base, p, n0, k, &etas, 0);
        let mut sw = SegmentWriter::create(&dir, &[p], add, 0).unwrap();
        for ci in 0..etas.len() {
            sw.begin_checkpoint().unwrap();
            sw.append_rows(&feats(n_total, k, ci as u64).data[n0 * k..]).unwrap();
            sw.end_checkpoint().unwrap();
        }
        sw.finalize().unwrap();
        let mono_path = dir.join("mono.qlds");
        let mono = seeded_datastore(&mono_path, p, n_total, k, &etas, 0);
        let live = LiveStore::open(&base).unwrap();
        assert_eq!(live.n_rows(), n_total);

        let t0 = vec![feats(3, k, 70), feats(3, k, 71)];
        let t1 = vec![feats(2, k, 72), feats(2, k, 73)];
        let tasks: Vec<&[FeatureMatrix]> = vec![&t0, &t1];
        let opts = ScoreOpts { shard_rows: 4, ..Default::default() };
        let (want, want_stats) = score_datastore_tasks(&mono, &tasks, opts, None).unwrap();
        let (got, stats) = score_live_tasks(&live, &tasks, opts).unwrap();
        assert_eq!(got, want, "live base+segment vs monolithic scores");
        assert_eq!(stats.rows_read, want_stats.rows_read);

        let mut scan = MultiScan::try_new_range(live.header(), &tasks, n0, add).unwrap();
        for ci in 0..etas.len() {
            let m = &live.members()[1];
            let mut r = m.ds.shard_reader(ci, 3).unwrap();
            let eta = r.eta();
            while let Some(shard) = r.next_shard().unwrap() {
                scan.feed(ci, eta, m.start_row + shard.start, &shard.rows());
            }
        }
        let (tail, _) = scan.finish();
        for (t, tail_scores) in tail.iter().enumerate() {
            assert_eq!(tail_scores.as_slice(), &want[t][n0..], "task {t}: tail-range scan");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nan_val_features_error_not_panic() {
        // a NaN validation gradient must fail the scan with a recoverable
        // Err, not abort the process mid-sweep
        let (ds, p) = build_ds_keep(8, &[1.0], 4, 64);
        let mut v = feats(2, 64, 5);
        v.data[7] = f32::NAN;
        let err = score_datastore(&ds, &[v], ScoreOpts::default(), None).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn checkpoint_count_mismatch_errors() {
        let (ds, p) = build_ds_keep(8, &[1.0, 1.0], 4, 64);
        let vals = vec![feats(2, 64, 1)];
        assert!(score_datastore(&ds, &vals, ScoreOpts::default(), None).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn mismatched_task_lengths_error() {
        let (ds, p) = build_ds_keep(8, &[1.0, 1.0], 4, 64);
        let good = vec![feats(2, 64, 1), feats(2, 64, 2)];
        let short = vec![feats(2, 64, 3)];
        assert!(score_datastore_tasks(
            &ds,
            &[&good, &short],
            ScoreOpts::default(),
            None
        )
        .is_err());
        std::fs::remove_file(p).ok();
    }
}
