//! Compute-constrained precision cascade — two-stage influence scoring.
//!
//! QLESS shows 1-bit gradients preserve valuation quality; compute-
//! constrained selection says valuation quality must be priced against
//! the compute that buys it. The cascade spends the two currencies where
//! each is cheap:
//!
//! * **Stage 1 (probe)** scans *every* row at a cheap probe precision
//!   (default 1-bit: ~12× popcount path, `k/8 + 4` resident bytes per
//!   row) with the existing fused [`MultiScan`], and keeps the top `c·k`
//!   candidate rows per task under the deterministic
//!   `(score desc, index asc)` order of [`top_k_scored`].
//! * **Stage 2 (rerank)** re-scores *only* the candidate union at the
//!   rerank precision (default 8- or 16-bit), using
//!   [`ShardReader::seek_to_row`](crate::datastore::ShardReader::seek_to_row)
//!   random access over the **aligned row spaces** the multi-precision
//!   builder guarantees: row `i` of `datastore_1b_sign.qlds` and of
//!   `datastore_8b_absmax.qlds` are the same sample, so probe indices
//!   address rerank rows directly.
//!
//! The final per-task top-`k` is taken over that task's own candidate
//! set with the rerank scores — **never** mixed probe/rerank scores, and
//! never dependent on which other tasks shared the pass (candidates are
//! per-task; the union only coalesces I/O). Exactness properties (proved
//! in `tests/cascade.rs`, derived in `DESIGN.md` §10):
//!
//! * with `c·k ≥ n` the candidate set is every row, so the cascade is
//!   **byte-identical** to the exhaustive rerank-precision scan;
//! * recall@k of the selected set is exactly
//!   `|ExactTopK ∩ candidates| / k` and monotone non-decreasing in `c`,
//!   because candidate sets grow as prefix-supersets in `c`;
//! * reranking a candidate subset via clipped feeds produces bit-exact
//!   per-row scores: [`MultiScan`] accumulation per row depends only on
//!   that row's bytes and the per-row checkpoint feed order.
//!
//! I/O is accounted in the same [`ScanStats`] units as every other scan:
//! probe ≈ `n · (k/8 + 4) · C` resident bytes, rerank ≈
//! `|candidates| · (k + 4) · C`, versus `n · (k + 4) · C` exhaustive —
//! the ratio the `xp cascade` harness and `bench_influence` report.

use anyhow::{bail, ensure, Result};

use crate::datastore::{Datastore, Header, LiveStore};
use crate::grads::FeatureMatrix;
use crate::influence::aggregate::{score_datastore_tasks, score_live_tasks, MultiScan, ScanStats, ScoreOpts};
use crate::select::{top_k_scored, top_k_scored_among};

/// Default candidate multiplier `c`: stage 1 keeps `c·k` rows per task.
/// Chosen so recall@k at paper-scale settings stays ≥ 0.95 with a
/// comfortable margin while the rerank stage stays a small fraction of
/// the row space (`tests/cascade.rs` pins both).
pub const DEFAULT_CASCADE_MULT: usize = 8;

/// Knobs of one cascade pass.
#[derive(Debug, Clone, Copy)]
pub struct CascadeOpts {
    /// Final selections per task (the `k` of recall@k).
    pub k: usize,
    /// Candidate multiplier `c` — stage 1 keeps `c·k` rows per task
    /// (clamped to the row count; `c·k ≥ n` makes the cascade exhaustive).
    pub mult: usize,
    /// Shard/memory knobs shared by both stages (the XLA route is forced
    /// off — the cascade is native-kernel only).
    pub scan: ScoreOpts,
}

/// Everything one cascade pass produced.
#[derive(Debug, Clone)]
pub struct CascadeOutcome {
    /// Per-task final top-`k`: `(row, rerank-precision score)` pairs under
    /// the shared `(score desc, index asc)` order — byte-identical to the
    /// exhaustive rerank scan's top-`k` whenever the candidates cover it.
    pub top: Vec<Vec<(usize, f32)>>,
    /// Distinct rows stage 2 re-scored (the per-task candidate union).
    pub reranked_rows: usize,
    /// Stage-1 I/O accounting (full scan at probe precision).
    pub probe_pass: ScanStats,
    /// Stage-2 I/O accounting (candidate rows only, at rerank precision).
    pub rerank_pass: ScanStats,
}

impl CascadeOutcome {
    /// Both stages as one [`ScanStats`]: traffic counters sum, geometry
    /// counters (checkpoints, tasks) take the max — the form the serving
    /// layer reports in a reply's `pass` field.
    pub fn combined_pass(&self) -> ScanStats {
        combine_stats(self.probe_pass, self.rerank_pass)
    }
}

/// Sum two passes' traffic counters (shards/rows/bytes), max their
/// geometry counters — the cascade's `pass` accounting, also used by the
/// coordinator when merging probe- and rerank-wave stats.
pub fn combine_stats(a: ScanStats, b: ScanStats) -> ScanStats {
    ScanStats {
        checkpoints: a.checkpoints.max(b.checkpoints),
        tasks: a.tasks.max(b.tasks),
        shards_read: a.shards_read + b.shards_read,
        rows_read: a.rows_read + b.rows_read,
        bytes_read: a.bytes_read + b.bytes_read,
    }
}

/// Resident bytes an exhaustive scan of `n_rows` rows streams under this
/// header's geometry — the denominator of the cascade's io-unit claim
/// (`C · n · resident_row_bytes`).
pub fn exhaustive_scan_bytes(header: &Header, n_rows: usize) -> u64 {
    header.n_checkpoints as u64 * n_rows as u64 * header.resident_row_bytes()
}

/// Collapse a **sorted, deduplicated** row list into maximal contiguous
/// `(start, len)` runs — the unit the rerank stage seeks and clip-feeds,
/// and the serving layer's cache-aware rerank path reuses.
pub fn contiguous_runs(rows: &[usize]) -> Vec<(usize, usize)> {
    let mut runs: Vec<(usize, usize)> = Vec::new();
    for &r in rows {
        match runs.last_mut() {
            Some((start, len)) if *start + *len == r => *len += 1,
            _ => runs.push((r, 1)),
        }
    }
    runs
}

/// Per-task candidate row sets (each task's probe top-`c·k`, ascending by
/// row) plus their sorted union — the exact rows stage 2 must score.
pub fn probe_candidates(
    probe_scores: &[Vec<f32>],
    ck: usize,
) -> (Vec<Vec<usize>>, Vec<usize>) {
    let mut per_task: Vec<Vec<usize>> = Vec::with_capacity(probe_scores.len());
    for scores in probe_scores {
        let mut rows: Vec<usize> = top_k_scored(scores, ck).into_iter().map(|(i, _)| i).collect();
        rows.sort_unstable();
        per_task.push(rows);
    }
    let mut union: Vec<usize> = per_task.iter().flatten().copied().collect();
    union.sort_unstable();
    union.dedup();
    (per_task, union)
}

/// Validate that a probe/rerank store pair describes the **same sample
/// rows**: equal row count, projection dim, checkpoint count and bit-equal
/// η weights. The multi-precision builder and ingest guarantee this for
/// the stores of one run directory; anything else must not cascade.
fn ensure_aligned(
    probe: &Header,
    probe_rows: usize,
    probe_etas: &[f32],
    rerank: &Header,
    rerank_rows: usize,
    rerank_etas: &[f32],
) -> Result<()> {
    ensure!(
        probe_rows == rerank_rows,
        "cascade stores disagree on row count: probe ({}) has {probe_rows} rows, \
         rerank ({}) has {rerank_rows}",
        probe.precision.label(),
        rerank.precision.label()
    );
    ensure!(
        probe.k == rerank.k,
        "cascade stores disagree on projection dim: probe k={}, rerank k={}",
        probe.k,
        rerank.k
    );
    ensure!(
        probe.n_checkpoints == rerank.n_checkpoints,
        "cascade stores disagree on checkpoint count: probe has {}, rerank has {}",
        probe.n_checkpoints,
        rerank.n_checkpoints
    );
    for (ci, (a, b)) in probe_etas.iter().zip(rerank_etas).enumerate() {
        ensure!(
            a.to_bits() == b.to_bits(),
            "cascade stores disagree on checkpoint {ci} η: probe {a}, rerank {b} — \
             the stores come from different training runs"
        );
    }
    Ok(())
}

fn validate_opts(opts: &CascadeOpts, n: usize) -> Result<ScoreOpts> {
    ensure!(opts.k >= 1, "cascade needs k >= 1 final selections per task");
    ensure!(opts.mult >= 1, "cascade candidate multiplier must be >= 1");
    ensure!(n >= 1, "cascade over an empty store");
    // the cascade is native-kernel only: the XLA tile path is not plumbed
    // through the clipped-feed rerank stage
    Ok(ScoreOpts { use_xla: false, ..opts.scan })
}

/// Stage 2 over a frozen store: re-score exactly the (sorted, unique)
/// `rows` at the store's precision, returning per-task scores aligned to
/// `rows` plus the stage's [`ScanStats`]. Each run of consecutive rows is
/// read via `seek_to_row` with a shard sized to the run, so I/O scales
/// with the candidate count, not `n`. Per-row scores are bit-exact to an
/// exhaustive scan's (clipped feeds don't change a row's arithmetic).
pub fn rerank_datastore_rows(
    ds: &Datastore,
    tasks: &[&[FeatureMatrix]],
    rows: &[usize],
    opts: ScoreOpts,
) -> Result<(Vec<Vec<f32>>, ScanStats)> {
    let n = ds.n_samples();
    if let Some(&last) = rows.last() {
        ensure!(last < n, "candidate row {last} out of range (store has {n} rows)");
    }
    let mut scan = MultiScan::try_new(&ds.header, tasks)?;
    let runs = contiguous_runs(rows);
    let rps = ds.rows_per_shard(opts.shard_rows, opts.effective_budget_mb());
    for ci in 0..ds.n_checkpoints() {
        for &(start, len) in &runs {
            // shard size capped to the run so random access reads what it
            // scores, not a budget-sized over-shoot past the run's end
            let mut reader = ds.shard_reader(ci, len.min(rps))?;
            let eta = reader.eta();
            reader.seek_to_row(start);
            let end = start + len;
            let mut row = start;
            while row < end {
                let Some(shard) = reader.next_shard()? else {
                    bail!("candidate run {start}+{len} ran past the end of the store");
                };
                let take = (end - shard.start).min(shard.len());
                scan.feed(ci, eta, shard.start, &shard.rows().slice(0, take));
                row = shard.start + take;
            }
        }
    }
    let (totals, stats) = scan.finish();
    Ok((gather(&totals, rows), stats))
}

/// [`rerank_datastore_rows`] over a **live** store: candidate runs are
/// clipped against each member's row range and fed member-local, same
/// global totals. Feed order (checkpoint → member → run) matches the
/// exhaustive live scan's per-row order, keeping accumulation bit-exact.
pub fn rerank_live_rows(
    live: &LiveStore,
    tasks: &[&[FeatureMatrix]],
    rows: &[usize],
    opts: ScoreOpts,
) -> Result<(Vec<Vec<f32>>, ScanStats)> {
    let n = live.n_rows();
    if let Some(&last) = rows.last() {
        ensure!(last < n, "candidate row {last} out of range (live store has {n} rows)");
    }
    let mut scan = MultiScan::try_new_range(live.header(), tasks, 0, n)?;
    let runs = contiguous_runs(rows);
    let rps = live.rows_per_shard(opts.shard_rows, opts.effective_budget_mb());
    for ci in 0..live.header().n_checkpoints as usize {
        for member in live.members() {
            let m_lo = member.start_row;
            let m_hi = m_lo + member.ds.n_samples();
            for &(start, len) in &runs {
                let lo = start.max(m_lo);
                let hi = (start + len).min(m_hi);
                if lo >= hi {
                    continue; // run doesn't touch this member
                }
                let mut reader = member.ds.shard_reader(ci, (hi - lo).min(rps))?;
                let eta = reader.eta();
                reader.seek_to_row(lo - m_lo);
                let mut row = lo - m_lo; // member-local
                let end = hi - m_lo;
                while row < end {
                    let Some(shard) = reader.next_shard()? else {
                        bail!("candidate run {start}+{len} ran past the end of a live member");
                    };
                    let take = (end - shard.start).min(shard.len());
                    scan.feed(ci, eta, m_lo + shard.start, &shard.rows().slice(0, take));
                    row = shard.start + take;
                }
            }
        }
    }
    let (totals, stats) = scan.finish();
    Ok((gather(&totals, rows), stats))
}

/// Pull the candidate rows' scores out of full-range totals, aligned to
/// `rows` order.
fn gather(totals: &[Vec<f32>], rows: &[usize]) -> Vec<Vec<f32>> {
    totals.iter().map(|t| rows.iter().map(|&r| t[r]).collect()).collect()
}

/// Shared stage-1 → stage-2 plumbing: pick candidates from the probe
/// scores, rerank their union through `rerank_fn`, and take each task's
/// final top-`k` over **its own** candidates (so an answer never depends
/// on which other tasks shared the pass).
fn finish_cascade(
    probe_scores: Vec<Vec<f32>>,
    probe_pass: ScanStats,
    n: usize,
    opts: &CascadeOpts,
    rerank_fn: impl FnOnce(&[usize]) -> Result<(Vec<Vec<f32>>, ScanStats)>,
) -> Result<CascadeOutcome> {
    let ck = opts.k.saturating_mul(opts.mult).min(n);
    let (per_task, union) = probe_candidates(&probe_scores, ck);
    let (rr, rerank_pass) = rerank_fn(&union)?;
    let mut top = Vec::with_capacity(per_task.len());
    for (t, cand) in per_task.iter().enumerate() {
        let pairs: Vec<(usize, f32)> = cand
            .iter()
            .map(|&row| {
                let at = union.binary_search(&row).expect("candidate in union");
                (row, rr[t][at])
            })
            .collect();
        top.push(top_k_scored_among(&pairs, opts.k));
    }
    Ok(CascadeOutcome { top, reranked_rows: union.len(), probe_pass, rerank_pass })
}

/// Run the full cascade over a frozen probe/rerank store pair (aligned
/// row spaces required — see the module docs). Returns each task's final
/// top-`k` at the rerank precision plus both stages' I/O accounting.
pub fn cascade_datastore_tasks(
    probe: &Datastore,
    rerank: &Datastore,
    tasks: &[&[FeatureMatrix]],
    opts: CascadeOpts,
) -> Result<CascadeOutcome> {
    let scan_opts = validate_opts(&opts, probe.n_samples())?;
    let etas = |ds: &Datastore| -> Result<Vec<f32>> {
        (0..ds.n_checkpoints()).map(|ci| Ok(ds.shard_reader(ci, 1)?.eta())).collect()
    };
    ensure_aligned(
        &probe.header,
        probe.n_samples(),
        &etas(probe)?,
        &rerank.header,
        rerank.n_samples(),
        &etas(rerank)?,
    )?;
    let (probe_scores, probe_pass) = score_datastore_tasks(probe, tasks, scan_opts, None)?;
    finish_cascade(probe_scores, probe_pass, probe.n_samples(), &opts, |rows| {
        rerank_datastore_rows(rerank, tasks, rows, scan_opts)
    })
}

/// [`cascade_datastore_tasks`] over a **live** probe/rerank pair (base +
/// ingested generations). Both stores must sit at the same generation —
/// they share one manifest in a run directory, so open/refresh them
/// together and this holds by construction.
pub fn cascade_live_tasks(
    probe: &LiveStore,
    rerank: &LiveStore,
    tasks: &[&[FeatureMatrix]],
    opts: CascadeOpts,
) -> Result<CascadeOutcome> {
    let scan_opts = validate_opts(&opts, probe.n_rows())?;
    ensure_aligned(
        probe.header(),
        probe.n_rows(),
        probe.etas(),
        rerank.header(),
        rerank.n_rows(),
        rerank.etas(),
    )?;
    let (probe_scores, probe_pass) = score_live_tasks(probe, tasks, scan_opts)?;
    finish_cascade(probe_scores, probe_pass, probe.n_rows(), &opts, |rows| {
        rerank_live_rows(rerank, tasks, rows, scan_opts)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Precision, Scheme};
    use crate::util::prop::{normal_features as feats, seeded_datastore};
    use std::path::PathBuf;

    fn tmp(tag: &str, bits: u8) -> PathBuf {
        std::env::temp_dir().join(format!(
            "qless_cascade_{tag}_{bits}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    /// An aligned probe/rerank pair: same rows (same fixture seed), two
    /// precisions.
    fn pair(n: usize, k: usize, etas: &[f32]) -> (Datastore, Datastore, Vec<PathBuf>) {
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        let (a, b) = (tmp("pair", 1), tmp("pair", 8));
        let probe = seeded_datastore(&a, p1, n, k, etas, 0);
        let rerank = seeded_datastore(&b, p8, n, k, etas, 0);
        (probe, rerank, vec![a, b])
    }

    #[test]
    fn contiguous_runs_collapse() {
        assert!(contiguous_runs(&[]).is_empty());
        assert_eq!(contiguous_runs(&[3]), vec![(3, 1)]);
        assert_eq!(contiguous_runs(&[0, 1, 2, 5, 7, 8]), vec![(0, 3), (5, 1), (7, 2)]);
    }

    #[test]
    fn covering_multiplier_is_exhaustive() {
        // c·k ≥ n: the cascade must equal the exhaustive rerank scan,
        // scores bit-identical.
        let (n, k) = (17usize, 64usize);
        let (probe, rerank, paths) = pair(n, k, &[0.8, 0.3]);
        let t0 = vec![feats(2, k, 90), feats(2, k, 91)];
        let t1 = vec![feats(3, k, 92), feats(3, k, 93)];
        let tasks: Vec<&[FeatureMatrix]> = vec![&t0, &t1];
        let scan = ScoreOpts { shard_rows: 4, ..Default::default() };
        let (want, _) = score_datastore_tasks(&rerank, &tasks, scan, None).unwrap();
        let kk = 3usize;
        let out = cascade_datastore_tasks(
            &probe,
            &rerank,
            &tasks,
            CascadeOpts { k: kk, mult: n, scan },
        )
        .unwrap();
        assert_eq!(out.reranked_rows, n, "covering multiplier reranks every row");
        for (t, got) in out.top.iter().enumerate() {
            assert_eq!(got, &top_k_scored(&want[t], kk), "task {t}");
        }
        for p in paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn rerank_rows_bit_match_full_scan() {
        let (n, k) = (13usize, 64usize);
        let (_, rerank, paths) = pair(n, k, &[0.6]);
        let t0 = vec![feats(2, k, 95)];
        let tasks: Vec<&[FeatureMatrix]> = vec![&t0];
        let scan = ScoreOpts { shard_rows: 5, ..Default::default() };
        let (full, _) = score_datastore_tasks(&rerank, &tasks, scan, None).unwrap();
        let rows = vec![0usize, 1, 2, 6, 9, 10, 12];
        let (got, stats) = rerank_datastore_rows(&rerank, &tasks, &rows, scan).unwrap();
        for (j, &r) in rows.iter().enumerate() {
            assert_eq!(got[0][j].to_bits(), full[0][r].to_bits(), "row {r}");
        }
        assert_eq!(stats.rows_read, rows.len() as u64, "rerank reads only candidates");
        for p in paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn misaligned_pair_and_bad_opts_error() {
        let (n, k) = (8usize, 64usize);
        let (probe, rerank, paths) = pair(n, k, &[1.0]);
        let t0 = vec![feats(2, k, 97)];
        let tasks: Vec<&[FeatureMatrix]> = vec![&t0];
        let scan = ScoreOpts::default();
        let err = cascade_datastore_tasks(
            &probe,
            &rerank,
            &tasks,
            CascadeOpts { k: 0, mult: 2, scan },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("k >= 1"), "{err:#}");
        let err = cascade_datastore_tasks(
            &probe,
            &rerank,
            &tasks,
            CascadeOpts { k: 2, mult: 0, scan },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("multiplier"), "{err:#}");
        // a rerank store with a different row count must be refused
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        let short_path = tmp("short", 8);
        let short = seeded_datastore(&short_path, p8, n - 2, k, &[1.0], 0);
        let err = cascade_datastore_tasks(
            &probe,
            &short,
            &tasks,
            CascadeOpts { k: 2, mult: 2, scan },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("row count"), "{err:#}");
        std::fs::remove_file(short_path).ok();
        for p in paths {
            std::fs::remove_file(p).ok();
        }
    }
}
