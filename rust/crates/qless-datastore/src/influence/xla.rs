//! XLA influence path: drives the L1 Pallas cosine tile
//! (`influence.hlo.txt`, compiled at `[tile_q × k] · [k × tile_v]`) over the
//! full train × val grid, padding tail tiles with zero rows (zero rows
//! normalize to zero and contribute zero similarity — sliced off on read).
//!
//! Multi-query scans concatenate every task's validation rows into one
//! tile sequence, so Q tasks share each train tile upload and kernel
//! launch; per-column task ownership routes the similarities into the
//! right task's accumulator on readback.

use anyhow::Result;

use crate::datastore::{CheckpointBlock, RowsView};
use crate::influence::native::ValFeatures;
use crate::runtime::{Arg, ModelInfo, Runtime};

/// Validation rows packed into zero-padded `[tile_v × k]` kernel tiles —
/// built **once per checkpoint** and reused by every shard of its scan
/// (rebuilding per shard would be an O(nv·k) copy per shard). Rows from
/// all tasks are concatenated in task order; `task_of` remembers which
/// task owns each concatenated row.
pub struct ValTiles {
    /// Task id of each concatenated (unpadded) validation row.
    task_of: Vec<usize>,
    /// Per-task `1/n_v` mean normalization.
    inv_nv: Vec<f32>,
    /// Zero-padded `[tile_v × k]` tiles over the concatenated rows.
    tiles: Vec<Vec<f32>>,
}

/// Pack prepared val features (all tasks) into kernel tiles for
/// [`scores_xla_rows`].
pub fn pack_val_tiles(info: &ModelInfo, val: &ValFeatures) -> ValTiles {
    assert_eq!(val.k, info.proj_dim);
    let (tv, k) = (info.tile_v, info.proj_dim);
    let nv_total = val.n();
    assert!(nv_total > 0, "no validation rows to pack");
    let mut tiles = vec![vec![0f32; tv * k]; nv_total.div_ceil(tv)];
    let mut task_of = Vec::with_capacity(nv_total);
    let mut inv_nv = Vec::with_capacity(val.n_tasks());
    let mut j = 0usize;
    for (t, task) in val.tasks.iter().enumerate() {
        inv_nv.push(1.0 / task.rows.len().max(1) as f32);
        for row in &task.rows {
            tiles[j / tv][(j % tv) * k..(j % tv + 1) * k].copy_from_slice(row);
            task_of.push(t);
            j += 1;
        }
    }
    ValTiles { task_of, inv_nv, tiles }
}

/// Mean cosine of each train row against each task's val rows via the AOT
/// kernel. Whole-block convenience wrapper over [`scores_xla_rows`];
/// row-major `[n × Q]` output.
pub fn scores_xla(
    rt: &Runtime,
    info: &ModelInfo,
    block: &CheckpointBlock,
    val: &ValFeatures,
) -> Result<Vec<f32>> {
    scores_xla_rows(rt, info, &block.rows(), &pack_val_tiles(info, val))
}

/// [`scores_xla`] over any row view (block or streamed shard). Same
/// contract as [`native::scores_rows`](super::native::scores_rows):
/// row-major `[n × Q]` scores, one entry per (train row, task).
pub fn scores_xla_rows(
    rt: &Runtime,
    info: &ModelInfo,
    rows_view: &RowsView<'_>,
    val_tiles: &ValTiles,
) -> Result<Vec<f32>> {
    assert_eq!(rows_view.k, info.proj_dim);
    let exec = rt.exec(info, "influence")?;
    let (tq, tv, k) = (info.tile_q, info.tile_v, info.proj_dim);
    let nv = val_tiles.task_of.len();
    let q = val_tiles.inv_nv.len();
    let n = rows_view.n();

    let mut scores = vec![0f32; n * q];
    let mut qt = vec![0f32; tq * k];
    for tile_start in (0..n).step_by(tq) {
        let rows = (n - tile_start).min(tq);
        qt.iter_mut().for_each(|x| *x = 0.0);
        for r in 0..rows {
            let row = rows_view.row_f32(tile_start + r); // codes×scale — scale cancels
            qt[r * k..(r + 1) * k].copy_from_slice(&row);
        }
        for (jt, vt) in val_tiles.tiles.iter().enumerate() {
            let out = exec.run(&[Arg::F32(&qt, &[tq, k]), Arg::F32(vt, &[tv, k])])?;
            let sims = &out[0]; // [tq, tv]
            let val_rows = (nv - jt * tv).min(tv);
            for r in 0..rows {
                let base = (tile_start + r) * q;
                for c in 0..val_rows {
                    let t = val_tiles.task_of[jt * tv + c];
                    scores[base + t] += sims[r * tv + c];
                }
            }
        }
    }
    // mean over each task's val rows
    for chunk in scores.chunks_exact_mut(q) {
        for (s, &inv) in chunk.iter_mut().zip(&val_tiles.inv_nv) {
            *s *= inv;
        }
    }
    Ok(scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Precision, Scheme};
    use crate::util::prop::{normal_features, seeded_datastore};
    use std::path::PathBuf;

    fn rt() -> Option<Runtime> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Runtime::new(&p).unwrap())
    }

    #[test]
    fn xla_matches_native_dense() {
        let Some(rt) = rt() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let info = rt.model("tiny").unwrap();
        let k = info.proj_dim;
        // n deliberately NOT a multiple of tile_q; nv not a multiple of tile_v
        let (n, nv) = (info.tile_q + 7, info.tile_v + 3);
        let vf = normal_features(nv, k, 22);
        let p = Precision::new(8, Scheme::Absmax).unwrap();

        let path = std::env::temp_dir().join(format!("qless_xla_{}.qlds", std::process::id()));
        let ds = seeded_datastore(&path, p, n, k, &[1.0], 21);
        let block = ds.load_checkpoint(0).unwrap();
        std::fs::remove_file(&path).ok();

        let val = ValFeatures::prepare(&vf, p);
        let native = crate::influence::native::scores_dense(&block, &val);
        let xla = scores_xla(&rt, &info, &block, &val).unwrap();
        assert_eq!(native.len(), xla.len());
        for (i, (a, b)) in native.iter().zip(&xla).enumerate() {
            assert!((a - b).abs() < 1e-4, "row {i}: native {a} xla {b}");
        }
    }

    #[test]
    fn xla_multi_task_matches_single_runs() {
        let Some(rt) = rt() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let info = rt.model("tiny").unwrap();
        let k = info.proj_dim;
        let n = info.tile_q + 3;
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let path = std::env::temp_dir().join(format!("qless_xlam_{}.qlds", std::process::id()));
        let ds = seeded_datastore(&path, p, n, k, &[1.0], 33);
        let block = ds.load_checkpoint(0).unwrap();
        std::fs::remove_file(&path).ok();

        // two tasks whose combined rows straddle a tile boundary
        let nva = (info.tile_v - 1).max(1);
        let t0 = normal_features(nva, k, 34);
        let t1 = normal_features(4, k, 35);
        let multi = ValFeatures::try_prepare_tasks(&[&t0, &t1], p).unwrap();
        let fused = scores_xla(&rt, &info, &block, &multi).unwrap();
        assert_eq!(fused.len(), n * 2);
        for (t, feat) in [&t0, &t1].into_iter().enumerate() {
            let single = ValFeatures::prepare(feat, p);
            let alone = scores_xla(&rt, &info, &block, &single).unwrap();
            for i in 0..n {
                assert!(
                    (alone[i] - fused[i * 2 + t]).abs() < 1e-5,
                    "task {t} row {i}: {} vs {}",
                    alone[i],
                    fused[i * 2 + t]
                );
            }
        }
    }
}
