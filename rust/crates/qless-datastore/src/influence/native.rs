//! Native influence paths: the integer-domain scoring engine for packed
//! 2/4/8-bit codes, the packed 1-bit XNOR+popcount kernel (its degenerate
//! case), and the generic f32 cosine reference.
//!
//! **Integer-domain scoring** (DESIGN.md §9): both sides of Eq. 7 are
//! quantized then L2-normalized, so the quantization scale cancels and the
//! cosine reduces to an integer code dot product times two precomputed
//! inverse norms:
//!
//! ```text
//! cos(t, v) = ⟨t, v⟩ / (‖t‖·‖v‖)        t, v ∈ {−α..α}^k integer codes
//! ```
//!
//! The engine dots the datastore's **stored** offset-binary lanes
//! (`s = t + α`) directly against validation codes with i32 accumulation
//! and removes the offset with one per-row zero-point fixup,
//! `⟨t, v⟩ = ⟨s, v⟩ − α·Σv` — no dequantization, no f32 normalization, no
//! per-element float math in the hot loop. At 1-bit the same algebra
//! degenerates to bit agreement, `cos = (2·agree − k)/k`, computed 64 dims
//! per instruction over packed words.
//!
//! **Multi-query scanning:** a [`ValFeatures`] is a *set* of validation
//! tasks. Every kernel scores one traversal of the train rows against all
//! tasks at once — the row's decode (unpack / dequantize / window
//! assembly) is paid once, and each task gets its own accumulator — and
//! returns the scores row-major: `out[i·Q + t]` is row `i` against task
//! `t`. A single-task set is the `Q = 1` case, with byte-identical scores
//! to the old per-task kernels.
//!
//! All kernels score a [`RowsView`] — a whole checkpoint block or one
//! streamed shard — so the block and streaming scan paths share one
//! per-row implementation and are bit-identical by construction. Row
//! parallelism runs on the persistent scan pool (`util::pool`): no
//! per-call thread spawns, no thread-count cap.

use std::cell::RefCell;

use crate::datastore::{CheckpointBlock, RowsView};
use crate::grads::FeatureMatrix;
use crate::influence::simd;
use crate::quant::pack::{as_sign_words, pack_codes, unpack_stored_slice};
use crate::quant::scheme::{normalize_row, quantize_row};
use crate::quant::Precision;
use crate::util::cpu::{self, Kernel};

/// One validation task's features, prepared for scoring at the datastore's
/// precision: quantized-normalized f32 rows (reference + XLA path), packed
/// sign words (1-bit path) and integer codes with precomputed sums and
/// inverse norms (integer-domain path).
#[derive(Debug, Clone, Default)]
pub struct ValTask {
    /// `[n_val][k]` quantized → normalized f32 rows.
    pub rows: Vec<Vec<f32>>,
    /// Packed sign words per row (populated only at 1-bit).
    pub sign_words: Vec<Vec<u64>>,
    /// Packed sign *bytes* per row (`⌈k/8⌉` each; populated only at
    /// 1-bit) — the byte-level twin of [`Self::sign_words`]. The blocked
    /// and SIMD XNOR kernels dot these against the packed train-row bytes
    /// directly, no word assembly per row.
    pub sign_bytes: Vec<Vec<u8>>,
    /// Integer codes per row (populated only at 2/4/8-bit).
    pub codes: Vec<Vec<i8>>,
    /// Σ codes per row — the zero-point fixup term (2/4/8-bit only).
    pub code_sums: Vec<i32>,
    /// 1/‖codes‖₂ per row, 0.0 for all-zero rows (2/4/8-bit only).
    pub inv_norms: Vec<f32>,
}

impl ValTask {
    /// Number of validation rows in this task.
    pub fn n(&self) -> usize {
        self.rows.len()
    }
}

/// A set of validation tasks prepared for scoring at a given precision.
///
/// The multi-query scan scores every task in one streamed pass over the
/// datastore; a single task is simply the one-element set. Build with
/// [`ValFeatures::prepare`] / [`ValFeatures::try_prepare`] (one task) or
/// [`ValFeatures::try_prepare_tasks`] (many).
#[derive(Debug, Clone)]
pub struct ValFeatures {
    /// Projection dimension shared by every task and the datastore.
    pub k: usize,
    /// The prepared tasks, in caller order.
    pub tasks: Vec<ValTask>,
}

impl ValFeatures {
    /// Prepare a set of validation tasks (one [`FeatureMatrix`] per task,
    /// raw unquantized gradients) at the datastore's precision. Rejects
    /// non-finite features, empty tasks and mismatched `k` with a
    /// recoverable error — one bad task fails the scan, not the process.
    pub fn try_prepare_tasks(
        per_task: &[&FeatureMatrix],
        precision: Precision,
    ) -> anyhow::Result<ValFeatures> {
        anyhow::ensure!(!per_task.is_empty(), "no validation tasks to prepare");
        let k = per_task[0].k;
        let mut tasks = Vec::with_capacity(per_task.len());
        for (t, feats) in per_task.iter().enumerate() {
            anyhow::ensure!(
                feats.k == k,
                "validation task {t} has feature dim {} (expected {k})",
                feats.k
            );
            tasks.push(prepare_task(feats, precision, t)?);
        }
        Ok(ValFeatures { k, tasks })
    }

    /// Fallible single-task [`ValFeatures::prepare`]: rejects non-finite
    /// validation gradients with a recoverable error instead of aborting —
    /// the form `score_datastore` uses, so one NaN val gradient fails the
    /// scan, not the process.
    pub fn try_prepare(feats: &FeatureMatrix, precision: Precision) -> anyhow::Result<ValFeatures> {
        Self::try_prepare_tasks(&[feats], precision)
    }

    /// Quantize raw validation gradient features with the datastore's
    /// precision, then normalize (paper: "validation gradients are
    /// quantized and normalized, yielding q̂_{z'}"). Panics on non-finite
    /// input; callers with a `Result` path should use [`Self::try_prepare`].
    pub fn prepare(feats: &FeatureMatrix, precision: Precision) -> ValFeatures {
        Self::try_prepare(feats, precision).expect("preparing validation features")
    }

    /// Number of validation tasks in the set.
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Total validation rows across all tasks (the scan's work factor).
    pub fn n(&self) -> usize {
        self.tasks.iter().map(|t| t.n()).sum()
    }
}

/// Prepare one task's features (see [`ValFeatures::try_prepare_tasks`]).
fn prepare_task(feats: &FeatureMatrix, precision: Precision, t: usize) -> anyhow::Result<ValTask> {
    anyhow::ensure!(feats.n > 0, "validation task {t} has no rows");
    let mut task = ValTask::default();
    task.rows.reserve(feats.n);
    for i in 0..feats.n {
        let raw = feats.row(i);
        // checked for every bitwidth (16-bit skips quantize_row) so a
        // NaN val gradient can't poison every score silently
        if let Some(j) = raw.iter().position(|x| !x.is_finite()) {
            anyhow::bail!(
                "non-finite validation gradient feature {} at task {t} row {i} index {j}: \
                 rejected at preparation time",
                raw[j]
            );
        }
        let mut row: Vec<f32> = if precision.bits == 16 {
            raw.to_vec()
        } else {
            let q = quantize_row(raw, precision.bits, precision.scheme);
            let as_f32: Vec<f32> = q.codes.iter().map(|&c| c as f32).collect();
            if precision.bits == 1 {
                let packed = pack_codes(&q.codes, 1, q.scale).expect("pack 1-bit");
                task.sign_words.push(as_sign_words(&packed));
                task.sign_bytes.push(packed.bytes);
            } else {
                let sum: i64 = q.codes.iter().map(|&c| c as i64).sum();
                let norm2: i64 = q.codes.iter().map(|&c| (c as i64) * (c as i64)).sum();
                task.code_sums.push(sum as i32);
                task.inv_norms.push(if norm2 > 0 { 1.0 / (norm2 as f32).sqrt() } else { 0.0 });
                task.codes.push(q.codes);
            }
            as_f32
        };
        normalize_row(&mut row);
        task.rows.push(row);
    }
    Ok(task)
}

/// Mean cosine similarity of each train row against each task's val rows:
/// the inner term of Eq. 7 for one checkpoint. Whole-block convenience
/// wrapper over [`scores_dense_rows`]; row-major `[n × Q]` output.
pub fn scores_dense(block: &CheckpointBlock, val: &ValFeatures) -> Vec<f32> {
    scores_dense_rows(&block.rows(), val)
}

/// [`scores_dense`] over any row view (block or streamed shard). The
/// dequantize-to-f32 **reference** path — works for every precision by
/// unpacking codes to f32 and normalizing; the integer-domain and popcount
/// kernels are property-tested against it. Row-major `[n × Q]` output.
pub fn scores_dense_rows(rows: &RowsView<'_>, val: &ValFeatures) -> Vec<f32> {
    assert_eq!(rows.k, val.k);
    let q = val.n_tasks();
    assert!(q > 0, "no validation tasks");
    // work per row ≈ total-val·k fused-multiply-adds (plus unpack)
    par_over_rows(rows.n(), q, (val.n() * rows.k) as u64, |i, out| {
        let mut row = if rows.precision.bits == 16 {
            rows.row_f32(i)
        } else {
            rows.row_codes(i).iter().map(|&c| c as f32).collect()
        };
        normalize_row(&mut row);
        for (o, task) in out.iter_mut().zip(&val.tasks) {
            let mut acc = 0f32;
            for v in &task.rows {
                acc += dot(&row, v);
            }
            *o = acc / task.rows.len() as f32;
        }
    })
}

/// True iff the i32 inner accumulator of [`scores_int_rows`] cannot
/// overflow at this bitwidth and projection dimension: the stored-lane dot
/// is bounded by `k · 2α²`, which must stay below `i32::MAX`. At 8-bit
/// this allows k ≤ 66 572 — far beyond the paper's k = 8192; the scan
/// dispatch falls back to the f32 path past the bound.
pub fn int_dot_fits(bits: u8, k: usize) -> bool {
    if !matches!(bits, 2 | 4 | 8) {
        return false;
    }
    let alpha = (1u64 << (bits - 1)) - 1;
    (k as u64) <= (i32::MAX as u64) / (2 * alpha * alpha)
}

thread_local! {
    /// Per-thread scratch for one row's unpacked stored lanes (2/4-bit).
    static STORED_SCRATCH: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
    /// Per-thread per-task agreement counters (1-bit kernel).
    static AGREE_SCRATCH: RefCell<Vec<i64>> = const { RefCell::new(Vec::new()) };
    /// Per-thread tile scratch for the blocked integer kernel.
    static INT_TILE: RefCell<IntTile> = RefCell::new(IntTile::default());
    /// Per-thread per-row agreement counters (blocked 1-bit kernel).
    static BIT_TILE: RefCell<Vec<i64>> = const { RefCell::new(Vec::new()) };
}

/// Reused buffers for one tile of the blocked integer kernel: the
/// unpacked stored lanes (`tile × k` at 2/4-bit; 8-bit borrows the view's
/// bytes), the per-row inverse norms, and the per-row f32 accumulators of
/// the task currently being scored.
#[derive(Default)]
struct IntTile {
    lanes: Vec<u8>,
    inv_norms: Vec<f32>,
    acc: Vec<f32>,
}

/// Rows per scan tile for a row whose decoded working set is
/// `bytes_per_row`: targets ~16 KiB of row data resident in L1 while a
/// tile is re-dotted against every task column, clamped to `[4, 64]` so
/// tiny rows still amortize loop overhead and huge rows (k > 4096) keep
/// at least a few rows per tile. Derivation in DESIGN.md §11.
pub fn tile_rows(bytes_per_row: usize) -> usize {
    (16 * 1024 / bytes_per_row.max(1)).clamp(4, 64)
}

/// The integer-domain scoring engine for 2/4/8-bit datastores.
///
/// Per train row: unpack the stored offset-binary lanes once (8-bit rows
/// are borrowed directly — the lanes *are* the row bytes), derive the
/// row's integer norm from lane sums via
/// `‖t‖² = Σs² − 2αΣs + kα²`, then for every validation row of every task
/// accumulate the integer dot `⟨s, v⟩` in i32 and apply the zero-point
/// fixup `⟨t, v⟩ = ⟨s, v⟩ − α·Σv` (Σv is precomputed in
/// [`ValTask::code_sums`]). The only float ops per (row, val-row) pair are
/// one i32→f32 conversion and one multiply by the val row's precomputed
/// inverse norm — no dequantization, no f32 normalization, 1/4 (8-bit) to
/// 1/16 (2-bit) the memory traffic of the f32 reference path.
///
/// Row-major `[n × Q]` output; panics if `!int_dot_fits(bits, k)` —
/// callers should dispatch through [`scores_rows`], which falls back to
/// the f32 path instead.
pub fn scores_int_rows(rows: &RowsView<'_>, val: &ValFeatures) -> Vec<f32> {
    let bits = rows.precision.bits;
    assert!(matches!(bits, 2 | 4 | 8), "integer path needs a 2/4/8-bit datastore");
    assert_eq!(rows.k, val.k);
    assert!(int_dot_fits(bits, rows.k), "k {} overflows the i32 dot at {bits}-bit", rows.k);
    let q = val.n_tasks();
    assert!(q > 0, "no validation tasks");
    for (t, task) in val.tasks.iter().enumerate() {
        assert!(!task.codes.is_empty(), "task {t} lacks integer codes");
    }
    let k = rows.k;
    let alpha = ((1i32 << (bits - 1)) - 1) as i64;
    // work per row ≈ total-val·k integer multiply-adds (plus unpack)
    par_over_rows(rows.n(), q, (val.n() * k) as u64, |i, out| {
        STORED_SCRATCH.with(|cell| {
            let mut buf = cell.borrow_mut();
            let stored: &[u8] = if bits == 8 {
                // 8-bit lanes are the row bytes themselves (stride == k)
                rows.row_bytes(i)
            } else {
                rows.row_stored_into(i, &mut buf);
                &buf[..k]
            };
            // row norm from lane sums: ‖t‖² = Σs² − 2αΣs + kα²
            let mut sum_s = 0i64;
            let mut sum_s2 = 0i64;
            for &s in stored {
                let s = s as i64;
                sum_s += s;
                sum_s2 += s * s;
            }
            let norm2 = sum_s2 - 2 * alpha * sum_s + k as i64 * alpha * alpha;
            let inv_norm_t = if norm2 > 0 { 1.0 / (norm2 as f32).sqrt() } else { 0.0 };
            for (o, task) in out.iter_mut().zip(&val.tasks) {
                let mut acc = 0f32;
                for ((codes, &csum), &inv_norm_v) in
                    task.codes.iter().zip(&task.code_sums).zip(&task.inv_norms)
                {
                    let mut dot_s = 0i32;
                    for (&s, &c) in stored.iter().zip(codes.iter()) {
                        dot_s += s as i32 * c as i32;
                    }
                    // zero-point fixup: ⟨t, v⟩ = ⟨s, v⟩ − α·Σv
                    let dot_tv = dot_s as i64 - alpha * csum as i64;
                    acc += dot_tv as f32 * inv_norm_v;
                }
                *o = acc * inv_norm_t / task.codes.len() as f32;
            }
        })
    })
}

/// The blocked (rows×tasks-tiled) integer engine: [`scores_int_rows`]
/// restructured so a tile of up to [`tile_rows`]`(k)` rows is unpacked
/// once into an L1-resident lane buffer and dotted against **every**
/// validation row of every task before eviction — the per-row val-code
/// traffic of the unblocked loop (Q·nv·k bytes per train row) collapses
/// to once per tile. The inner dot runs through [`simd`] for `kernel`
/// (scalar for [`Kernel::Blocked`], intrinsics for
/// [`Kernel::Avx2`]/[`Kernel::Neon`]).
///
/// **Bit-exact** vs the scalar reference: integer dots are exact in any
/// order, and each row's f32 accumulator receives the same values in the
/// same validation-row order with the same final
/// `acc · inv_norm_t / nv` arithmetic (DESIGN.md §11).
/// Row-major `[n × Q]` output; same preconditions as [`scores_int_rows`].
pub fn scores_int_rows_blocked(rows: &RowsView<'_>, val: &ValFeatures, kernel: Kernel) -> Vec<f32> {
    let bits = rows.precision.bits;
    assert!(matches!(bits, 2 | 4 | 8), "integer path needs a 2/4/8-bit datastore");
    assert_eq!(rows.k, val.k);
    assert!(int_dot_fits(bits, rows.k), "k {} overflows the i32 dot at {bits}-bit", rows.k);
    let q = val.n_tasks();
    assert!(q > 0, "no validation tasks");
    for (t, task) in val.tasks.iter().enumerate() {
        assert!(!task.codes.is_empty(), "task {t} lacks integer codes");
    }
    let k = rows.k;
    let stride = rows.row_stride;
    let alpha = ((1i32 << (bits - 1)) - 1) as i64;
    let tile = tile_rows(k);
    par_over_row_blocks(rows.n(), q, tile, (val.n() * k) as u64, |start, out_block| {
        let nb = out_block.len() / q;
        INT_TILE.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let IntTile { lanes, inv_norms, acc } = &mut *scratch;
            // decode the tile once: 8-bit lanes are the row bytes
            // themselves (stride == k), 2/4-bit unpack into the scratch
            let stored_block: &[u8] = if bits == 8 {
                &rows.data[start * stride..(start + nb) * stride]
            } else {
                lanes.resize(nb * k, 0);
                for r in 0..nb {
                    unpack_stored_slice(
                        rows.row_bytes(start + r),
                        bits,
                        &mut lanes[r * k..(r + 1) * k],
                    );
                }
                lanes
            };
            // per-row norms from lane sums: ‖t‖² = Σs² − 2αΣs + kα²
            inv_norms.clear();
            for r in 0..nb {
                let mut sum_s = 0i64;
                let mut sum_s2 = 0i64;
                for &s in &stored_block[r * k..(r + 1) * k] {
                    let s = s as i64;
                    sum_s += s;
                    sum_s2 += s * s;
                }
                let norm2 = sum_s2 - 2 * alpha * sum_s + k as i64 * alpha * alpha;
                inv_norms.push(if norm2 > 0 { 1.0 / (norm2 as f32).sqrt() } else { 0.0 });
            }
            for (t, task) in val.tasks.iter().enumerate() {
                acc.clear();
                acc.resize(nb, 0f32);
                for ((codes, &csum), &inv_norm_v) in
                    task.codes.iter().zip(&task.code_sums).zip(&task.inv_norms)
                {
                    // the val row's codes stay register/L1-hot across the
                    // whole tile; accumulation order per row matches the
                    // scalar reference (val rows in task order)
                    for r in 0..nb {
                        let dot_s = simd::int_dot(kernel, &stored_block[r * k..(r + 1) * k], codes);
                        // zero-point fixup: ⟨t, v⟩ = ⟨s, v⟩ − α·Σv
                        let dot_tv = dot_s as i64 - alpha * csum as i64;
                        acc[r] += dot_tv as f32 * inv_norm_v;
                    }
                }
                let nv = task.codes.len() as f32;
                for r in 0..nb {
                    out_block[r * q + t] = acc[r] * inv_norms[r] / nv;
                }
            }
        })
    })
}

/// Score with the fastest applicable native path for the view's
/// precision — [`scores_rows_with`] at the process's active kernel
/// variant ([`cpu::active`]) — and publish per-variant per-bitwidth
/// `kernel_scan_rows_total` counters to the calling thread's registry.
/// Row-major `[n × Q]` output. This is the dispatch the streamed scan
/// (`influence::score_datastore_tasks`) uses per shard.
pub fn scores_rows(rows: &RowsView<'_>, val: &ValFeatures) -> Vec<f32> {
    let kernel = cpu::active();
    let out = scores_rows_with(rows, val, kernel);
    crate::util::obs::counter_add(
        &format!(
            "kernel_scan_rows_total{{variant=\"{}\",bits=\"{}\"}}",
            kernel.label(),
            rows.precision.bits
        ),
        rows.n() as u64,
    );
    out
}

/// [`scores_rows`] pinned to an explicit kernel variant: XNOR+popcount at
/// 1-bit, the integer-domain engine at 2/4/8-bit (f32 fallback past the
/// i32 overflow bound), the f32 path at 16-bit. [`Kernel::Scalar`] takes
/// the original unblocked reference loops; every other variant takes the
/// blocked loops with `kernel`'s inner dot. The equality property tests
/// and `bench_influence` call this directly to sweep variants; production
/// goes through [`scores_rows`].
pub fn scores_rows_with(rows: &RowsView<'_>, val: &ValFeatures, kernel: Kernel) -> Vec<f32> {
    match rows.precision.bits {
        1 => match kernel {
            Kernel::Scalar => scores_1bit_rows(rows, val),
            k => scores_1bit_rows_blocked(rows, val, k),
        },
        b if int_dot_fits(b, rows.k) => match kernel {
            Kernel::Scalar => scores_int_rows(rows, val),
            k => scores_int_rows_blocked(rows, val, k),
        },
        _ => scores_dense_rows(rows, val),
    }
}

/// Evaluate `f(i, out_chunk)` for each row index in parallel
/// (order-preserving), filling a row-major `[n × width]` output.
///
/// `work_per_row` is an estimate of the inner-op count per row; jobs below
/// ~8M total ops stay serial — handing a 1.4ms popcount scan to the pool
/// costs more in wakeup latency than it saves (§Perf iteration 2 measured
/// the same effect with spawned threads at 2.6× worse). Larger jobs run on
/// the persistent worker pool: threads follow `QLESS_SCORE_THREADS` or the
/// machine's full parallelism (the old hard cap of 16 is gone), and rows
/// are claimed from a shared cursor so uneven rows can't straggle.
/// `QLESS_SCORE_THREADS=1` forces the serial path (before/after benches).
fn par_over_rows<F: Fn(usize, &mut [f32]) + Sync>(
    n: usize,
    width: usize,
    work_per_row: u64,
    f: F,
) -> Vec<f32> {
    assert!(width >= 1);
    let mut out = vec![0f32; n * width];
    let threads = crate::util::pool::scan_threads().min(n.max(1));
    if threads <= 1 || n < 256 || (n as u64).saturating_mul(work_per_row) < 8_000_000 {
        for (i, row) in out.chunks_exact_mut(width).enumerate() {
            f(i, row);
        }
        return out;
    }
    crate::util::pool::par_fill_rows(&mut out, width, &f);
    out
}

/// Blocked twin of [`par_over_rows`]: evaluate `f(start_row, out_block)`
/// per tile of up to `tile` consecutive rows (the final tile may be
/// short), filling a row-major `[n × width]` output. Same serial
/// thresholds as the per-row engine — the blocked loop structure is used
/// either way; only the parallel grain changes (whole tiles, so a tile's
/// decode is never split across participants).
fn par_over_row_blocks<F: Fn(usize, &mut [f32]) + Sync>(
    n: usize,
    width: usize,
    tile: usize,
    work_per_row: u64,
    f: F,
) -> Vec<f32> {
    assert!(width >= 1 && tile >= 1);
    let mut out = vec![0f32; n * width];
    let threads = crate::util::pool::scan_threads().min(n.max(1));
    if threads <= 1 || n < 256 || (n as u64).saturating_mul(work_per_row) < 8_000_000 {
        for (b, block) in out.chunks_mut(tile * width).enumerate() {
            f(b * tile, block);
        }
        return out;
    }
    crate::util::pool::par_fill_row_blocks(&mut out, width, tile, &f);
    out
}

/// The 1-bit fast path: XNOR+popcount over packed words, no unpacking.
/// Whole-block convenience wrapper over [`scores_1bit_rows`];
/// row-major `[n × Q]` output.
pub fn scores_1bit(block: &CheckpointBlock, val: &ValFeatures) -> Vec<f32> {
    scores_1bit_rows(&block.rows(), val)
}

/// [`scores_1bit`] over any row view. Identical results to
/// [`scores_dense_rows`] on a 1-bit view (up to fp rounding of the final
/// division) — the degenerate case of the integer engine where the code
/// dot collapses to bit agreement. Streams each row through a fixed
/// 64-word stack window, so any projection dimension is supported — the
/// seed implementation sliced a `[u64; 64]` buffer by `k/64` words and
/// panicked for k > 4096. Each window is assembled once and scored against
/// every task's sign words (per-task agreement counters), so a multi-query
/// scan pays the byte shuffling once per row. Row-major `[n × Q]` output.
pub fn scores_1bit_rows(rows: &RowsView<'_>, val: &ValFeatures) -> Vec<f32> {
    assert_eq!(rows.precision.bits, 1, "1-bit path needs a sign datastore");
    assert_eq!(rows.k, val.k);
    let q = val.n_tasks();
    assert!(q > 0, "no validation tasks");
    for (t, task) in val.tasks.iter().enumerate() {
        assert!(!task.sign_words.is_empty(), "task {t} lacks sign words");
    }
    let k = rows.k;
    let nwords = k.div_ceil(64);
    let tail = (nwords * 64 - k) as i64;
    let inv_k = 1.0 / k as f32;

    // work per row ≈ total-val·nwords popcount iterations (~1.4 ns each —
    // tiny; this path only crosses the parallel threshold at ≫10⁴ rows)
    par_over_rows(rows.n(), q, (val.n() * nwords) as u64, |i, out| {
        let row = rows.row_bytes(i);
        AGREE_SCRATCH.with(|cell| {
            let mut agree = cell.borrow_mut();
            agree.clear();
            agree.resize(q, 0i64);
            // Bit agreement is summed exactly in i64 across each task's val
            // rows and words; per-val-row dot products are linear in
            // agreement, so one conversion per task at the end loses
            // nothing:  Σ_v dot_v = 2·(Σ_v agree_v − nv·tail) − nv·k
            let mut word_base = 0usize;
            // 512-byte (64-word) window: fixed stack buffer, unbounded k
            for byte_chunk in row.chunks(512) {
                let mut words = [0u64; 64];
                let cw = byte_chunk.len().div_ceil(8);
                for (w, ch) in words.iter_mut().zip(byte_chunk.chunks(8)) {
                    let mut b = [0u8; 8];
                    b[..ch.len()].copy_from_slice(ch);
                    *w = u64::from_le_bytes(b);
                }
                for (a, task) in agree.iter_mut().zip(&val.tasks) {
                    for v in &task.sign_words {
                        for (x, y) in words[..cw].iter().zip(&v[word_base..word_base + cw]) {
                            *a += (!(x ^ y)).count_ones() as i64;
                        }
                    }
                }
                word_base += cw;
            }
            // remove the always-agreeing zero tail, convert to mean cosine
            for ((o, &a), task) in out.iter_mut().zip(agree.iter()).zip(&val.tasks) {
                let nv = task.sign_words.len();
                let total_dot = 2 * (a - nv as i64 * tail) - (nv * k) as i64;
                *o = (total_dot as f32 * inv_k) / nv as f32;
            }
        })
    })
}

/// The blocked (rows×tasks-tiled) 1-bit kernel: XNOR+popcount straight on
/// the packed row *bytes* against [`ValTask::sign_bytes`], a tile of rows
/// against every task's val rows before eviction, with `kernel`'s agree
/// primitive ([`simd::xnor_agree`]).
///
/// **Bit-exact** vs [`scores_1bit_rows`]: agreement is an exact integer
/// in any order, and the byte-level tail fixup
/// (`tail₈ = row_stride·8 − k`) yields the identical total dot as the
/// reference's word-level fixup (`tail₆₄ = ⌈k/64⌉·64 − k`) because both
/// sides zero-pad, so every phantom position agrees and
/// `2·(agree − nv·tail) − nv·k` is invariant to the padded length
/// (DESIGN.md §11). The final f32 ops match the reference exactly.
/// Row-major `[n × Q]` output; same preconditions as
/// [`scores_1bit_rows`].
pub fn scores_1bit_rows_blocked(
    rows: &RowsView<'_>,
    val: &ValFeatures,
    kernel: Kernel,
) -> Vec<f32> {
    assert_eq!(rows.precision.bits, 1, "1-bit path needs a sign datastore");
    assert_eq!(rows.k, val.k);
    let q = val.n_tasks();
    assert!(q > 0, "no validation tasks");
    for (t, task) in val.tasks.iter().enumerate() {
        assert!(!task.sign_bytes.is_empty(), "task {t} lacks sign bytes");
    }
    let k = rows.k;
    let stride = rows.row_stride;
    let tail = (stride * 8 - k) as i64;
    let inv_k = 1.0 / k as f32;
    let tile = tile_rows(stride);
    par_over_row_blocks(rows.n(), q, tile, (val.n() * k.div_ceil(64)) as u64, |start, out_block| {
        let nb = out_block.len() / q;
        BIT_TILE.with(|cell| {
            let mut agree = cell.borrow_mut();
            for (t, task) in val.tasks.iter().enumerate() {
                agree.clear();
                agree.resize(nb, 0i64);
                for v in &task.sign_bytes {
                    // the val row's packed bytes stay L1-hot across the
                    // whole tile of train rows
                    for (r, a) in agree.iter_mut().enumerate() {
                        *a += simd::xnor_agree(kernel, rows.row_bytes(start + r), v) as i64;
                    }
                }
                // remove the always-agreeing zero tail, convert to mean
                // cosine — identical arithmetic to the scalar reference
                let nv = task.sign_bytes.len();
                for (r, &a) in agree.iter().enumerate() {
                    let total_dot = 2 * (a - nv as i64 * tail) - (nv * k) as i64;
                    out_block[r * q + t] = (total_dot as f32 * inv_k) / nv as f32;
                }
            }
        })
    })
}

/// 4-way unrolled f32 dot product (autovectorizes well) — the inner op of
/// the f32 reference path.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Scheme;
    use crate::util::prop::{normal_features as feats, seeded_datastore};
    use crate::util::Rng;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "qless_inf_{tag}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    fn make_block(bits: u8, n: usize, k: usize, seed: u64) -> CheckpointBlock {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = tmpfile(&format!("b{bits}_{seed}"));
        let ds = seeded_datastore(&path, p, n, k, &[1.0], seed);
        let block = ds.load_checkpoint(0).unwrap();
        std::fs::remove_file(&path).ok();
        block
    }

    #[test]
    fn dense_scores_bounded_and_finite() {
        for bits in [16u8, 8, 4, 2, 1] {
            let block = make_block(bits, 12, 96, 1);
            let val = ValFeatures::prepare(
                &feats(5, 96, 2),
                Precision::new(bits, if bits == 1 { Scheme::Sign } else { Scheme::Absmax })
                    .unwrap(),
            );
            let s = scores_dense(&block, &val);
            assert_eq!(s.len(), 12);
            assert!(s.iter().all(|x| x.is_finite() && x.abs() <= 1.0 + 1e-5), "{bits}: {s:?}");
        }
    }

    #[test]
    fn popcount_matches_dense_exactly() {
        for (k, seed) in [(64usize, 3u64), (96, 4), (128, 5), (65, 6), (512, 7)] {
            let block = make_block(1, 10, k, seed);
            let val = ValFeatures::prepare(
                &feats(7, k, seed + 100),
                Precision::new(1, Scheme::Sign).unwrap(),
            );
            let dense = scores_dense(&block, &val);
            let fast = scores_1bit(&block, &val);
            for (a, b) in dense.iter().zip(&fast) {
                assert!((a - b).abs() < 1e-5, "k={k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn int_matches_dense_all_bitwidths_and_schemes() {
        // The integer-domain engine must track the dequantize-f32 reference
        // at every supported bitwidth × scheme (the full property-level
        // sweep lives in tests/int_scoring.rs).
        for bits in [8u8, 4, 2] {
            for scheme in [Scheme::Absmax, Scheme::Absmean] {
                let p = Precision::new(bits, scheme).unwrap();
                let path = tmpfile(&format!("int{bits}_{scheme}"));
                let (n, k) = (9usize, 97usize);
                let ds = seeded_datastore(&path, p, n, k, &[1.0], 31);
                let block = ds.load_checkpoint(0).unwrap();
                std::fs::remove_file(&path).ok();
                let val = ValFeatures::prepare(&feats(4, k, 32), p);
                let dense = scores_dense(&block, &val);
                let fast = scores_int_rows(&block.rows(), &val);
                assert_eq!(dense.len(), fast.len());
                for (i, (a, b)) in dense.iter().zip(&fast).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "{bits}-bit {scheme} row {i}: dense {a} vs int {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_task_scores_equal_single_task_runs() {
        // One multi-query traversal must give byte-identical scores to Q
        // independent single-task runs, for every kernel path.
        let k = 128;
        for bits in [16u8, 8, 4, 2, 1] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            let block = make_block(bits, 20, k, 40);
            let t0 = feats(3, k, 41);
            let t1 = feats(5, k, 42);
            let t2 = feats(1, k, 43);
            let multi = ValFeatures::try_prepare_tasks(&[&t0, &t1, &t2], p).unwrap();
            let q = multi.n_tasks();
            assert_eq!(q, 3);
            let fused = scores_rows(&block.rows(), &multi);
            assert_eq!(fused.len(), 20 * q);
            for (t, feat) in [&t0, &t1, &t2].into_iter().enumerate() {
                let single = ValFeatures::prepare(feat, p);
                let alone = scores_rows(&block.rows(), &single);
                for i in 0..20 {
                    assert_eq!(
                        alone[i],
                        fused[i * q + t],
                        "bits {bits} task {t} row {i}: single vs fused"
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_and_simd_variants_match_scalar_bitwise() {
        // Every non-reference variant (blocked scalar and whatever SIMD
        // this machine has) must produce bit-identical scores to the
        // pinned scalar reference at every packed bitwidth — the full
        // bitwidth × scheme × k property grid lives in tests/kernels.rs.
        for bits in [1u8, 2, 4, 8] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            for k in [64usize, 97, 513] {
                let block = make_block(bits, 77, k, 50 + bits as u64 + k as u64);
                let t0 = feats(3, k, 51);
                let t1 = feats(2, k, 52);
                let val = ValFeatures::try_prepare_tasks(&[&t0, &t1], p).unwrap();
                let reference = scores_rows_with(&block.rows(), &val, Kernel::Scalar);
                for kernel in cpu::available() {
                    let got = scores_rows_with(&block.rows(), &val, kernel);
                    assert_eq!(got.len(), reference.len());
                    for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "bits {bits} k {k} kernel {} idx {i}: {a} vs {b}",
                            kernel.label()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tile_rows_targets_l1_and_clamps() {
        assert_eq!(tile_rows(512), 32); // 8-bit k=512: 32 rows × 512 B = 16 KiB
        assert_eq!(tile_rows(64), 64); // tiny rows clamp at 64
        assert_eq!(tile_rows(1), 64);
        assert_eq!(tile_rows(0), 64); // degenerate guard
        assert_eq!(tile_rows(16 * 1024), 4); // huge rows clamp at 4
        assert_eq!(tile_rows(8192), 4); // 8-bit k=8192 (paper scale)
    }

    #[test]
    fn int_dot_bound_is_sane() {
        assert!(int_dot_fits(8, 8192)); // paper scale
        // exact 8-bit bound: ⌊i32::MAX / (2·127²)⌋ = ⌊2147483647/32258⌋
        assert!(int_dot_fits(8, 66_572));
        assert!(!int_dot_fits(8, 66_573));
        assert!(int_dot_fits(4, 1 << 20));
        assert!(int_dot_fits(2, 1 << 28));
        assert!(!int_dot_fits(1, 64)); // popcount path, not int
        assert!(!int_dot_fits(16, 64)); // f32 path
    }

    #[test]
    fn popcount_k8192_regression() {
        // Seed code copied each row into a fixed `[0u64; 64]` buffer and
        // sliced `words[..nwords]` — nwords = 128 at k = 8192, so the
        // release build panicked (and debug builds tripped the
        // debug_assert). The windowed kernel must handle any k and still
        // match the dense path.
        let k = 8192;
        let block = make_block(1, 4, k, 42);
        let val =
            ValFeatures::prepare(&feats(3, k, 43), Precision::new(1, Scheme::Sign).unwrap());
        let dense = scores_dense(&block, &val);
        let fast = scores_1bit(&block, &val);
        assert_eq!(fast.len(), 4);
        for (a, b) in dense.iter().zip(&fast) {
            assert!((a - b).abs() < 1e-5, "k=8192: {a} vs {b}");
        }
    }

    #[test]
    fn shard_views_score_identically_to_block() {
        // The kernels take a RowsView; a sub-view over the same bytes must
        // give bit-identical scores to the whole block's rows.
        for bits in [16u8, 8, 1] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let block = make_block(bits, 12, 96, 8);
            let val = ValFeatures::prepare(&feats(5, 96, 9), Precision::new(bits, scheme).unwrap());
            let whole = scores_rows(&block.rows(), &val);
            // split the block's rows into two shard-like views
            let full = block.rows();
            let split = 5usize;
            for (start, end) in [(0usize, split), (split, 12)] {
                let view = RowsView {
                    precision: full.precision,
                    k: full.k,
                    row_stride: full.row_stride,
                    scales: if bits == 16 {
                        full.scales
                    } else {
                        &full.scales[start..end]
                    },
                    data: &full.data[start * full.row_stride..end * full.row_stride],
                };
                let part = scores_rows(&view, &val);
                assert_eq!(part.as_slice(), &whole[start..end], "bits {bits} [{start},{end})");
            }
        }
    }

    #[test]
    fn self_similarity_ranks_first() {
        // A train row identical to the single val row must get score 1.
        let k = 128;
        let f = feats(6, k, 9);
        let val_raw = FeatureMatrix { n: 1, k, data: f.row(3).to_vec() };
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let block = make_block(8, 6, k, 9);
        let val = ValFeatures::prepare(&val_raw, p);
        let s = scores_dense(&block, &val);
        let best = s.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap();
        assert_eq!(best.0, 3);
        assert!(*best.1 > 0.99, "{s:?}");
    }

    #[test]
    fn scale_cancels_in_scoring() {
        // Scaling raw val features must not change prepared rows.
        let k = 64;
        let f = feats(3, k, 11);
        let scaled = FeatureMatrix { n: 3, k, data: f.data.iter().map(|x| x * 123.0).collect() };
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let a = ValFeatures::prepare(&f, p);
        let b = ValFeatures::prepare(&scaled, p);
        for (ra, rb) in a.tasks[0].rows.iter().zip(&b.tasks[0].rows) {
            for (x, y) in ra.iter().zip(rb) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn prepare_rejects_empty_and_mismatched_tasks() {
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let good = feats(2, 64, 1);
        let empty = FeatureMatrix { n: 0, k: 64, data: vec![] };
        let otherk = feats(2, 32, 2);
        assert!(ValFeatures::try_prepare_tasks(&[], p).is_err());
        assert!(ValFeatures::try_prepare_tasks(&[&good, &empty], p).is_err());
        assert!(ValFeatures::try_prepare_tasks(&[&good, &otherk], p).is_err());
        assert_eq!(ValFeatures::try_prepare_tasks(&[&good, &good], p).unwrap().n(), 4);
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(12);
        let a: Vec<f32> = (0..103).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..103).map(|_| rng.normal() as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }
}
