//! Quantization-bin occupancy histograms — reproduces paper Figure 3
//! (absmax vs absmean value distributions; the zero-bin sparsity effect).

use super::scheme::{quantize_row, Scheme};

/// Occupancy counts over the 2α+1 integer bins of a bit width (or the two
/// bins of sign quantization).
#[derive(Debug, Clone)]
pub struct BinHistogram {
    /// Quantization bit width the histogram bins.
    pub bits: u8,
    /// Scheme used when quantizing added rows.
    pub scheme: Scheme,
    /// counts[i] = occurrences of code (i − α); for 1-bit: [−1, +1].
    pub counts: Vec<u64>,
    /// Total codes accumulated across all added rows.
    pub total: u64,
}

impl BinHistogram {
    /// Empty histogram over the bit width's `2α+1` bins (2 bins at 1-bit).
    pub fn new(bits: u8, scheme: Scheme) -> BinHistogram {
        let nbins = if bits == 1 { 2 } else { (1usize << bits) - 1 };
        BinHistogram { bits, scheme, counts: vec![0; nbins], total: 0 }
    }

    /// The bit width's α (max |code|); 1 at 1-bit.
    pub fn alpha(&self) -> i32 {
        if self.bits == 1 {
            1
        } else {
            (1i32 << (self.bits - 1)) - 1
        }
    }

    /// Quantize a feature row with this histogram's scheme and accumulate.
    pub fn add_row(&mut self, g: &[f32]) {
        let q = quantize_row(g, self.bits, self.scheme);
        self.add_codes(&q.codes);
    }

    /// Accumulate already-quantized codes into the bins.
    pub fn add_codes(&mut self, codes: &[i8]) {
        let alpha = self.alpha();
        for &c in codes {
            let idx = if self.bits == 1 {
                usize::from(c > 0)
            } else {
                (c as i32 + alpha) as usize
            };
            self.counts[idx] += 1;
            self.total += 1;
        }
    }

    /// Fraction of codes in the zero bin (the paper's sparsity measure).
    /// 1-bit has no zero bin → always 0.
    pub fn zero_bin_frac(&self) -> f64 {
        if self.bits == 1 || self.total == 0 {
            return 0.0;
        }
        self.counts[self.alpha() as usize] as f64 / self.total as f64
    }

    /// Fraction of nonzero codes ("density" of the representation).
    pub fn density(&self) -> f64 {
        1.0 - self.zero_bin_frac()
    }

    /// Render as `code -> fraction` rows (Fig. 3 series).
    pub fn rows(&self) -> Vec<(i32, f64)> {
        let alpha = self.alpha();
        if self.bits == 1 {
            return vec![
                (-1, self.counts[0] as f64 / self.total.max(1) as f64),
                (1, self.counts[1] as f64 / self.total.max(1) as f64),
            ];
        }
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as i32 - alpha, c as f64 / self.total.max(1) as f64))
            .collect()
    }

    /// Sparkline-ish ASCII rendering for console reports.
    pub fn ascii(&self) -> String {
        let rows = self.rows();
        let max = rows.iter().map(|r| r.1).fold(0.0, f64::max).max(1e-12);
        rows.iter()
            .map(|(code, frac)| {
                let bar = "#".repeat((frac / max * 40.0).round() as usize);
                format!("{code:>5}: {bar} {:.1}%", frac * 100.0)
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gaussian_row(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn bin_count_shapes() {
        assert_eq!(BinHistogram::new(1, Scheme::Sign).counts.len(), 2);
        assert_eq!(BinHistogram::new(2, Scheme::Absmax).counts.len(), 3);
        assert_eq!(BinHistogram::new(4, Scheme::Absmax).counts.len(), 15);
        assert_eq!(BinHistogram::new(8, Scheme::Absmax).counts.len(), 255);
    }

    #[test]
    fn totals_accumulate() {
        let mut h = BinHistogram::new(4, Scheme::Absmax);
        h.add_row(&gaussian_row(256, 1));
        h.add_row(&gaussian_row(256, 2));
        assert_eq!(h.total, 512);
        assert_eq!(h.counts.iter().sum::<u64>(), 512);
    }

    #[test]
    fn paper_fig3_absmax_sparser_than_absmean() {
        // Gaussian features at 2-bit: absmax puts most mass in the zero bin,
        // absmean pushes it out (paper §5).
        let mut hmax = BinHistogram::new(2, Scheme::Absmax);
        let mut hmean = BinHistogram::new(2, Scheme::Absmean);
        for s in 0..20 {
            let row = gaussian_row(512, s);
            hmax.add_row(&row);
            hmean.add_row(&row);
        }
        assert!(hmax.zero_bin_frac() > 0.5, "absmax zero bin {}", hmax.zero_bin_frac());
        assert!(
            hmean.zero_bin_frac() < hmax.zero_bin_frac(),
            "{} !< {}",
            hmean.zero_bin_frac(),
            hmax.zero_bin_frac()
        );
    }

    #[test]
    fn one_bit_has_no_zero_bin() {
        let mut h = BinHistogram::new(1, Scheme::Sign);
        h.add_row(&gaussian_row(512, 3));
        assert_eq!(h.zero_bin_frac(), 0.0);
        assert_eq!(h.density(), 1.0);
        let rows = h.rows();
        assert!((rows[0].1 + rows[1].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ascii_renders_all_bins() {
        let mut h = BinHistogram::new(2, Scheme::Absmax);
        h.add_row(&gaussian_row(128, 4));
        let s = h.ascii();
        assert_eq!(s.lines().count(), 3);
        assert!(s.contains("-1:"));
    }
}
