//! Gradient quantization — the paper's core contribution (§3.1).
//!
//! * [`scheme`]   — absmax / absmean / sign quantizers + dequantization,
//!   semantically identical to the L1 Pallas kernels (`kernels/ref.py`).
//! * [`pack`]     — sub-byte bit packing (1/2/4/8-bit) + bf16, the storage
//!   format XLA cannot express (no sub-byte dtypes) so it lives in Rust
//!   between the kernel output and the datastore.
//! * [`batch`]    — pool-parallel window quantization (the streaming
//!   multi-precision datastore builder's quantize stage; byte-identical
//!   to the per-row path at every worker count).
//! * [`hist`]     — quantization-bin occupancy histograms (paper Fig. 3).
//! * [`weights`]  — base-weight block quantization for the QLoRA ablation
//!   (paper §5, Tables 2/5).

pub mod batch;
pub mod hist;
pub mod pack;
pub mod scheme;
pub mod weights;

pub use batch::quantize_rows_into;
pub use hist::BinHistogram;
pub use pack::{pack_codes, unpack_codes, PackedRow};
pub use scheme::{dequantize_row, quantize_row, try_quantize_row, QuantizedRow, Scheme};

use anyhow::{bail, Result};

/// Storage precision of the gradient datastore.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Precision {
    /// 16 (LESS bf16 baseline) or 8/4/2/1 quantized.
    pub bits: u8,
    /// Row-scale scheme for 2/4/8-bit codes (sign at 1-bit, unused at 16).
    pub scheme: Scheme,
}

impl Precision {
    /// Validated constructor: 16-bit coerces to absmax, 1-bit to sign;
    /// sign at 2/4/8-bit is rejected.
    pub fn new(bits: u8, scheme: Scheme) -> Result<Precision> {
        match bits {
            16 => Ok(Precision { bits, scheme: Scheme::Absmax }),
            1 => Ok(Precision { bits, scheme: Scheme::Sign }),
            2 | 4 | 8 => {
                if scheme == Scheme::Sign {
                    bail!("sign scheme is 1-bit only");
                }
                Ok(Precision { bits, scheme })
            }
            _ => bail!("unsupported bits {bits}"),
        }
    }

    /// α = 2^(b−1) − 1 (paper Eq. 5); None for 16-bit / sign.
    pub fn alpha(&self) -> Option<f32> {
        match self.bits {
            16 | 1 => None,
            b => Some(((1u32 << (b - 1)) - 1) as f32),
        }
    }

    /// Stored bytes for one k-dim gradient row (codes + one f32 scale).
    /// The paper's Table 1 storage column follows this accounting exactly.
    pub fn row_bytes(&self, k: usize) -> usize {
        match self.bits {
            16 => k * 2, // bf16, no scale needed
            b => (k * b as usize).div_ceil(8) + 4,
        }
    }

    /// Human-readable precision label (e.g. `4-bit/absmean`).
    pub fn label(&self) -> String {
        match self.bits {
            16 => "16-bit".to_string(),
            1 => "1-bit".to_string(),
            b => format!("{b}-bit/{}", self.scheme),
        }
    }
}

/// Paper-scale storage accounting: N samples × k dims × C checkpoints at
/// this precision (reproduces Table 1's 16.54 GB → 1.03 GB column when
/// called with the paper's N=270K, k=8192, C=4).
pub fn datastore_bytes(p: Precision, n_samples: usize, k: usize, checkpoints: usize) -> u64 {
    (p.row_bytes(k) as u64) * (n_samples as u64) * (checkpoints as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_validation() {
        assert!(Precision::new(16, Scheme::Absmax).is_ok());
        assert!(Precision::new(1, Scheme::Absmax).is_ok()); // coerced to sign
        assert_eq!(Precision::new(1, Scheme::Absmax).unwrap().scheme, Scheme::Sign);
        assert!(Precision::new(4, Scheme::Sign).is_err());
        assert!(Precision::new(3, Scheme::Absmax).is_err());
    }

    #[test]
    fn alpha_matches_paper_eq5() {
        let p = |b| Precision::new(b, Scheme::Absmax).unwrap();
        assert_eq!(p(8).alpha(), Some(127.0));
        assert_eq!(p(4).alpha(), Some(7.0));
        assert_eq!(p(2).alpha(), Some(1.0));
        assert_eq!(p(1).alpha(), None);
        assert_eq!(p(16).alpha(), None);
    }

    #[test]
    fn paper_table1_storage_column() {
        // Paper: 270K samples × 8192 dims × 4 checkpoints.
        // 16-bit: 16.54 GB, 8-bit: 8.27, 4-bit: 4.14, 2-bit: 2.07, 1-bit: 1.03
        let (n, k, c) = (270_000, 8192, 4);
        let gb = |p: Precision| datastore_bytes(p, n, k, c) as f64 / 1e9;
        let mk = |b| Precision::new(b, Scheme::Absmax).unwrap();
        assert!((gb(mk(16)) - 17.69).abs() < 0.1); // 2 B/dim: 17.7e9 = "16.54 GiB"
        let gib = |p: Precision| datastore_bytes(p, n, k, c) as f64 / (1u64 << 30) as f64;
        assert!((gib(mk(16)) - 16.48).abs() < 0.1, "{}", gib(mk(16)));
        assert!((gib(mk(8)) - 8.24).abs() < 0.1);
        assert!((gib(mk(4)) - 4.12).abs() < 0.05);
        assert!((gib(mk(2)) - 2.06).abs() < 0.05);
        assert!((gib(mk(1)) - 1.03).abs() < 0.05);
    }

    #[test]
    fn row_bytes_rounding() {
        let p = Precision::new(1, Scheme::Sign).unwrap();
        assert_eq!(p.row_bytes(8), 1 + 4);
        assert_eq!(p.row_bytes(9), 2 + 4);
        let p4 = Precision::new(4, Scheme::Absmax).unwrap();
        assert_eq!(p4.row_bytes(10), 5 + 4);
    }
}
