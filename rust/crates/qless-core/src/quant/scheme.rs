//! Quantization schemes (paper Eq. 4–5 + the §5 absmean/sign ablation).
//!
//! Semantics must match `python/compile/kernels/ref.py` exactly — the
//! integration tests compare codes produced here against the Pallas kernel
//! output for the same inputs. Rounding contract: ties go **to even**
//! (banker's rounding, like `jnp.round`), never away from zero — `0.5 → 0`,
//! `1.5 → 2`, `2.5 → 2`. [`round_ties_even`] implements exactly this;
//! `f32::round` (half-away-from-zero) must never touch a code path that is
//! compared against the kernels.

use anyhow::{bail, Result};

/// ABSMEAN_C from simconfig.py — values beyond c·mean|g| saturate.
pub const ABSMEAN_C: f32 = 2.5;

/// Row-scale selection rule for the 2/4/8-bit quantizers (paper Eq. 4–5),
/// plus the 1-bit sign scheme of the §5 ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Paper Eq. 4: scale by the row max absolute value.
    Absmax,
    /// §5 ablation: scale by c·mean|g| (denser low-bit codes, clipped tails).
    Absmean,
    /// 1-bit sign quantization (no zero bin).
    Sign,
}

impl std::fmt::Display for Scheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Scheme::Absmax => "absmax",
            Scheme::Absmean => "absmean",
            Scheme::Sign => "sign",
        })
    }
}

impl std::str::FromStr for Scheme {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Scheme> {
        match s {
            "absmax" => Ok(Scheme::Absmax),
            "absmean" => Ok(Scheme::Absmean),
            "sign" => Ok(Scheme::Sign),
            _ => bail!("unknown scheme '{s}' (absmax|absmean|sign)"),
        }
    }
}

/// One quantized gradient row: int8 codes + the reconstruction scale
/// (dequantized value = code × scale).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedRow {
    /// Integer codes in `[-α, α]` (±1 for the sign scheme).
    pub codes: Vec<i8>,
    /// Reconstruction scale; multiplies every code on dequantization.
    pub scale: f32,
}

/// Round-half-to-even, matching `jnp.round` / the Pallas kernels.
/// (§Perf iteration 4 tried `f32::round_ties_even` — 1.55× SLOWER here,
/// the std version lowers to a libm call on this target; reverted to the
/// branchy-but-predictable hand-rolled form.)
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    let r = x.round(); // round-half-away-from-zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let t = x.trunc();
        if (t as i64) % 2 == 0 {
            t
        } else {
            t + x.signum()
        }
    } else {
        r
    }
}

/// Quantize one row of projected gradient features (paper §3.1), rejecting
/// non-finite inputs with a clear error.
///
/// NaN must be stopped *here*: the sign path would otherwise map NaN to a
/// perfectly valid −1 code (`NaN >= 0.0` is false) and a NaN scale, and
/// the corruption only resurfaces as the NaN panic in `select::topk` —
/// several stages and one datastore file away from the actual bug.
pub fn try_quantize_row(g: &[f32], bits: u8, scheme: Scheme) -> Result<QuantizedRow> {
    if let Some(i) = g.iter().position(|x| !x.is_finite()) {
        bail!(
            "non-finite gradient feature {} at index {i} (row of {}): \
             rejected at quantization time",
            g[i],
            g.len()
        );
    }
    Ok(quantize_row_unchecked(g, bits, scheme))
}

/// Infallible [`try_quantize_row`]: panics (with the same clear message)
/// on non-finite input. Callers with a `Result` path should prefer the
/// fallible form.
pub fn quantize_row(g: &[f32], bits: u8, scheme: Scheme) -> QuantizedRow {
    if let Some(i) = g.iter().position(|x| !x.is_finite()) {
        panic!(
            "non-finite gradient feature {} at index {i}: rejected at quantization time",
            g[i]
        );
    }
    quantize_row_unchecked(g, bits, scheme)
}

fn quantize_row_unchecked(g: &[f32], bits: u8, scheme: Scheme) -> QuantizedRow {
    assert!(!g.is_empty());
    match (bits, scheme) {
        (1, _) | (_, Scheme::Sign) => {
            let codes = g.iter().map(|&x| if x >= 0.0 { 1i8 } else { -1i8 }).collect();
            let scale = g.iter().map(|x| x.abs()).sum::<f32>() / g.len() as f32;
            QuantizedRow { codes, scale }
        }
        (b, sch) => {
            debug_assert!(matches!(b, 2 | 4 | 8), "bits {b}");
            let alpha = ((1u32 << (b - 1)) - 1) as f32;
            let s = match sch {
                Scheme::Absmax => g.iter().fold(0f32, |m, &x| m.max(x.abs())),
                Scheme::Absmean => {
                    ABSMEAN_C * g.iter().map(|x| x.abs()).sum::<f32>() / g.len() as f32
                }
                Scheme::Sign => unreachable!(),
            };
            let safe = if s > 0.0 { s } else { 1.0 };
            // §Perf: hoist the division — one multiply per element instead
            // of a divide (≈1.6× on the 8/4/2-bit quantize path).
            let mul = alpha / safe;
            let codes = g
                .iter()
                .map(|&x| round_ties_even(mul * x).clamp(-alpha, alpha) as i8)
                .collect();
            QuantizedRow { codes, scale: if s > 0.0 { s / alpha } else { 0.0 } }
        }
    }
}

/// Reconstruct float features: code × scale.
pub fn dequantize_row(row: &QuantizedRow) -> Vec<f32> {
    row.codes.iter().map(|&c| c as f32 * row.scale).collect()
}

/// Row L2 normalization (paper Eq. 2 / Eq. 6); zero rows stay zero.
pub fn normalize_row(g: &mut [f32]) {
    let n = g.iter().map(|x| x * x).sum::<f32>().sqrt();
    if n > 0.0 {
        for x in g {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    #[test]
    fn absmax_outer_bin_exact() {
        let q = quantize_row(&[1.0, -2.0, 0.5], 4, Scheme::Absmax);
        assert_eq!(q.codes, vec![4, -7, 2]); // α=7, scale by 2.0
        assert!((q.scale - 2.0 / 7.0).abs() < 1e-7);
    }

    #[test]
    fn sign_has_no_zero_bin() {
        let q = quantize_row(&[0.3, -0.7, 0.0, -0.0], 1, Scheme::Absmax);
        // IEEE: -0.0 >= 0.0 is true, so both zeros map to +1 (same as jnp).
        assert_eq!(q.codes, vec![1, -1, 1, 1]);
    }

    #[test]
    fn sign_scale_is_absmean() {
        let q = quantize_row(&[1.0, -3.0], 1, Scheme::Sign);
        assert_eq!(q.scale, 2.0);
    }

    #[test]
    fn zero_row_is_safe() {
        for bits in [2, 4, 8] {
            let q = quantize_row(&[0.0; 8], bits, Scheme::Absmax);
            assert!(q.codes.iter().all(|&c| c == 0));
            assert_eq!(q.scale, 0.0);
        }
    }

    #[test]
    fn try_quantize_rejects_non_finite() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for (bits, scheme) in
                [(1u8, Scheme::Sign), (2, Scheme::Absmax), (4, Scheme::Absmean), (8, Scheme::Absmax)]
            {
                let err = try_quantize_row(&[0.5, bad, -0.5], bits, scheme).unwrap_err();
                let msg = err.to_string();
                assert!(msg.contains("non-finite"), "{bits}-bit {scheme}: {msg}");
                assert!(msg.contains("index 1"), "{msg}");
            }
        }
        assert!(try_quantize_row(&[0.5, -0.5], 1, Scheme::Sign).is_ok());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn quantize_row_panics_on_nan_sign_path() {
        // The seed code silently emitted a −1 code here.
        quantize_row(&[f32::NAN, 1.0], 1, Scheme::Sign);
    }

    #[test]
    fn round_ties_even_matches_numpy() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(0.4999), 0.0);
        assert_eq!(round_ties_even(1.2), 1.0);
        assert_eq!(round_ties_even(-1.7), -2.0);
    }

    #[test]
    fn prop_codes_bounded_by_alpha() {
        run_prop("codes-bounded", 100, |g| {
            let n = 1 + g.usize_up_to(64);
            let v = g.vec_f32_edgy(n);
            for bits in [2u8, 4, 8] {
                let alpha = ((1u32 << (bits - 1)) - 1) as i32;
                for scheme in [Scheme::Absmax, Scheme::Absmean] {
                    let q = quantize_row(&v, bits, scheme);
                    for &c in &q.codes {
                        prop_assert!(
                            (c as i32).abs() <= alpha,
                            "code {c} exceeds alpha {alpha} at {bits}-bit {scheme}"
                        );
                    }
                    prop_assert!(q.scale.is_finite() && q.scale >= 0.0, "bad scale");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sign_preserved_for_large_components() {
        // absmax: any component ≥ half the row max must keep its sign.
        run_prop("sign-preserved", 100, |g| {
            let n = 2 + g.usize_up_to(32);
            let v = g.vec_f32(n, 1.0);
            let q = quantize_row(&v, 8, Scheme::Absmax);
            let max = v.iter().fold(0f32, |m, &x| m.max(x.abs()));
            for (x, c) in v.iter().zip(&q.codes) {
                if x.abs() >= max * 0.5 && max > 0.0 {
                    prop_assert!(
                        (*x > 0.0) == (*c > 0),
                        "sign flipped: {x} -> {c}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dequant_error_bounded() {
        // absmax reconstruction error ≤ scale/2 per element (round step).
        run_prop("dequant-bounded", 100, |g| {
            let n = 1 + g.usize_up_to(64);
            let v = g.vec_f32(n, 3.0);
            let q = quantize_row(&v, 8, Scheme::Absmax);
            let rec = dequantize_row(&q);
            for (x, r) in v.iter().zip(&rec) {
                prop_assert!(
                    (x - r).abs() <= q.scale * 0.5 + 1e-6,
                    "err {} > half-scale {}",
                    (x - r).abs(),
                    q.scale * 0.5
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_absmean_zero_bin_not_denser() {
        // Paper Fig. 3: on Gaussian-like gradient rows, absmean occupies the
        // zero bin (much) less than absmax. Statistical claim → large rows
        // (for tiny rows where mean|g| ≈ max|g| the ordering can flip) and
        // a small count-noise slack.
        run_prop("absmean-denser", 60, |g| {
            let n = 256 + g.usize_up_to(64) * 8;
            let v = g.vec_f32(n, 1.0);
            for bits in [2u8, 4] {
                let zmax = quantize_row(&v, bits, Scheme::Absmax)
                    .codes
                    .iter()
                    .filter(|&&c| c == 0)
                    .count();
                let zmean = quantize_row(&v, bits, Scheme::Absmean)
                    .codes
                    .iter()
                    .filter(|&&c| c == 0)
                    .count();
                prop_assert!(
                    zmean <= zmax + n / 50,
                    "absmean zero bin {zmean} > absmax {zmax} (n={n}, {bits}-bit)"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn normalize_row_unit_or_zero() {
        let mut v = vec![3.0, 4.0];
        normalize_row(&mut v);
        assert!((v[0] - 0.6).abs() < 1e-6 && (v[1] - 0.8).abs() < 1e-6);
        let mut z = vec![0.0; 4];
        normalize_row(&mut z);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scheme_parse_display_roundtrip() {
        for s in [Scheme::Absmax, Scheme::Absmean, Scheme::Sign] {
            assert_eq!(s.to_string().parse::<Scheme>().unwrap(), s);
        }
        assert!("bogus".parse::<Scheme>().is_err());
    }
}
