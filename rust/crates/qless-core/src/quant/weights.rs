//! Base-weight quantization for the QLoRA ablation (paper §5, Tables 2/5).
//!
//! The paper extracts gradients from LLM.int8 (8-bit) and NF4 (4-bit)
//! quantized base models. We reproduce the same *question* — does degraded
//! weight precision degrade gradient-feature fidelity? — with block-wise
//! quantizers over the frozen flat base-parameter vector:
//!
//! * 8-bit: per-block absmax int8 (the LLM.int8 analogue without outlier
//!   decomposition — SimLM activations have no 7B-scale outliers).
//! * 4-bit: NF4 — the exact 16-level NormalFloat codebook from QLoRA
//!   (Dettmers et al. 2024), per-block absmax-normalized nearest-neighbour.
//!
//! Weights are quantized *and dequantized back to f32* before being fed to
//! the AOT graphs (the graphs compute in f32, like QLoRA's bf16 compute
//! dtype over quantized storage).

/// The NF4 codebook: 16 quantiles of N(0,1) normalized to [−1, 1]
/// (values from the QLoRA reference implementation).
pub const NF4_LEVELS: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// Block size of the weight quantizers (one absmax scale per block).
pub const BLOCK: usize = 64;

/// Simulate storing `w` at `bits` precision: quantize block-wise, then
/// dequantize back to f32. `bits` ∈ {16 (identity), 8, 4}.
pub fn quantize_weights(w: &[f32], bits: u8) -> Vec<f32> {
    match bits {
        16 => w.to_vec(),
        8 => roundtrip_int8(w),
        4 => roundtrip_nf4(w),
        _ => panic!("quantize_weights: unsupported bits {bits}"),
    }
}

fn roundtrip_int8(w: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(w.len());
    for block in w.chunks(BLOCK) {
        let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
        if absmax == 0.0 {
            out.extend(std::iter::repeat_n(0f32, block.len()));
            continue;
        }
        let scale = absmax / 127.0;
        for &x in block {
            let q = (x / scale).round().clamp(-127.0, 127.0);
            out.push(q * scale);
        }
    }
    out
}

fn roundtrip_nf4(w: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(w.len());
    for block in w.chunks(BLOCK) {
        let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
        if absmax == 0.0 {
            out.extend(std::iter::repeat_n(0f32, block.len()));
            continue;
        }
        for &x in block {
            let v = x / absmax;
            // nearest codebook level (codebook is sorted)
            let idx = NF4_LEVELS
                .iter()
                .enumerate()
                .min_by(|a, b| {
                    (a.1 - v).abs().partial_cmp(&(b.1 - v).abs()).unwrap()
                })
                .unwrap()
                .0;
            out.push(NF4_LEVELS[idx] * absmax);
        }
    }
    out
}

/// Stored bytes for a weight vector at this precision (reporting only):
/// codes + one f32 absmax per block for 8/4-bit, bf16 for 16.
pub fn weight_bytes(n: usize, bits: u8) -> u64 {
    match bits {
        16 => 2 * n as u64,
        8 => n as u64 + 4 * n.div_ceil(BLOCK) as u64,
        4 => n.div_ceil(2) as u64 + 4 * n.div_ceil(BLOCK) as u64,
        _ => panic!("weight_bytes: unsupported bits {bits}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;
    use crate::util::Rng;

    fn normals(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn identity_at_16() {
        let w = normals(100, 1, 0.1);
        assert_eq!(quantize_weights(&w, 16), w);
    }

    #[test]
    fn int8_error_small() {
        let w = normals(1000, 2, 0.05);
        let q = quantize_weights(&w, 8);
        let max_err: f32 = w.iter().zip(&q).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        let absmax = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
        assert!(max_err <= absmax / 127.0, "{max_err}");
    }

    #[test]
    fn nf4_error_larger_but_bounded() {
        let w = normals(1000, 3, 0.05);
        let q = quantize_weights(&w, 4);
        let rms_err = (w.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
            / w.len() as f32)
            .sqrt();
        let rms = (w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32).sqrt();
        assert!(rms_err < rms * 0.12, "nf4 rms err {rms_err} vs rms {rms}");
        assert!(rms_err > 0.0);
    }

    #[test]
    fn nf4_levels_sorted_and_symmetric_ends() {
        for i in 1..16 {
            assert!(NF4_LEVELS[i] > NF4_LEVELS[i - 1]);
        }
        assert_eq!(NF4_LEVELS[0], -1.0);
        assert_eq!(NF4_LEVELS[15], 1.0);
        assert_eq!(NF4_LEVELS[7], 0.0);
    }

    #[test]
    fn prop_blockwise_max_preserved() {
        // The absmax element of each block is exactly representable
        // (±absmax maps to an end level in both schemes).
        run_prop("weights-max-preserved", 60, |g| {
            let n = BLOCK * (1 + g.usize_up_to(4));
            let w = g.vec_f32(n, 0.1);
            for bits in [8u8, 4] {
                let q = quantize_weights(&w, bits);
                for (block_w, block_q) in w.chunks(BLOCK).zip(q.chunks(BLOCK)) {
                    let (i, _) = block_w
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                        .unwrap();
                    let rel = (block_w[i] - block_q[i]).abs() / block_w[i].abs().max(1e-9);
                    prop_assert!(rel < 0.005, "block max drifted {rel} at {bits}-bit");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_blocks_safe() {
        let w = vec![0.0f32; 2 * BLOCK];
        assert_eq!(quantize_weights(&w, 8), w);
        assert_eq!(quantize_weights(&w, 4), w);
    }

    #[test]
    fn weight_bytes_accounting() {
        assert_eq!(weight_bytes(BLOCK, 16), 128);
        assert_eq!(weight_bytes(BLOCK, 8), 64 + 4);
        assert_eq!(weight_bytes(BLOCK, 4), 32 + 4);
    }
}
