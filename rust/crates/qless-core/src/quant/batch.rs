//! Batched window quantization — the streaming datastore builder's
//! quantize stage.
//!
//! The legacy build path quantized one row per `DatastoreWriter::append_features`
//! call: per-row dispatch, one allocation per row, single-threaded. This
//! module quantizes a *window* of rows at once, in parallel on the
//! persistent pool ([`crate::util::pool::par_for`]), with every worker
//! packing straight into its row's disjoint slot of one pre-sized output
//! buffer. Per-row semantics are exactly [`try_quantize_row`] +
//! [`pack_codes_into`] (bf16 encode at 16-bit), so datastores assembled
//! from these windows are **byte-identical** to ones written row-by-row —
//! the property `tests/build_stream.rs` locks in across bitwidth × scheme
//! × worker count.

use anyhow::{bail, Result};

use super::pack::{pack_codes_into, packed_bytes};
use super::scheme::try_quantize_row;
use super::Precision;
use crate::util::bits::f32_to_bf16;
use crate::util::pool;

/// Packed bytes one k-dim row occupies on disk at `precision`, excluding
/// its f32 scale (the datastore header's `row_stride`).
pub fn row_stride(k: usize, precision: Precision) -> usize {
    match precision.bits {
        16 => k * 2,
        b => packed_bytes(k, b),
    }
}

/// Builder-resident bytes one window row costs at `precision`: the packed
/// row plus its staged f32 scale (16-bit rows carry no scale).
pub fn window_row_bytes(k: usize, precision: Precision) -> usize {
    row_stride(k, precision) + if precision.bits == 16 { 0 } else { 4 }
}

/// Quantize a window of `rows.len() / k` feature rows at `precision`, in
/// parallel on the persistent pool, into `bytes` (resized to
/// `n × row_stride`) and `scales` (resized to `n`; left **empty** at
/// 16-bit, where bf16 rows are self-describing).
///
/// `max_workers` caps the parallelism (0 = no cap); the output is
/// identical at every worker count because each row owns a fixed slot.
/// Non-finite features are rejected with the lowest offending
/// window-relative row index, so the error is deterministic too.
pub fn quantize_rows_into(
    rows: &[f32],
    k: usize,
    precision: Precision,
    bytes: &mut Vec<u8>,
    scales: &mut Vec<f32>,
    max_workers: usize,
) -> Result<()> {
    if k == 0 || rows.len() % k != 0 {
        bail!("quantize_rows_into: {} floats is not a whole number of k={k} rows", rows.len());
    }
    let n = rows.len() / k;
    let stride = row_stride(k, precision);
    bytes.clear();
    bytes.resize(n * stride, 0);
    scales.clear();
    if precision.bits != 16 {
        scales.resize(n, 0.0);
    }

    // Raw output cursors so pool workers can write their rows' disjoint
    // slots without locking (same lifetime-erasure idiom as util::pool:
    // the buffers outlive the call because par_for blocks until done).
    struct Out {
        bytes: *mut u8,
        scales: *mut f32,
    }
    unsafe impl Send for Out {}
    unsafe impl Sync for Out {}
    let out = Out { bytes: bytes.as_mut_ptr(), scales: scales.as_mut_ptr() };
    let first_err: std::sync::Mutex<Option<(usize, anyhow::Error)>> = std::sync::Mutex::new(None);
    pool::par_for(n, max_workers, &|i| {
        let g = &rows[i * k..(i + 1) * k];
        // SAFETY: row i's byte/scale slots are written by exactly one
        // closure invocation (par_for indices are disjoint) and the
        // buffers live until par_for returns.
        let slot = unsafe { std::slice::from_raw_parts_mut(out.bytes.add(i * stride), stride) };
        match quantize_row_slot(g, precision, slot) {
            Ok(scale) => {
                if precision.bits != 16 {
                    unsafe { *out.scales.add(i) = scale };
                }
            }
            Err(e) => {
                let mut guard = first_err.lock().unwrap_or_else(|p| p.into_inner());
                if guard.as_ref().is_none_or(|(j, _)| i < *j) {
                    *guard = Some((i, e));
                }
            }
        }
    });
    if let Some((i, e)) = first_err.lock().unwrap_or_else(|p| p.into_inner()).take() {
        return Err(e.context(format!("quantizing window row {i}")));
    }
    Ok(())
}

/// Quantize + pack one row into its `row_stride`-byte slot; returns the
/// row scale (0.0 at 16-bit, which stores bf16 and keeps no scale).
fn quantize_row_slot(g: &[f32], precision: Precision, slot: &mut [u8]) -> Result<f32> {
    if precision.bits == 16 {
        if let Some(i) = g.iter().position(|x| !x.is_finite()) {
            bail!(
                "non-finite gradient feature {} at index {i}: rejected at quantization time",
                g[i]
            );
        }
        for (b, &f) in slot.chunks_exact_mut(2).zip(g) {
            b.copy_from_slice(&f32_to_bf16(f).to_le_bytes());
        }
        Ok(0.0)
    } else {
        let q = try_quantize_row(g, precision.bits, precision.scheme)?;
        pack_codes_into(&q.codes, precision.bits, slot)?;
        Ok(q.scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack_codes;
    use crate::quant::Scheme;
    use crate::util::Rng;

    fn rows(n: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * k).map(|_| rng.normal() as f32).collect()
    }

    fn all_precisions() -> Vec<Precision> {
        vec![
            Precision::new(16, Scheme::Absmax).unwrap(),
            Precision::new(8, Scheme::Absmax).unwrap(),
            Precision::new(8, Scheme::Absmean).unwrap(),
            Precision::new(4, Scheme::Absmax).unwrap(),
            Precision::new(4, Scheme::Absmean).unwrap(),
            Precision::new(2, Scheme::Absmax).unwrap(),
            Precision::new(2, Scheme::Absmean).unwrap(),
            Precision::new(1, Scheme::Sign).unwrap(),
        ]
    }

    #[test]
    fn window_matches_per_row_path_exactly() {
        let (n, k) = (13usize, 97usize); // k not byte-aligned at sub-byte widths
        let data = rows(n, k, 7);
        for p in all_precisions() {
            let mut bytes = Vec::new();
            let mut scales = Vec::new();
            quantize_rows_into(&data, k, p, &mut bytes, &mut scales, 0).unwrap();
            let stride = row_stride(k, p);
            assert_eq!(bytes.len(), n * stride);
            for i in 0..n {
                let g = &data[i * k..(i + 1) * k];
                if p.bits == 16 {
                    let mut want = Vec::with_capacity(k * 2);
                    for &f in g {
                        want.extend_from_slice(&f32_to_bf16(f).to_le_bytes());
                    }
                    assert_eq!(&bytes[i * stride..(i + 1) * stride], &want[..], "{}", p.label());
                } else {
                    let q = try_quantize_row(g, p.bits, p.scheme).unwrap();
                    let packed = pack_codes(&q.codes, p.bits, q.scale).unwrap();
                    assert_eq!(
                        &bytes[i * stride..(i + 1) * stride],
                        &packed.bytes[..],
                        "{} row {i}",
                        p.label()
                    );
                    assert_eq!(scales[i], q.scale, "{} row {i}", p.label());
                }
            }
            if p.bits == 16 {
                assert!(scales.is_empty(), "16-bit windows carry no scales");
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_output() {
        let (n, k) = (37usize, 64usize);
        let data = rows(n, k, 11);
        for p in all_precisions() {
            let mut ref_bytes = Vec::new();
            let mut ref_scales = Vec::new();
            quantize_rows_into(&data, k, p, &mut ref_bytes, &mut ref_scales, 1).unwrap();
            for workers in [0usize, 2, 3, 16] {
                // dirty scratch buffers must not leak into the output
                let mut bytes = vec![0xAB; 5];
                let mut scales = vec![9.0; 3];
                quantize_rows_into(&data, k, p, &mut bytes, &mut scales, workers).unwrap();
                assert_eq!(bytes, ref_bytes, "{} workers={workers}", p.label());
                assert_eq!(scales, ref_scales, "{} workers={workers}", p.label());
            }
        }
    }

    #[test]
    fn rejects_non_finite_with_lowest_row_index() {
        let (n, k) = (9usize, 16usize);
        let mut data = rows(n, k, 3);
        data[5 * k + 2] = f32::NAN;
        data[7 * k] = f32::INFINITY;
        for p in all_precisions() {
            let mut bytes = Vec::new();
            let mut scales = Vec::new();
            let err = quantize_rows_into(&data, k, p, &mut bytes, &mut scales, 0).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("non-finite"), "{}: {msg}", p.label());
            assert!(msg.contains("window row 5"), "{}: {msg}", p.label());
        }
    }

    #[test]
    fn rejects_ragged_windows() {
        let mut bytes = Vec::new();
        let mut scales = Vec::new();
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        assert!(quantize_rows_into(&[0.0; 10], 4, p, &mut bytes, &mut scales, 0).is_err());
        assert!(quantize_rows_into(&[0.0; 4], 0, p, &mut bytes, &mut scales, 0).is_err());
        // empty window is fine (zero rows)
        quantize_rows_into(&[], 4, p, &mut bytes, &mut scales, 0).unwrap();
        assert!(bytes.is_empty() && scales.is_empty());
    }

    #[test]
    fn stride_accounting_matches_precision() {
        for p in all_precisions() {
            assert_eq!(row_stride(100, p), p.row_bytes(100) - if p.bits == 16 { 0 } else { 4 });
            let extra = if p.bits == 16 { 0 } else { 4 };
            assert_eq!(window_row_bytes(100, p), row_stride(100, p) + extra);
        }
    }
}
