//! Sub-byte bit packing — the storage layer XLA cannot express.
//!
//! Codes from the quantizer are int8 in `[-α, α]`; packing stores them in
//! `b` bits each (offset-binary: `stored = code + α`, with α = 2^(b−1) − 1;
//! for 1-bit sign codes the bit is simply `code > 0`). Little-endian bit
//! order within each byte, rows padded to whole bytes — the exact on-disk
//! layout of the gradient datastore.
//!
//! The 1-bit path additionally exposes the row as packed `u64` words for
//! the XNOR+popcount influence fast path (`influence::native`).

use anyhow::{bail, Result};

/// A bit-packed quantized row.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedRow {
    /// Field width in bits: 1, 2, 4 or 8.
    pub bits: u8,
    /// Number of codes (k).
    pub len: usize,
    /// Packed little-endian lane bytes, `⌈len·bits/8⌉` of them.
    pub bytes: Vec<u8>,
    /// Reconstruction scale (dequantized value = code × scale).
    pub scale: f32,
}

/// Pack int8 codes into `bits`-wide fields.
pub fn pack_codes(codes: &[i8], bits: u8, scale: f32) -> Result<PackedRow> {
    let n = codes.len();
    let nbytes = packed_bytes(n, bits);
    let mut bytes = vec![0u8; nbytes];
    pack_codes_into(codes, bits, &mut bytes)?;
    Ok(PackedRow { bits, len: n, bytes, scale })
}

/// Packed bytes one row of `len` codes occupies at `bits` per code.
pub fn packed_bytes(len: usize, bits: u8) -> usize {
    (len * bits as usize).div_ceil(8)
}

/// Pack int8 codes into `bits`-wide fields directly into `out` — the
/// allocation-free core of [`pack_codes`], used by the batched window
/// quantizer so parallel workers write straight into their disjoint row
/// slots. `out` must be exactly [`packed_bytes`]`(codes.len(), bits)` long
/// (zeroed or not — every byte is overwritten).
pub fn pack_codes_into(codes: &[i8], bits: u8, out: &mut [u8]) -> Result<()> {
    if ![1, 2, 4, 8].contains(&bits) {
        bail!("pack_codes: unsupported bits {bits}");
    }
    let n = codes.len();
    if out.len() != packed_bytes(n, bits) {
        bail!("pack_codes_into: {} byte slot for {} codes at {bits}-bit", out.len(), n);
    }
    let bytes = out;
    if bits == 1 {
        // §Perf iteration 5: byte-at-a-time assembly (no per-bit indexed
        // writes) — ~5× on the 1-bit pack path, which dominated datastore
        // writes (14.4ms → below the 16-bit path's 5ms per block).
        for (b, chunk) in bytes.iter_mut().zip(codes.chunks(8)) {
            let mut acc = 0u8;
            for (j, &c) in chunk.iter().enumerate() {
                acc |= u8::from(c > 0) << j;
            }
            *b = acc;
        }
    } else {
        let alpha = ((1i16 << (bits - 1)) - 1) as i8;
        let per_byte = 8 / bits as usize;
        for &c in codes {
            if c < -alpha || c > alpha {
                bail!("code {c} out of [-{alpha}, {alpha}] for {bits}-bit");
            }
        }
        for (b, chunk) in bytes.iter_mut().zip(codes.chunks(per_byte)) {
            let mut acc = 0u8;
            for (j, &c) in chunk.iter().enumerate() {
                acc |= (((c as i16 + alpha as i16) as u8) << (j * bits as usize)) as u8;
            }
            *b = acc;
        }
    }
    Ok(())
}

/// Unpack back to int8 codes (exact inverse of [`pack_codes`]).
pub fn unpack_codes(row: &PackedRow) -> Vec<i8> {
    let mut out = Vec::with_capacity(row.len);
    if row.bits == 1 {
        for i in 0..row.len {
            let bit = (row.bytes[i / 8] >> (i % 8)) & 1;
            out.push(if bit == 1 { 1 } else { -1 });
        }
    } else {
        let bits = row.bits as usize;
        let alpha = ((1i16 << (bits - 1)) - 1) as i16;
        let mask = ((1u16 << bits) - 1) as u8;
        let per_byte = 8 / bits;
        for i in 0..row.len {
            let stored = (row.bytes[i / per_byte] >> ((i % per_byte) * bits)) & mask;
            out.push((stored as i16 - alpha) as i8);
        }
    }
    out
}

/// Unpack the first `len` lanes of a packed row's bytes as zero-extended
/// **stored** values (offset-binary: `stored = code + α` for 2/4/8-bit;
/// the raw 0/1 sign bit at 1-bit) into `out`, resizing it to `len`.
///
/// This is the integer scoring engine's row decoder: the hot loop dots
/// stored lanes against validation codes and removes the `+α` offset with
/// a single per-row zero-point fixup (`influence::native::scores_int_rows`),
/// so no sign extension — and no f32 conversion — happens per element.
/// For 8-bit rows the lanes are the bytes themselves and this is a copy;
/// callers on the hottest path can borrow the row bytes directly instead.
pub fn unpack_stored_into(bytes: &[u8], bits: u8, len: usize, out: &mut Vec<u8>) {
    out.resize(len, 0);
    unpack_stored_slice(bytes, bits, out);
}

/// [`unpack_stored_into`] over a caller-sized slice: unpacks exactly
/// `out.len()` lanes. The blocked scan kernels unpack a whole *tile* of
/// rows into one reused scratch buffer (each row at its `k`-lane offset),
/// so the destination is a sub-slice of a larger allocation rather than a
/// `Vec` to resize.
pub fn unpack_stored_slice(bytes: &[u8], bits: u8, out: &mut [u8]) {
    assert!(matches!(bits, 1 | 2 | 4 | 8), "unpack_stored_slice: unsupported bits {bits}");
    let len = out.len();
    if bits == 8 {
        out.copy_from_slice(&bytes[..len]);
        return;
    }
    let bits = bits as usize;
    let mask = ((1u16 << bits) - 1) as u8;
    let per_byte = 8 / bits;
    for (i, o) in out.iter_mut().enumerate() {
        *o = (bytes[i / per_byte] >> ((i % per_byte) * bits)) & mask;
    }
}

/// View a 1-bit row as little-endian u64 words (tail zero-padded). Zero
/// padding maps to "−1" bits, so callers must subtract the tail's phantom
/// agreement — see the tail fixup in
/// `influence::native::scores_1bit_rows` (in the `qless-datastore` crate).
pub fn as_sign_words(row: &PackedRow) -> Vec<u64> {
    assert_eq!(row.bits, 1, "sign words need a 1-bit row");
    let nwords = row.len.div_ceil(64);
    let mut words = vec![0u64; nwords];
    for (i, chunk) in row.bytes.chunks(8).enumerate() {
        let mut w = [0u8; 8];
        w[..chunk.len()].copy_from_slice(chunk);
        words[i] = u64::from_le_bytes(w);
    }
    words
}

/// Dequantize a packed row straight to f32 (code × scale).
pub fn unpack_dequant(row: &PackedRow) -> Vec<f32> {
    unpack_codes(row).into_iter().map(|c| c as f32 * row.scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    #[test]
    fn pack_sizes() {
        assert_eq!(pack_codes(&[1; 8], 1, 0.0).unwrap().bytes.len(), 1);
        assert_eq!(pack_codes(&[1; 9], 1, 0.0).unwrap().bytes.len(), 2);
        assert_eq!(pack_codes(&[0; 4], 2, 0.0).unwrap().bytes.len(), 1);
        assert_eq!(pack_codes(&[0; 5], 4, 0.0).unwrap().bytes.len(), 3);
        assert_eq!(pack_codes(&[0; 3], 8, 0.0).unwrap().bytes.len(), 3);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(pack_codes(&[2], 2, 0.0).is_err()); // α=1 at 2-bit
        assert!(pack_codes(&[-8], 4, 0.0).is_err()); // α=7 at 4-bit
        assert!(pack_codes(&[1], 3, 0.0).is_err());
    }

    #[test]
    fn prop_pack_unpack_identity_all_bitwidths() {
        run_prop("pack-roundtrip", 200, |g| {
            let n = 1 + g.usize_up_to(200);
            for bits in [1u8, 2, 4, 8] {
                let alpha = if bits == 1 { 1 } else { ((1i16 << (bits - 1)) - 1) as i8 };
                let codes: Vec<i8> = (0..n)
                    .map(|_| {
                        if bits == 1 {
                            if g.rng.below(2) == 0 { -1 } else { 1 }
                        } else {
                            (g.rng.below(2 * alpha as usize + 1) as i16 - alpha as i16) as i8
                        }
                    })
                    .collect();
                let packed = pack_codes(&codes, bits, 0.5).map_err(|e| e.to_string())?;
                let back = unpack_codes(&packed);
                prop_assert!(back == codes, "roundtrip failed at {bits}-bit n={n}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_stored_lanes_match_codes_plus_alpha() {
        // unpack_stored_into must agree with unpack_codes up to the
        // offset-binary zero point at every bitwidth and length.
        run_prop("stored-lanes", 100, |g| {
            let n = 1 + g.usize_up_to(150);
            for bits in [1u8, 2, 4, 8] {
                let alpha: i16 = if bits == 1 { 0 } else { (1i16 << (bits - 1)) - 1 };
                let codes: Vec<i8> = (0..n)
                    .map(|_| {
                        if bits == 1 {
                            if g.rng.below(2) == 0 { -1 } else { 1 }
                        } else {
                            (g.rng.below(2 * alpha as usize + 1) as i16 - alpha) as i8
                        }
                    })
                    .collect();
                let packed = pack_codes(&codes, bits, 1.0).map_err(|e| e.to_string())?;
                let mut stored = Vec::new();
                unpack_stored_into(&packed.bytes, bits, n, &mut stored);
                prop_assert!(stored.len() == n, "len at {bits}-bit");
                for (i, (&s, &c)) in stored.iter().zip(&codes).enumerate() {
                    let want: i16 = if bits == 1 {
                        i16::from(c > 0) // raw sign bit, not offset-binary
                    } else {
                        c as i16 + alpha
                    };
                    prop_assert!(
                        s as i16 == want,
                        "lane {i} at {bits}-bit: stored {s} != code {c} + α {alpha}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pack_into_validates_slot_and_overwrites_dirty_bytes() {
        // wrong slot size is an error, not a silent truncation
        let mut small = vec![0u8; 1];
        assert!(pack_codes_into(&[1i8; 9], 1, &mut small).is_err());
        // a dirty (non-zero) slot must come out identical to a fresh pack,
        // including the padding bits of the final partial byte
        let codes: Vec<i8> = (0..11).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        for bits in [1u8, 2, 4, 8] {
            let clean = pack_codes(&codes, bits, 0.0).unwrap();
            let mut dirty = vec![0xFFu8; packed_bytes(codes.len(), bits)];
            pack_codes_into(&codes, bits, &mut dirty).unwrap();
            assert_eq!(dirty, clean.bytes, "{bits}-bit");
        }
    }

    #[test]
    fn sign_words_match_bit_layout() {
        let codes: Vec<i8> = (0..70).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        let packed = pack_codes(&codes, 1, 1.0).unwrap();
        let words = as_sign_words(&packed);
        assert_eq!(words.len(), 2);
        for (i, &c) in codes.iter().enumerate() {
            let bit = (words[i / 64] >> (i % 64)) & 1;
            assert_eq!(bit == 1, c > 0, "bit {i}");
        }
        // tail bits are zero
        for i in 70..128 {
            assert_eq!((words[i / 64] >> (i % 64)) & 1, 0);
        }
    }

    #[test]
    fn unpack_dequant_applies_scale() {
        let packed = pack_codes(&[-7, 0, 7], 4, 0.25).unwrap();
        assert_eq!(unpack_dequant(&packed), vec![-1.75, 0.0, 1.75]);
    }

    #[test]
    fn quantize_then_pack_roundtrip() {
        use crate::quant::scheme::{quantize_row, Scheme};
        let mut rng = crate::util::Rng::new(9);
        let g: Vec<f32> = (0..300).map(|_| rng.normal() as f32).collect();
        for bits in [1u8, 2, 4, 8] {
            let q = quantize_row(&g, bits, Scheme::Absmax);
            let packed = pack_codes(&q.codes, bits, q.scale).unwrap();
            assert_eq!(unpack_codes(&packed), q.codes, "{bits}-bit");
            assert_eq!(packed.scale, q.scale);
        }
    }
}
