//! Character-level tokenizer, vocab-identical to `python/compile/simconfig.py`.
//!
//! 64 tokens: `<pad>`=0, `<bos>`=1, `<eot>`=2, `<sep>`=3, then the 60 text
//! characters. The runtime cross-checks this table against the vocab list in
//! `artifacts/manifest.json` at startup so a drifted artifact set fails fast.

use anyhow::{bail, Result};

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOT: i32 = 2;
pub const SEP: i32 = 3;

/// Text characters at ids 4..64 (must match simconfig.VOCAB order).
pub const CHARS: &str = "abcdefghijklmnopqrstuvwxyz0123456789 .,:;?!'\"()+-*/=%<>|&#@_";

#[derive(Debug, Clone)]
pub struct Tokenizer {
    to_id: [i32; 128],
    to_char: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        let mut to_id = [-1i32; 128];
        let mut to_char = vec!['\0'; 4];
        for (i, c) in CHARS.chars().enumerate() {
            to_id[c as usize] = (i + 4) as i32;
            to_char.push(c);
        }
        assert_eq!(to_char.len(), 64, "vocab must be 64");
        Tokenizer { to_id, to_char }
    }
}

impl Tokenizer {
    pub fn vocab_size(&self) -> usize {
        64
    }

    /// Encode text; errors on characters outside the vocabulary.
    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(text.len());
        for c in text.chars() {
            let id = if (c as usize) < 128 { self.to_id[c as usize] } else { -1 };
            if id < 0 {
                bail!("character '{c}' (U+{:04X}) not in vocab", c as u32);
            }
            out.push(id);
        }
        Ok(out)
    }

    /// Decode ids back to text; specials are rendered as markers, pads
    /// dropped (round-trip of plain text is exact).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut s = String::new();
        for &id in ids {
            match id {
                PAD => {}
                BOS => s.push_str("<bos>"),
                EOT => s.push_str("<eot>"),
                SEP => s.push_str("<sep>"),
                _ if (id as usize) < self.to_char.len() => s.push(self.to_char[id as usize]),
                _ => s.push('\u{FFFD}'),
            }
        }
        s
    }

    /// Decode only text chars, stopping at the first EOT (generation reads).
    pub fn decode_until_eot(&self, ids: &[i32]) -> String {
        let mut s = String::new();
        for &id in ids {
            if id == EOT {
                break;
            }
            if id >= 4 && (id as usize) < self.to_char.len() {
                s.push(self.to_char[id as usize]);
            }
        }
        s
    }

    /// Validate this table against the manifest's vocab array.
    pub fn check_manifest_vocab(&self, vocab: &[String]) -> Result<()> {
        if vocab.len() != 64 {
            bail!("manifest vocab has {} entries, expected 64", vocab.len());
        }
        let specials = ["<pad>", "<bos>", "<eot>", "<sep>"];
        for (i, want) in specials.iter().enumerate() {
            if vocab[i] != *want {
                bail!("manifest vocab[{i}] = {:?}, expected {want}", vocab[i]);
            }
        }
        for (i, c) in CHARS.chars().enumerate() {
            if vocab[i + 4] != c.to_string() {
                bail!("manifest vocab[{}] = {:?}, expected {c:?}", i + 4, vocab[i + 4]);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_is_64() {
        assert_eq!(CHARS.chars().count(), 60);
        assert_eq!(Tokenizer::default().vocab_size(), 64);
    }

    #[test]
    fn roundtrip_plain_text() {
        let t = Tokenizer::default();
        let s = "what color is alba? 3+4*2=11, ok!";
        assert_eq!(t.decode(&t.encode(s).unwrap()), s);
    }

    #[test]
    fn rejects_out_of_vocab() {
        let t = Tokenizer::default();
        assert!(t.encode("ALBA").is_err()); // no uppercase
        assert!(t.encode("héllo").is_err());
    }

    #[test]
    fn specials_render() {
        let t = Tokenizer::default();
        assert_eq!(t.decode(&[BOS, 4, SEP, 5, EOT, PAD, PAD]), "<bos>a<sep>b<eot>");
    }

    #[test]
    fn decode_until_eot_stops() {
        let t = Tokenizer::default();
        let ids = [BOS, 4, 5, EOT, 6, 7];
        assert_eq!(t.decode_until_eot(&ids), "ab");
    }

    #[test]
    fn char_ids_match_python_layout() {
        let t = Tokenizer::default();
        // 'a' is the first char after 4 specials; space is index 36+4.
        assert_eq!(t.encode("a").unwrap(), vec![4]);
        assert_eq!(t.encode("0").unwrap(), vec![30]);
        assert_eq!(t.encode(" ").unwrap(), vec![40]);
    }

    #[test]
    fn manifest_check_catches_drift() {
        let t = Tokenizer::default();
        let mut vocab: Vec<String> = ["<pad>", "<bos>", "<eot>", "<sep>"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        vocab.extend(CHARS.chars().map(|c| c.to_string()));
        t.check_manifest_vocab(&vocab).unwrap();
        vocab[10] = "Z".into();
        assert!(t.check_manifest_vocab(&vocab).is_err());
    }
}
