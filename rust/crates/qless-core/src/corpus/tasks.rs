//! Task generators for the four corpus sources + the shared format helpers
//! reused verbatim by the benchmark builders (`eval::benchmarks`), so the
//! skills in the training mix transfer to evaluation exactly like the
//! paper's Flan/CoT/Dolly → MMLU/BBH/TyDiQA alignment.

use super::sample::{Sample, Source};
use super::world::{Fact, World};
use super::Tokenizer;
use crate::util::Rng;

pub const OPTION_LETTERS: [&str; 4] = ["a", "b", "c", "d"];

// ---------------------------------------------------------------------------
// shared format helpers (single source of truth for train & eval formats)
// ---------------------------------------------------------------------------

/// Multiple-choice prompt: passage clause + question + lettered options.
/// `options` holds 4 value strings; the answer is the letter of the correct
/// one. This is the SynMC / synflan-MC format.
pub fn mc_prompt(fact: &Fact, options: &[&str]) -> String {
    let mut s = format!("{}. which is the {} of {}?", fact.clause(), fact.attr_name(), fact.entity);
    for (i, opt) in options.iter().enumerate() {
        s.push_str(&format!(" {} {}", OPTION_LETTERS[i], opt));
    }
    s
}

/// Extraction-QA prompt: multi-fact passage + question (SynQA / syndolly).
pub fn qa_prompt(passage: &[Fact], ask: &Fact) -> String {
    let mut s = String::new();
    for f in passage {
        s.push_str(&f.clause());
        s.push_str(". ");
    }
    s.push_str(&format!("what {} is {}?", ask.attr_name(), ask.entity));
    s
}

/// A 2-step arithmetic expression with its chain-of-thought answer
/// (SynArith / syncot). Returns (prompt, cot_answer, final_value).
pub fn arith_task(rng: &mut Rng) -> (String, String, i64) {
    let a = rng.below(10) as i64;
    let b = rng.below(10) as i64;
    let c = rng.below(10) as i64;
    match rng.below(4) {
        0 => {
            // a+b*c: multiply first
            let p = b * c;
            let r = a + p;
            (format!("{a}+{b}*{c}="), format!("{a}+{b}*{c} = {a}+{p} = {r}"), r)
        }
        1 => {
            let p = a * b;
            let r = p + c;
            (format!("{a}*{b}+{c}="), format!("{a}*{b}+{c} = {p}+{c} = {r}"), r)
        }
        2 => {
            let p = a + b;
            let r = p - c;
            (format!("{a}+{b}-{c}="), format!("{a}+{b}-{c} = {p}-{c} = {r}"), r)
        }
        _ => {
            let p = a * b;
            let r = p - c;
            (format!("{a}*{b}-{c}="), format!("{a}*{b}-{c} = {p}-{c} = {r}"), r)
        }
    }
}

/// Parse the final value out of a chain-of-thought answer ("… = N").
pub fn arith_final(answer: &str) -> Option<i64> {
    answer.rsplit('=').next()?.trim().parse().ok()
}

// ---------------------------------------------------------------------------
// per-source generators
// ---------------------------------------------------------------------------

/// Generate one training sample for `source`, guaranteed to encode within
/// `max_len` (retries with fresh randomness; the formats are sized to fit).
pub fn generate(
    source: Source,
    world: &World,
    rng: &mut Rng,
    tok: &Tokenizer,
    max_len: usize,
) -> Sample {
    for _ in 0..64 {
        let s = match source {
            Source::SynFlan => gen_flan(world, rng),
            Source::SynCot => gen_cot(rng),
            Source::SynDolly => gen_dolly(world, rng),
            Source::SynOasst => gen_oasst(world, rng),
        };
        if s.encoded_len() <= max_len && s.try_encode(tok, max_len).is_ok() {
            return s;
        }
    }
    panic!("task generator for {source} cannot fit max_len={max_len}");
}

/// synflan: option-selection over facts (the SynMC-aligned skill) mixed
/// with generic string/count instructions — a broad, medium-relevance pool.
fn gen_flan(world: &World, rng: &mut Rng) -> Sample {
    match rng.below(5) {
        0 | 1 => {
            // MC over a *training* fact — the skill SynMC needs.
            let fact = world.train_fact(rng);
            let mut opts = world.distractors(&fact, 4, rng);
            let correct = rng.below(4);
            opts.insert(correct, fact.value_name());
            Sample::new(
                Source::SynFlan,
                mc_prompt(&fact, &opts),
                OPTION_LETTERS[correct].to_string(),
            )
        }
        2 => {
            let w = pick_word(world, rng);
            Sample::new(Source::SynFlan, format!("reverse {w}"), w.chars().rev().collect::<String>())
        }
        3 => {
            let w = pick_word(world, rng);
            Sample::new(Source::SynFlan, format!("count letters in {w}"), w.len().to_string())
        }
        _ => {
            let n = rng.below(100);
            let ans = if n % 2 == 0 { "even" } else { "odd" };
            Sample::new(Source::SynFlan, format!("is {n} even or odd?"), ans)
        }
    }
}

/// syncot: chain-of-thought arithmetic (the SynArith-aligned skill).
fn gen_cot(rng: &mut Rng) -> Sample {
    let (prompt, answer, _) = arith_task(rng);
    Sample::new(Source::SynCot, prompt, answer)
}

/// syndolly: passage-grounded extraction QA (the SynQA-aligned skill).
fn gen_dolly(world: &World, rng: &mut Rng) -> Sample {
    let n_facts = 2 + rng.below(2); // 2–3 clause passage
    let mut facts: Vec<Fact> = (0..n_facts).map(|_| world.train_fact(rng)).collect();
    // ensure asked entity+attr is unambiguous within the passage
    facts.dedup_by(|a, b| a.entity == b.entity && a.attr == b.attr);
    let ask = facts[rng.below(facts.len())].clone();
    Sample::new(Source::SynDolly, qa_prompt(&facts, &ask), ask.value_name().to_string())
}

/// synoasst: chit-chat — realistic filler with *low* relevance to every
/// benchmark; random selection wastes budget here, targeted selection
/// should not (paper Fig. 5's Oasst fraction).
fn gen_oasst(world: &World, rng: &mut Rng) -> Sample {
    match rng.below(6) {
        0 => Sample::new(Source::SynOasst, "hello there", "hello! how can i help you today?"),
        1 => Sample::new(Source::SynOasst, "how are you doing", "i am doing well, thank you for asking"),
        2 => Sample::new(Source::SynOasst, "what is your name", "i am sim, a small language model"),
        3 => Sample::new(
            Source::SynOasst,
            "good morning | good morning! | can you chat with me",
            "of course, i am happy to chat",
        ),
        4 => {
            let w = pick_word(world, rng);
            Sample::new(Source::SynOasst, format!("please say {w}"), w)
        }
        _ => Sample::new(Source::SynOasst, "thanks for the help", "you are welcome! anytime"),
    }
}

fn pick_word(world: &World, rng: &mut Rng) -> String {
    match rng.below(3) {
        0 => world.entities[rng.below(world.entities.len())].clone(),
        1 => {
            let a = rng.below(5);
            super::world::VALUES[a][rng.below(super::world::VALUES[a].len())].to_string()
        }
        _ => super::world::ATTRIBUTES[rng.below(5)].to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (World, Rng, Tokenizer) {
        (World::generate(1), Rng::new(2), Tokenizer::default())
    }

    #[test]
    fn all_sources_generate_and_fit() {
        let (w, mut rng, tok) = setup();
        for source in Source::ALL {
            for _ in 0..100 {
                let s = generate(source, &w, &mut rng, &tok, 96);
                assert_eq!(s.source, source);
                assert!(s.encoded_len() <= 96, "{source}: {:?}", s.prompt);
                assert!(!s.answer.is_empty());
            }
        }
    }

    #[test]
    fn mc_prompt_format() {
        let f = Fact { entity: "bodo".into(), attr: 0, value: 0 };
        let p = mc_prompt(&f, &["red", "blue", "green", "gold"]);
        assert_eq!(
            p,
            "bodo color red. which is the color of bodo? a red b blue c green d gold"
        );
    }

    #[test]
    fn qa_prompt_contains_passage_and_question() {
        let f1 = Fact { entity: "bodo".into(), attr: 0, value: 1 };
        let f2 = Fact { entity: "kira".into(), attr: 2, value: 0 };
        let p = qa_prompt(&[f1.clone(), f2], &f1);
        assert!(p.starts_with("bodo color blue. kira food cake. "));
        assert!(p.ends_with("what color is bodo?"));
    }

    #[test]
    fn arith_cot_is_consistent() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let (prompt, answer, val) = arith_task(&mut rng);
            assert!(answer.starts_with(prompt.trim_end_matches('=')));
            assert_eq!(arith_final(&answer), Some(val));
        }
    }

    #[test]
    fn arith_final_parses() {
        assert_eq!(arith_final("1+2*3 = 1+6 = 7"), Some(7));
        assert_eq!(arith_final("5*0-9 = 0-9 = -9"), Some(-9));
        assert_eq!(arith_final("junk"), None);
    }

    #[test]
    fn dolly_answer_is_in_passage() {
        let (w, mut rng, tok) = setup();
        for _ in 0..50 {
            let s = generate(Source::SynDolly, &w, &mut rng, &tok, 96);
            assert!(s.prompt.contains(&s.answer), "{:?} {:?}", s.prompt, s.answer);
        }
    }

    #[test]
    fn flan_mc_answer_is_letter() {
        let (w, mut rng, tok) = setup();
        let mut seen_mc = false;
        for _ in 0..100 {
            let s = generate(Source::SynFlan, &w, &mut rng, &tok, 96);
            if s.prompt.contains("which is the") {
                seen_mc = true;
                assert!(OPTION_LETTERS.contains(&s.answer.as_str()));
            }
        }
        assert!(seen_mc);
    }
}
