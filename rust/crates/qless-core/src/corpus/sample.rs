//! Instruction-tuning sample representation + fixed-shape encoding.
//!
//! Chat template (char-level): `<bos> prompt <sep> answer <eot>`, padded to
//! the model's static sequence length. The loss mask covers the answer span
//! plus `<eot>` only — the instruction-tuning convention whose token-mean
//! gradient carries the sequence-length bias that LESS's normalization
//! (paper Eq. 2) corrects.

use anyhow::{bail, Result};

use super::tokenizer::{Tokenizer, BOS, EOT, SEP};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Source {
    SynFlan,
    SynCot,
    SynDolly,
    SynOasst,
}

impl Source {
    pub const ALL: [Source; 4] =
        [Source::SynFlan, Source::SynCot, Source::SynDolly, Source::SynOasst];

    pub fn name(&self) -> &'static str {
        match self {
            Source::SynFlan => "synflan",
            Source::SynCot => "syncot",
            Source::SynDolly => "syndolly",
            Source::SynOasst => "synoasst",
        }
    }
}

impl std::fmt::Display for Source {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[derive(Debug, Clone)]
pub struct Sample {
    pub id: usize,
    pub source: Source,
    pub prompt: String,
    pub answer: String,
}

/// Fixed-shape encoding ready for the AOT graphs.
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedSample {
    /// `[seq]` token ids, zero padded.
    pub tokens: Vec<i32>,
    /// `[seq]` loss weights: 1.0 on answer tokens + `<eot>`.
    pub loss_mask: Vec<f32>,
    /// Position of the last prompt token (`<sep>`): decode starts at this.
    pub prompt_end: usize,
    /// Number of loss-masked tokens.
    pub answer_len: usize,
}

impl Sample {
    pub fn new(source: Source, prompt: impl Into<String>, answer: impl Into<String>) -> Sample {
        Sample { id: usize::MAX, source, prompt: prompt.into(), answer: answer.into() }
    }

    /// Total encoded length (specials included) — generator fit checks.
    pub fn encoded_len(&self) -> usize {
        1 + self.prompt.chars().count() + 1 + self.answer.chars().count() + 1
    }

    /// Encode into fixed `[seq]` buffers. Panics in debug if the sample does
    /// not fit; generators must guarantee fit via [`Sample::encoded_len`].
    pub fn encode(&self, tok: &Tokenizer, seq: usize) -> EncodedSample {
        self.try_encode(tok, seq).expect("sample must fit seq (generator bug)")
    }

    pub fn try_encode(&self, tok: &Tokenizer, seq: usize) -> Result<EncodedSample> {
        let p = tok.encode(&self.prompt)?;
        let a = tok.encode(&self.answer)?;
        let total = 1 + p.len() + 1 + a.len() + 1;
        if total > seq {
            bail!("sample length {total} exceeds seq {seq}: {:?}", self.prompt);
        }
        if a.is_empty() {
            bail!("empty answer");
        }
        let mut tokens = Vec::with_capacity(seq);
        tokens.push(BOS);
        tokens.extend_from_slice(&p);
        tokens.push(SEP);
        let prompt_end = tokens.len() - 1;
        let answer_start = tokens.len();
        tokens.extend_from_slice(&a);
        tokens.push(EOT);
        let answer_len = tokens.len() - answer_start;
        tokens.resize(seq, 0);
        let mut loss_mask = vec![0f32; seq];
        for m in loss_mask.iter_mut().skip(answer_start).take(answer_len) {
            *m = 1.0;
        }
        Ok(EncodedSample { tokens, loss_mask, prompt_end, answer_len })
    }

    /// Prompt-only encoding for generation: `<bos> prompt <sep>` + pads.
    pub fn encode_prompt(&self, tok: &Tokenizer, seq: usize) -> Result<EncodedSample> {
        let p = tok.encode(&self.prompt)?;
        if 2 + p.len() >= seq {
            bail!("prompt too long for decode: {}", self.prompt);
        }
        let mut tokens = Vec::with_capacity(seq);
        tokens.push(BOS);
        tokens.extend_from_slice(&p);
        tokens.push(SEP);
        let prompt_end = tokens.len() - 1;
        tokens.resize(seq, 0);
        Ok(EncodedSample { tokens, loss_mask: vec![0.0; seq], prompt_end, answer_len: 0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::default()
    }

    #[test]
    fn encode_layout() {
        let s = Sample::new(Source::SynDolly, "ab", "cd");
        let e = s.encode(&tok(), 12);
        assert_eq!(&e.tokens[..7], &[BOS, 4, 5, SEP, 6, 7, EOT]);
        assert_eq!(&e.tokens[7..], &[0; 5]);
        assert_eq!(e.loss_mask[..4], [0.0; 4]);
        assert_eq!(e.loss_mask[4..7], [1.0; 3]); // c, d, <eot>
        assert_eq!(e.prompt_end, 3);
        assert_eq!(e.answer_len, 3);
    }

    #[test]
    fn encoded_len_matches() {
        let s = Sample::new(Source::SynFlan, "abc", "de");
        assert_eq!(s.encoded_len(), 1 + 3 + 1 + 2 + 1);
        let e = s.encode(&tok(), 8);
        let used = e.tokens.iter().filter(|&&t| t != 0).count();
        assert_eq!(used, s.encoded_len());
    }

    #[test]
    fn too_long_errors() {
        let s = Sample::new(Source::SynFlan, "a".repeat(95), "b");
        assert!(s.try_encode(&tok(), 96).is_err());
    }

    #[test]
    fn empty_answer_errors() {
        let s = Sample::new(Source::SynFlan, "a", "");
        assert!(s.try_encode(&tok(), 16).is_err());
    }

    #[test]
    fn prompt_encoding_has_no_loss() {
        let s = Sample::new(Source::SynCot, "1+1=", "2");
        let e = s.encode_prompt(&tok(), 16).unwrap();
        assert!(e.loss_mask.iter().all(|&m| m == 0.0));
        assert_eq!(e.tokens[e.prompt_end], SEP);
        assert_eq!(e.answer_len, 0);
    }

    #[test]
    fn mask_sums_to_answer_len_plus_eot() {
        let s = Sample::new(Source::SynOasst, "hello", "hi there");
        let e = s.encode(&tok(), 32);
        let m: f32 = e.loss_mask.iter().sum();
        assert_eq!(m as usize, "hi there".len() + 1);
    }
}
