//! Synthetic instruction-tuning corpus — the stand-in for the paper's
//! Flan v2 / CoT / Dolly / OpenAssistant mix (270K examples, §4.1).
//!
//! Four generators with the paper's 37/37/6/20% source proportions produce
//! tasks whose *skills* align with exactly one benchmark each, so influence
//! -based selection has a real signal to find (DESIGN.md §2):
//!
//! * [`Source::SynFlan`]  — option-selection + string/count tasks  → SynMC
//! * [`Source::SynCot`]   — chain-of-thought arithmetic            → SynArith
//! * [`Source::SynDolly`] — passage-grounded extraction QA         → SynQA
//! * [`Source::SynOasst`] — multi-turn chit-chat (low relevance everywhere)

pub mod sample;
pub mod tasks;
pub mod tokenizer;
pub mod world;

pub use sample::{EncodedSample, Sample, Source};
pub use tokenizer::Tokenizer;
pub use world::World;

use crate::util::Rng;

/// Paper mix: Flan 100K, CoT 100K, Dolly 15K, Oasst 55K of 270K total.
pub const SOURCE_FRACS: [(Source, f64); 4] = [
    (Source::SynFlan, 100.0 / 270.0),
    (Source::SynCot, 100.0 / 270.0),
    (Source::SynDolly, 15.0 / 270.0),
    (Source::SynOasst, 55.0 / 270.0),
];

/// Generate the full training corpus: `n` samples in the paper's source
/// proportions, shuffled, with unique ids.
pub fn generate_corpus(n: usize, seed: u64, tok: &Tokenizer, max_len: usize) -> Vec<Sample> {
    let world = World::generate(seed);
    let mut rng = Rng::new(seed).fork(0xC0_8915);
    let mut out = Vec::with_capacity(n);
    for (source, frac) in SOURCE_FRACS {
        let count = ((n as f64) * frac).round() as usize;
        for _ in 0..count {
            out.push(tasks::generate(source, &world, &mut rng, tok, max_len));
        }
    }
    // Top up rounding losses from the largest source.
    while out.len() < n {
        out.push(tasks::generate(Source::SynFlan, &world, &mut rng, tok, max_len));
    }
    out.truncate(n);
    rng.shuffle(&mut out);
    for (i, s) in out.iter_mut().enumerate() {
        s.id = i;
    }
    out
}

/// Deterministic corpus **extension** for incremental ingest: generation
/// `generation` (≥ 1) appends `n` fresh samples drawn with the same
/// per-source mixture as [`generate_corpus`], from an RNG stream salted by
/// the generation — so segment `generation`'s samples regenerate
/// bit-identically (with ids starting at `id_base`, the segment's global
/// start row) without re-deriving any earlier generation's rows. The base
/// corpus is generation 0; extensions never overlap its stream.
pub fn extend_corpus(
    n: usize,
    seed: u64,
    generation: u64,
    id_base: usize,
    tok: &Tokenizer,
    max_len: usize,
) -> Vec<Sample> {
    let world = World::generate(seed);
    let mut rng = Rng::new(seed ^ 0xE87E_5D00).fork(generation);
    let mut out = Vec::with_capacity(n);
    for (source, frac) in SOURCE_FRACS {
        let count = ((n as f64) * frac).round() as usize;
        for _ in 0..count {
            out.push(tasks::generate(source, &world, &mut rng, tok, max_len));
        }
    }
    while out.len() < n {
        out.push(tasks::generate(Source::SynFlan, &world, &mut rng, tok, max_len));
    }
    out.truncate(n);
    rng.shuffle(&mut out);
    for (i, s) in out.iter_mut().enumerate() {
        s.id = id_base + i;
    }
    out
}

/// Per-source sample counts (corpus statistics / Fig. 5 denominators).
pub fn source_counts(samples: &[Sample]) -> [(Source, usize); 4] {
    let mut counts = [
        (Source::SynFlan, 0),
        (Source::SynCot, 0),
        (Source::SynDolly, 0),
        (Source::SynOasst, 0),
    ];
    for s in samples {
        for c in counts.iter_mut() {
            if c.0 == s.source {
                c.1 += 1;
            }
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_has_requested_size_and_mix() {
        let tok = Tokenizer::default();
        let c = generate_corpus(1000, 7, &tok, 96);
        assert_eq!(c.len(), 1000);
        let counts = source_counts(&c);
        let get = |s: Source| counts.iter().find(|(x, _)| *x == s).unwrap().1;
        // 37/37/6/20% within rounding
        assert!((get(Source::SynFlan) as i64 - 370).abs() <= 15);
        assert!((get(Source::SynCot) as i64 - 370).abs() <= 5);
        assert!((get(Source::SynDolly) as i64 - 56).abs() <= 5);
        assert!((get(Source::SynOasst) as i64 - 204).abs() <= 5);
    }

    #[test]
    fn corpus_is_deterministic() {
        let tok = Tokenizer::default();
        let a = generate_corpus(100, 3, &tok, 96);
        let b = generate_corpus(100, 3, &tok, 96);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn corpus_ids_unique_and_ordered() {
        let tok = Tokenizer::default();
        let c = generate_corpus(200, 9, &tok, 96);
        for (i, s) in c.iter().enumerate() {
            assert_eq!(s.id, i);
        }
    }

    #[test]
    fn extensions_are_deterministic_and_generation_distinct() {
        let tok = Tokenizer::default();
        let a = extend_corpus(50, 3, 1, 100, &tok, 96);
        let b = extend_corpus(50, 3, 1, 100, &tok, 96);
        assert_eq!(a.len(), 50);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.prompt, y.prompt, "sample {i} must regenerate bit-identically");
            assert_eq!(x.answer, y.answer);
            assert_eq!(x.id, 100 + i, "ids start at the segment's global row");
        }
        // a different generation draws different samples from the same seed
        let g2 = extend_corpus(50, 3, 2, 150, &tok, 96);
        assert!(
            a.iter().zip(&g2).any(|(x, y)| x.prompt != y.prompt),
            "generations must not repeat each other's rows"
        );
        // the extension keeps the corpus mixture: every source appears
        let counts = source_counts(&extend_corpus(400, 3, 1, 0, &tok, 96));
        assert!(counts.iter().all(|(_, c)| *c > 0), "{counts:?}");
    }

    #[test]
    fn all_samples_fit_max_len() {
        let tok = Tokenizer::default();
        for s in generate_corpus(500, 11, &tok, 96) {
            let enc = s.encode(&tok, 96);
            assert!(enc.answer_len > 0, "{:?}", s.prompt);
        }
    }
}
