//! The entity–attribute fact world behind the synthetic tasks.
//!
//! A deterministic set of invented entities ("bodo", "kira", …) each with a
//! value for every attribute (color, size, food, place, pet). Passage-based
//! tasks quote facts verbatim, so the *skill* the model must learn is
//! extraction/option-matching — transferable to held-out eval facts, which
//! is what makes benchmark scores sensitive to data selection rather than
//! to memorization (DESIGN.md §2).

use crate::util::Rng;

pub const ATTRIBUTES: [&str; 5] = ["color", "size", "food", "place", "pet"];

pub const VALUES: [&[&str]; 5] = [
    &["red", "blue", "green", "gray", "pink", "gold"],
    &["big", "small", "tiny", "huge", "wide", "flat"],
    &["cake", "rice", "soup", "corn", "figs", "stew"],
    &["home", "lake", "city", "farm", "cave", "port"],
    &["cat", "dog", "fox", "owl", "hen", "bee"],
];

const CONSONANTS: &str = "bdfgklmnprstvz";
const VOWELS: &str = "aeiou";

#[derive(Debug, Clone)]
pub struct Fact {
    pub entity: String,
    /// Index into [`ATTRIBUTES`].
    pub attr: usize,
    /// Index into `VALUES[attr]`.
    pub value: usize,
}

impl Fact {
    pub fn attr_name(&self) -> &'static str {
        ATTRIBUTES[self.attr]
    }

    pub fn value_name(&self) -> &'static str {
        VALUES[self.attr][self.value]
    }

    /// The passage clause: `"bodo color red"`.
    pub fn clause(&self) -> String {
        format!("{} {} {}", self.entity, self.attr_name(), self.value_name())
    }
}

#[derive(Debug, Clone)]
pub struct World {
    pub entities: Vec<String>,
    /// `values[e][a]` = value index of entity `e` for attribute `a`.
    pub values: Vec<[usize; 5]>,
    /// Entity index split: `0..train_split` may appear in training data,
    /// the rest are reserved for evaluation.
    pub train_split: usize,
}

impl World {
    pub fn generate(seed: u64) -> World {
        let mut rng = Rng::new(seed).fork(0x0071D);
        let n = 96;
        let mut entities = Vec::with_capacity(n);
        let cs: Vec<char> = CONSONANTS.chars().collect();
        let vs: Vec<char> = VOWELS.chars().collect();
        while entities.len() < n {
            let syllables = 2;
            let mut name = String::new();
            for _ in 0..syllables {
                name.push(*rng.pick(&cs));
                name.push(*rng.pick(&vs));
            }
            if !entities.contains(&name) {
                entities.push(name);
            }
        }
        let values = (0..n)
            .map(|_| {
                let mut row = [0usize; 5];
                for (a, slot) in row.iter_mut().enumerate() {
                    *slot = rng.below(VALUES[a].len());
                }
                row
            })
            .collect();
        World { entities, values, train_split: n * 4 / 5 }
    }

    pub fn fact(&self, entity_idx: usize, attr: usize) -> Fact {
        Fact {
            entity: self.entities[entity_idx].clone(),
            attr,
            value: self.values[entity_idx][attr],
        }
    }

    /// Random fact over training entities.
    pub fn train_fact(&self, rng: &mut Rng) -> Fact {
        let e = rng.below(self.train_split);
        self.fact(e, rng.below(5))
    }

    /// Random fact over held-out eval entities.
    pub fn eval_fact(&self, rng: &mut Rng) -> Fact {
        let e = self.train_split + rng.below(self.entities.len() - self.train_split);
        self.fact(e, rng.below(5))
    }

    /// `k−1` distractor values (distinct from the fact's own value) from the
    /// same attribute — multiple-choice options.
    pub fn distractors(&self, fact: &Fact, k: usize, rng: &mut Rng) -> Vec<&'static str> {
        let pool = VALUES[fact.attr];
        assert!(k <= pool.len(), "not enough values for {k} options");
        let mut idx: Vec<usize> = (0..pool.len()).filter(|&i| i != fact.value).collect();
        rng.shuffle(&mut idx);
        idx.truncate(k - 1);
        idx.into_iter().map(|i| pool[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_deterministic() {
        let a = World::generate(1);
        let b = World::generate(1);
        assert_eq!(a.entities, b.entities);
        assert_eq!(a.values, b.values);
        assert_ne!(a.entities, World::generate(2).entities);
    }

    #[test]
    fn entities_unique_and_in_vocab() {
        let w = World::generate(3);
        let mut e = w.entities.clone();
        e.sort();
        e.dedup();
        assert_eq!(e.len(), w.entities.len());
        let tok = crate::corpus::Tokenizer::default();
        for name in &w.entities {
            tok.encode(name).unwrap();
        }
    }

    #[test]
    fn split_separates_train_and_eval() {
        let w = World::generate(4);
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let f = w.train_fact(&mut rng);
            assert!(w.entities[..w.train_split].contains(&f.entity));
            let g = w.eval_fact(&mut rng);
            assert!(w.entities[w.train_split..].contains(&g.entity));
        }
    }

    #[test]
    fn distractors_exclude_answer() {
        let w = World::generate(5);
        let mut rng = Rng::new(1);
        let f = w.train_fact(&mut rng);
        let ds = w.distractors(&f, 4, &mut rng);
        assert_eq!(ds.len(), 3);
        assert!(!ds.contains(&f.value_name()));
        let mut u = ds.clone();
        u.sort();
        u.dedup();
        assert_eq!(u.len(), 3);
    }

    #[test]
    fn clause_format() {
        let f = Fact { entity: "bodo".into(), attr: 0, value: 0 };
        assert_eq!(f.clause(), "bodo color red");
    }
}
