//! Runtime kernel dispatch: pick the fastest scan-kernel variant this CPU
//! supports, once, at first use.
//!
//! The scoring kernels (`influence::native` in `qless-datastore`) exist in
//! four flavors sharing one arithmetic definition:
//!
//! * [`Kernel::Scalar`] — the original unblocked per-row loops, retained
//!   verbatim as the pinned reference every other variant is
//!   property-tested against (bit-exact for the 1-bit and integer-domain
//!   paths).
//! * [`Kernel::Blocked`] — the rows×tasks-tiled loop structure with the
//!   scalar inner dot. Always available; isolates the blocking change
//!   from the intrinsics change in tests and benches.
//! * [`Kernel::Avx2`] — blocked loops with AVX2 intrinsics for the i8×u8
//!   integer dot and the XNOR+popcount agree kernel (x86_64 with AVX2).
//! * [`Kernel::Neon`] — the same with NEON intrinsics (aarch64 baseline).
//!
//! Detection runs once per process ([`active`] memoizes in a `OnceLock`)
//! and is overridable for testing via `QLESS_KERNEL=scalar|blocked|avx2|
//! neon` — forcing a variant the CPU lacks logs a warning and falls back
//! to detection, except `scalar`/`blocked`, which always honor the
//! override (CI forces `scalar` to pin the reference path). The resolved
//! variant is published as a `kernel_dispatch{variant="…"}` gauge in the
//! process-global metrics registry so `qless stats` and the Prometheus
//! scrape show which kernel the process runs.

use std::sync::OnceLock;

/// One scan-kernel variant. All variants exist as enum values on every
/// architecture (so tests and benches can *name* them portably); whether a
/// variant can run here is [`Kernel::supported`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Pinned reference: the original unblocked scalar loops.
    Scalar,
    /// Rows×tasks blocking with the scalar inner dot (always available).
    Blocked,
    /// Blocked loops + AVX2 intrinsics (x86_64, runtime-detected).
    Avx2,
    /// Blocked loops + NEON intrinsics (aarch64 baseline).
    Neon,
}

impl Kernel {
    /// Stable lowercase label — the `QLESS_KERNEL` value that forces this
    /// variant, and the `variant=` metric label.
    pub fn label(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Blocked => "blocked",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    /// Can this variant run on the current CPU?
    pub fn supported(self) -> bool {
        match self {
            Kernel::Scalar | Kernel::Blocked => true,
            Kernel::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Kernel::Neon => {
                // NEON is baseline on aarch64: every target the `neon`
                // cfg gate compiles for has it.
                cfg!(target_arch = "aarch64")
            }
        }
    }

    /// Parse a `QLESS_KERNEL` value; `None` for unknown strings.
    pub fn from_label(s: &str) -> Option<Kernel> {
        match s {
            "scalar" => Some(Kernel::Scalar),
            "blocked" => Some(Kernel::Blocked),
            "avx2" => Some(Kernel::Avx2),
            "neon" => Some(Kernel::Neon),
            _ => None,
        }
    }
}

/// The best variant the current CPU supports: SIMD when detected, else
/// the blocked-scalar kernel (never [`Kernel::Scalar`] — the reference is
/// only ever *forced*, so perf regressions can't hide behind dispatch).
pub fn detect() -> Kernel {
    if Kernel::Avx2.supported() {
        Kernel::Avx2
    } else if Kernel::Neon.supported() {
        Kernel::Neon
    } else {
        Kernel::Blocked
    }
}

/// Resolve an override string (the `QLESS_KERNEL` env value) against the
/// machine: `None`/`"auto"` detect, a supported label forces, an
/// unsupported or unknown label warns and detects. Pure given its input —
/// unit-testable without touching the process environment.
pub fn resolve(over: Option<&str>) -> Kernel {
    match over {
        None | Some("") | Some("auto") => detect(),
        Some(s) => match Kernel::from_label(s) {
            Some(k) if k.supported() => k,
            Some(k) => {
                crate::warn_!(
                    "QLESS_KERNEL={} not supported on this CPU; auto-detecting",
                    k.label()
                );
                detect()
            }
            None => {
                crate::warn_!(
                    "QLESS_KERNEL={s} unknown (scalar|blocked|avx2|neon|auto); auto-detecting"
                );
                detect()
            }
        },
    }
}

/// The process's active kernel variant: detection (or the `QLESS_KERNEL`
/// override) memoized on first call. Publishes the choice once as a
/// `kernel_dispatch{variant="…"}` gauge in the **global** registry —
/// deliberately not the thread-local override, so a test scan under
/// `with_registry` captures its own counters but dispatch identity stays
/// a process-level fact.
pub fn active() -> Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let over = std::env::var("QLESS_KERNEL").ok();
        let k = resolve(over.as_deref());
        super::obs::global().gauge_set(&format!("kernel_dispatch{{variant=\"{}\"}}", k.label()), 1);
        k
    })
}

/// Every variant that can run on this machine, reference first — the
/// equality property tests and `bench_influence` sweep this list.
pub fn available() -> Vec<Kernel> {
    [Kernel::Scalar, Kernel::Blocked, Kernel::Avx2, Kernel::Neon]
        .into_iter()
        .filter(|k| k.supported())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for k in [Kernel::Scalar, Kernel::Blocked, Kernel::Avx2, Kernel::Neon] {
            assert_eq!(Kernel::from_label(k.label()), Some(k));
        }
        assert_eq!(Kernel::from_label("sse2"), None);
        assert_eq!(Kernel::from_label("AVX2"), None); // labels are lowercase
    }

    #[test]
    fn scalar_and_blocked_always_supported() {
        assert!(Kernel::Scalar.supported());
        assert!(Kernel::Blocked.supported());
    }

    #[test]
    fn detect_never_picks_the_reference() {
        let k = detect();
        assert!(k != Kernel::Scalar, "detection must not pick the pinned reference");
        assert!(k.supported());
    }

    #[test]
    fn resolve_honors_supported_overrides_and_falls_back() {
        assert_eq!(resolve(Some("scalar")), Kernel::Scalar);
        assert_eq!(resolve(Some("blocked")), Kernel::Blocked);
        assert_eq!(resolve(None), detect());
        assert_eq!(resolve(Some("")), detect());
        assert_eq!(resolve(Some("auto")), detect());
        // unknown strings fall back to detection instead of panicking
        assert_eq!(resolve(Some("bogus")), detect());
        // an unsupported SIMD force falls back; a supported one sticks
        for simd in [Kernel::Avx2, Kernel::Neon] {
            let got = resolve(Some(simd.label()));
            if simd.supported() {
                assert_eq!(got, simd);
            } else {
                assert_eq!(got, detect());
            }
        }
    }

    #[test]
    fn active_is_supported_and_stable() {
        let a = active();
        assert!(a.supported());
        assert_eq!(active(), a); // memoized
        if let Ok(forced) = std::env::var("QLESS_KERNEL") {
            if let Some(k) = Kernel::from_label(&forced) {
                if k.supported() {
                    assert_eq!(a, k, "QLESS_KERNEL={forced} must force the variant");
                }
            }
        }
    }

    #[test]
    fn available_lists_reference_first_and_only_supported() {
        let avail = available();
        assert_eq!(avail[0], Kernel::Scalar);
        assert!(avail.contains(&Kernel::Blocked));
        assert!(avail.iter().all(|k| k.supported()));
    }
}
