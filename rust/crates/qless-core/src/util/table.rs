//! Aligned console / markdown table rendering for the experiment reports.
//!
//! Every `xp` harness prints the same rows the paper's tables report; this
//! type owns alignment, bold/underline annotations for best / second-best
//! entries (mirroring the paper's formatting), and markdown export.

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Mark the best (`**v**`) and second best (`_v_`) numeric value in a
    /// column, parsing cells as f64 (non-numeric cells are skipped) —
    /// mirrors the paper's bold/underline convention.
    pub fn mark_best(&mut self, col: usize, higher_is_better: bool) {
        let mut vals: Vec<(usize, f64)> = self
            .rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| parse_cell(&r[col]).map(|v| (i, v)))
            .collect();
        if vals.len() < 2 {
            return;
        }
        vals.sort_by(|a, b| {
            if higher_is_better {
                b.1.partial_cmp(&a.1).unwrap()
            } else {
                a.1.partial_cmp(&b.1).unwrap()
            }
        });
        let best = vals[0].0;
        let second = vals[1].0;
        self.rows[best][col] = format!("**{}**", self.rows[best][col]);
        self.rows[second][col] = format!("_{}_", self.rows[second][col]);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned console block.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:w$} | ", c, w = w[i]));
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&"-".repeat(wi + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &w));
            out.push('\n');
        }
        out
    }

    /// Markdown (same shape — the aligned render *is* valid markdown).
    pub fn to_markdown(&self) -> String {
        self.render()
    }
}

fn parse_cell(s: &str) -> Option<f64> {
    // first whitespace-separated token, stripped of annotation chars
    let tok = s.trim().split_whitespace().next()?;
    tok.trim_matches(|c| c == '*' || c == '_').parse().ok()
}

/// Format a fraction as `xx.yy` percent (paper tables are 2-dp percents).
pub fn pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

/// Human-readable byte count, binary units.
pub fn human_bytes(n: u64) -> String {
    const U: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut i = 0;
    while v >= 1024.0 && i < U.len() - 1 {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", U[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["method", "acc"]);
        t.row(vec!["random".into(), "50.00".into()]);
        t.row(vec!["qless-1bit".into(), "65.93".into()]);
        let s = t.render();
        assert!(s.contains("| method     | acc"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn mark_best_bold_and_underline() {
        let mut t = Table::new("", &["m", "v"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["b".into(), "3.0".into()]);
        t.row(vec!["c".into(), "2.0".into()]);
        t.mark_best(1, true);
        assert_eq!(t.rows[1][1], "**3.0**");
        assert_eq!(t.rows[2][1], "_2.0_");
    }

    #[test]
    fn mark_best_lower_is_better() {
        let mut t = Table::new("", &["m", "v"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["b".into(), "3.0".into()]);
        t.mark_best(1, false);
        assert_eq!(t.rows[0][1], "**1.0**");
    }

    #[test]
    fn mark_best_skips_non_numeric() {
        let mut t = Table::new("", &["m", "v"]);
        t.row(vec!["a".into(), "-".into()]);
        t.row(vec!["b".into(), "3.0".into()]);
        t.row(vec!["c".into(), "1.0".into()]);
        t.mark_best(1, true);
        assert_eq!(t.rows[1][1], "**3.0**");
        assert_eq!(t.rows[0][1], "-");
    }

    #[test]
    fn pct_and_bytes() {
        assert_eq!(pct(0.7035), "70.35"); // paper-style 2dp
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1024 * 1024), "1.00 MiB");
        assert!(human_bytes(17_770_000_000).starts_with("16.5")); // paper's 16.54 GB is GiB-ish
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
