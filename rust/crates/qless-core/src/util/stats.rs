//! Timing + summary statistics used by the bench harness and the pipeline's
//! stage metrics. `BenchStats` implements the measurement protocol of the
//! custom `cargo bench` harness (criterion is not in the offline vendor
//! set): warmup, N timed iterations, mean/median/p95/stddev, throughput.

use std::time::Instant;

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
    pub label: String,
}

impl Timer {
    pub fn start(label: &str) -> Timer {
        Timer { start: Instant::now(), label: label.to_string() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn stop(self) -> f64 {
        self.elapsed_s()
    }
}

/// Summary of a set of observations (seconds, losses, scores, …).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize: empty input");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| -> f64 {
        let idx = (p * (n - 1) as f64).round() as usize;
        sorted[idx.min(n - 1)]
    };
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median: q(0.5),
        p95: q(0.95),
    }
}

/// Measurement result of one bench case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Summary,
    /// Work units per iteration (bytes, samples, FLOPs …) for throughput.
    pub work_per_iter: f64,
    pub work_unit: &'static str,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        self.work_per_iter / self.secs.mean
    }

    pub fn report_line(&self) -> String {
        let tput = if self.work_per_iter > 0.0 {
            format!(
                "  {:>10.3} {}/s",
                scale_si(self.throughput()).0,
                format!("{}{}", scale_si(self.throughput()).1, self.work_unit)
            )
        } else {
            String::new()
        };
        format!(
            "{:<44} {:>10} iters  mean {:>9}  p95 {:>9}{}",
            self.name,
            self.iters,
            fmt_secs(self.secs.mean),
            fmt_secs(self.secs.p95),
            tput
        )
    }
}

fn scale_si(v: f64) -> (f64, &'static str) {
    if v >= 1e9 {
        (v / 1e9, "G")
    } else if v >= 1e6 {
        (v / 1e6, "M")
    } else if v >= 1e3 {
        (v / 1e3, "K")
    } else {
        (v, "")
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Run one bench case: `warmup` untimed runs, then timed iterations until
/// `min_time_s` elapses (at least `min_iters`).
pub fn bench<F: FnMut()>(
    name: &str,
    work_per_iter: f64,
    work_unit: &'static str,
    mut f: F,
) -> BenchResult {
    bench_cfg(name, work_per_iter, work_unit, 2, 10, 1.0, &mut f)
}

pub fn bench_cfg<F: FnMut()>(
    name: &str,
    work_per_iter: f64,
    work_unit: &'static str,
    warmup: usize,
    min_iters: usize,
    min_time_s: f64,
    f: &mut F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::new();
    let t0 = Instant::now();
    while times.len() < min_iters || t0.elapsed().as_secs_f64() < min_time_s {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
        if times.len() > 10_000 {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iters: times.len(),
        secs: summarize(&times),
        work_per_iter,
        work_unit,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = summarize(&[5.0]);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.p95, 5.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn bench_runs_enough_iters() {
        let mut n = 0;
        let r = bench_cfg("noop", 1.0, "op", 1, 5, 0.01, &mut || n += 1);
        assert!(r.iters >= 5);
        assert!(n >= r.iters);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(2e-9).ends_with("ns"));
    }

    #[test]
    fn timer_measures() {
        let t = Timer::start("x");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.stop() >= 0.004);
    }
}
