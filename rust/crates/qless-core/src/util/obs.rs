//! Process-wide observability: a metrics registry and a lightweight
//! tracing span API. Std-only by construction (the offline vendor set
//! has no `metrics`/`tracing` crates): counters and gauges are plain
//! atomics behind a name-keyed map, latency histograms are fixed-bucket
//! atomic arrays, and finished spans land in a bounded ring buffer.
//!
//! Two access paths exist on purpose:
//!
//! * [`reg`] returns the process-global [`Registry`] — the serving
//!   stack's default, scraped by the `metrics` wire verb.
//! * [`with_registry`] installs a **thread-local override** for the
//!   duration of a closure, so property tests can run a scan against a
//!   fresh registry and assert *exact* counter values without seeing
//!   traffic from parallel tests (instrumented seams only touch the
//!   registry on the calling thread, never inside pool-parallel loops).
//!
//! Tracing is off by default and costs one relaxed atomic load per
//! [`span`] call when disabled (`bench_obs` pins that to <2% of the
//! fused scan hot loop). When enabled via [`set_tracing`], spans carry a
//! trace id and parent span id (thread-local context, or explicit via
//! [`span_in`] for wire-propagated traces) and record monotonic-clock
//! durations into the owning registry's ring on drop.

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Finished spans kept per registry (oldest evicted first).
pub const SPAN_RING_CAP: usize = 2048;

/// Upper bucket bounds (µs) for latency histograms; a final +Inf bucket
/// is implicit. Spans 100µs–1s, the range a serve-path query can land in.
pub const LATENCY_BOUNDS_US: [u64; 12] = [
    100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 1_000_000,
];

// ---------------------------------------------------------------------------
// histograms

/// Fixed-bucket latency histogram: atomic per-bucket counts plus running
/// sum/count, observable lock-free from any thread.
pub struct Histo {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histo {
    fn new() -> Histo {
        Histo {
            buckets: (0..=LATENCY_BOUNDS_US.len()).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one value (µs for latency histograms; any u64 works).
    pub fn observe(&self, v: u64) {
        let i = LATENCY_BOUNDS_US.iter().position(|&b| v <= b).unwrap_or(LATENCY_BOUNDS_US.len());
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> HistoSnapshot {
        HistoSnapshot {
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one [`Histo`]: `counts[i]` pairs with
/// `LATENCY_BOUNDS_US[i]` (last entry = +Inf bucket).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistoSnapshot {
    /// Per-bucket counts, one per bound plus the +Inf bucket.
    pub counts: Vec<u64>,
    /// Sum of observed values.
    pub sum: u64,
    /// Number of observations.
    pub count: u64,
}

impl HistoSnapshot {
    /// Element-wise merge (fleet aggregation sums worker histograms).
    pub fn merge(&mut self, other: &HistoSnapshot) {
        if self.counts.is_empty() {
            self.counts = vec![0; other.counts.len()];
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    /// Upper bound (µs) of the bucket containing quantile `q` in [0,1]
    /// — the usual conservative histogram-quantile estimate. Returns
    /// `u64::MAX` when the quantile falls in the +Inf bucket, 0 when
    /// the histogram is empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return LATENCY_BOUNDS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }
}

// ---------------------------------------------------------------------------
// registry

/// One metrics domain: named counters, gauges, histograms, and the ring
/// of finished spans. The process owns one global instance ([`reg`]);
/// tests may instantiate their own and install it with [`with_registry`].
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    histos: Mutex<BTreeMap<String, Arc<Histo>>>,
    spans: Mutex<VecDeque<SpanRecord>>,
    epoch: Instant,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    /// Fresh empty registry with its own time epoch.
    pub fn new() -> Registry {
        Registry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histos: Mutex::new(BTreeMap::new()),
            spans: Mutex::new(VecDeque::new()),
            epoch: Instant::now(),
        }
    }

    /// Monotonic µs since this registry's creation (span timestamps).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Handle to the named counter (created at zero on first use).
    /// Callers on hot-ish seams may cache the `Arc` and `fetch_add`
    /// without re-taking the map lock.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Add `n` to the named counter.
    pub fn counter_add(&self, name: &str, n: u64) {
        self.counter(name).fetch_add(n, Ordering::Relaxed);
    }

    /// Handle to the named gauge (created at zero on first use).
    pub fn gauge(&self, name: &str) -> Arc<AtomicI64> {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Set the named gauge to `v`.
    pub fn gauge_set(&self, name: &str, v: i64) {
        self.gauge(name).store(v, Ordering::Relaxed);
    }

    /// Add `d` (may be negative) to the named gauge.
    pub fn gauge_add(&self, name: &str, d: i64) {
        self.gauge(name).fetch_add(d, Ordering::Relaxed);
    }

    /// Handle to the named histogram (created empty on first use).
    pub fn histo(&self, name: &str) -> Arc<Histo> {
        let mut m = self.histos.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(Histo::new())).clone()
    }

    /// Record one µs observation into the named histogram.
    pub fn observe_us(&self, name: &str, us: u64) {
        self.histo(name).observe(us);
    }

    /// Push a finished span into the bounded ring (oldest evicted).
    pub fn record_span(&self, rec: SpanRecord) {
        let mut ring = self.spans.lock().unwrap();
        if ring.len() >= SPAN_RING_CAP {
            ring.pop_front();
        }
        ring.push_back(rec);
    }

    /// The most recent `max` finished spans, oldest first.
    pub fn recent_spans(&self, max: usize) -> Vec<SpanRecord> {
        let ring = self.spans.lock().unwrap();
        let skip = ring.len().saturating_sub(max);
        ring.iter().skip(skip).cloned().collect()
    }

    /// Point-in-time copy of every metric (spans not included — those
    /// travel separately so scrapes can skip them).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let histos = self
            .histos
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect();
        MetricsSnapshot { counters, gauges, histos }
    }
}

/// Point-in-time, mergeable copy of a [`Registry`]'s metrics. Metric
/// names may embed Prometheus-style labels (`scan_rows_total{bits="4"}`)
/// — the maps treat them as opaque keys; only [`MetricsSnapshot::prometheus`]
/// parses them back apart.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Monotone counters by name.
    pub counters: BTreeMap<String, u64>,
    /// Instantaneous gauges by name.
    pub gauges: BTreeMap<String, i64>,
    /// Fixed-bucket histograms by name.
    pub histos: BTreeMap<String, HistoSnapshot>,
}

impl MetricsSnapshot {
    /// Fleet merge: counters and histograms sum, gauges sum (a fleet's
    /// queue depth / resident bytes are additive across workers).
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            *self.gauges.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.histos {
            self.histos.entry(k.clone()).or_default().merge(v);
        }
    }

    /// Render in the Prometheus text exposition format. Every metric is
    /// prefixed `qless_`; a name's `{label="v"}` suffix (if any) becomes
    /// the sample's label set, and metrics sharing a base name share one
    /// `# TYPE` line (BTreeMap order keeps them adjacent).
    pub fn prometheus(&self) -> String {
        fn split(name: &str) -> (&str, &str) {
            match name.find('{') {
                Some(i) => (&name[..i], &name[i..]),
                None => (name, ""),
            }
        }
        let mut out = String::new();
        let mut last_base = String::new();
        for (name, v) in &self.counters {
            let (base, labels) = split(name);
            if base != last_base {
                let _ = writeln!(out, "# TYPE qless_{base} counter");
                last_base = base.to_string();
            }
            let _ = writeln!(out, "qless_{base}{labels} {v}");
        }
        last_base.clear();
        for (name, v) in &self.gauges {
            let (base, labels) = split(name);
            if base != last_base {
                let _ = writeln!(out, "# TYPE qless_{base} gauge");
                last_base = base.to_string();
            }
            let _ = writeln!(out, "qless_{base}{labels} {v}");
        }
        for (name, h) in &self.histos {
            let (base, _) = split(name);
            let _ = writeln!(out, "# TYPE qless_{base} histogram");
            let mut cum = 0u64;
            for (i, c) in h.counts.iter().enumerate() {
                cum += c;
                let le = LATENCY_BOUNDS_US
                    .get(i)
                    .map(|b| b.to_string())
                    .unwrap_or_else(|| "+Inf".to_string());
                let _ = writeln!(out, "qless_{base}_bucket{{le=\"{le}\"}} {cum}");
            }
            let _ = writeln!(out, "qless_{base}_sum {}", h.sum);
            let _ = writeln!(out, "qless_{base}_count {}", h.count);
        }
        out
    }
}

// ---------------------------------------------------------------------------
// global + thread-local override

static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();

thread_local! {
    static OVERRIDE: RefCell<Option<Arc<Registry>>> = const { RefCell::new(None) };
    /// (trace id, current span id) of the innermost live span, 0 = none.
    static CURRENT: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

/// The process-global registry (what the `metrics` wire verb scrapes).
pub fn global() -> Arc<Registry> {
    GLOBAL.get_or_init(|| Arc::new(Registry::new())).clone()
}

/// The registry in force on this thread: the [`with_registry`] override
/// if one is installed, else the global one.
pub fn reg() -> Arc<Registry> {
    OVERRIDE
        .with(|o| o.borrow().clone())
        .unwrap_or_else(global)
}

/// Run `f` with `r` installed as this thread's registry, restoring the
/// previous override afterwards (panic-safe). Instrumented seams only
/// touch the registry on the calling thread, so a test wrapping a scan
/// here observes exactly that scan's traffic.
pub fn with_registry<R>(r: Arc<Registry>, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<Arc<Registry>>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0.take();
            OVERRIDE.with(|o| *o.borrow_mut() = prev);
        }
    }
    let prev = OVERRIDE.with(|o| o.borrow_mut().replace(r));
    let _restore = Restore(prev);
    f()
}

/// Add `n` to `name` in the thread's registry ([`reg`]).
pub fn counter_add(name: &str, n: u64) {
    reg().counter_add(name, n);
}

/// Set gauge `name` in the thread's registry.
pub fn gauge_set(name: &str, v: i64) {
    reg().gauge_set(name, v);
}

/// Add `d` to gauge `name` in the thread's registry.
pub fn gauge_add(name: &str, d: i64) {
    reg().gauge_add(name, d);
}

/// Record a µs observation into histogram `name` in the thread's registry.
pub fn observe_us(name: &str, us: u64) {
    reg().observe_us(name, us);
}

// ---------------------------------------------------------------------------
// tracing

static TRACING: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Globally enable/disable span collection. Disabled (the default),
/// [`span`] is a single relaxed load returning an inert guard.
pub fn set_tracing(on: bool) {
    TRACING.store(on, Ordering::Relaxed);
}

/// Whether span collection is enabled.
pub fn tracing_enabled() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Fresh process-unique nonzero id (trace ids, span ids — wire and local).
pub fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// One finished span: what the ring stores and what reply `timing`
/// arrays carry over the wire. `start_us` is relative to the recording
/// registry's epoch (or, on the wire, to the handling server's request
/// start — the coordinator re-bases when stitching).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecord {
    /// Stage name, e.g. `server.score` or `scan.pass`.
    pub name: String,
    /// Trace this span belongs to (0 = standalone).
    pub trace: u64,
    /// This span's id (nonzero).
    pub id: u64,
    /// Parent span id (0 = root).
    pub parent: u64,
    /// Start, µs (registry-relative locally; handler-relative on wire).
    pub start_us: u64,
    /// Duration, µs.
    pub dur_us: u64,
}

/// RAII guard for one live span; records into the owning registry on
/// drop. Inert (all-zero, no allocation) when tracing is disabled.
pub struct SpanGuard {
    inner: Option<SpanInner>,
}

struct SpanInner {
    name: String,
    trace: u64,
    id: u64,
    parent: u64,
    start: Instant,
    start_us: u64,
    prev: (u64, u64),
    reg: Arc<Registry>,
}

impl SpanGuard {
    /// This span's id, or 0 when tracing was disabled at creation.
    pub fn id(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.id)
    }

    /// The trace id this span belongs to, or 0 when inert.
    pub fn trace(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.trace)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(i) = self.inner.take() {
            CURRENT.set(i.prev);
            i.reg.record_span(SpanRecord {
                name: i.name,
                trace: i.trace,
                id: i.id,
                parent: i.parent,
                start_us: i.start_us,
                dur_us: i.start.elapsed().as_micros() as u64,
            });
        }
    }
}

/// Open a span named `name` under the thread's current span (a fresh
/// trace if none is live). One branch and no work when tracing is off.
pub fn span(name: &str) -> SpanGuard {
    if !tracing_enabled() {
        return SpanGuard { inner: None };
    }
    let (trace, parent) = CURRENT.with(|c| c.get());
    let trace = if trace == 0 { next_id() } else { trace };
    open(name, trace, parent)
}

/// Open a span with an **explicit** trace id and parent span id — the
/// entry point for wire-propagated traces (`trace` request field).
/// Still inert when tracing is disabled.
pub fn span_in(name: &str, trace: u64, parent: u64) -> SpanGuard {
    if !tracing_enabled() {
        return SpanGuard { inner: None };
    }
    open(name, if trace == 0 { next_id() } else { trace }, parent)
}

fn open(name: &str, trace: u64, parent: u64) -> SpanGuard {
    let reg = reg();
    let id = next_id();
    let prev = CURRENT.with(|c| c.replace((trace, id)));
    SpanGuard {
        inner: Some(SpanInner {
            name: name.to_string(),
            trace,
            id,
            parent,
            start: Instant::now(),
            start_us: reg.now_us(),
            prev,
            reg,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests flipping the global TRACING flag serialize on this.
    static TRACE_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn counters_gauges_histos_roundtrip() {
        let r = Registry::new();
        r.counter_add("a_total", 2);
        r.counter_add("a_total", 3);
        r.gauge_set("depth", 7);
        r.gauge_add("depth", -2);
        r.observe_us("lat_us", 90);
        r.observe_us("lat_us", 9_000);
        let s = r.snapshot();
        assert_eq!(s.counters["a_total"], 5);
        assert_eq!(s.gauges["depth"], 5);
        let h = &s.histos["lat_us"];
        assert_eq!(h.count, 2);
        assert_eq!(h.sum, 9_090);
        assert_eq!(h.counts[0], 1, "90µs lands in the first bucket");
        assert_eq!(h.counts.iter().sum::<u64>(), 2);
    }

    #[test]
    fn snapshot_merge_sums() {
        let a = Registry::new();
        let b = Registry::new();
        a.counter_add("x", 1);
        b.counter_add("x", 2);
        b.counter_add("y", 4);
        a.gauge_set("g", 3);
        b.gauge_set("g", 5);
        a.observe_us("h", 50);
        b.observe_us("h", 500_000);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.counters["x"], 3);
        assert_eq!(m.counters["y"], 4);
        assert_eq!(m.gauges["g"], 8);
        assert_eq!(m.histos["h"].count, 2);
        assert_eq!(m.histos["h"].sum, 500_050);
    }

    #[test]
    fn histo_quantile_is_bucket_upper_bound() {
        let h = Histo::new();
        for _ in 0..99 {
            h.observe(90); // bucket ≤100
        }
        h.observe(700_000); // bucket ≤1_000_000
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 100);
        assert_eq!(s.quantile(1.0), 1_000_000);
        assert_eq!(HistoSnapshot::default().quantile(0.5), 0);
    }

    #[test]
    fn with_registry_isolates_thread() {
        let mine = Arc::new(Registry::new());
        with_registry(mine.clone(), || {
            counter_add("iso_total", 11);
        });
        assert_eq!(mine.snapshot().counters["iso_total"], 11);
        // after the closure the override is gone: traffic goes global
        counter_add("iso_total", 1);
        assert_eq!(mine.snapshot().counters["iso_total"], 11);
        // and a sibling thread with its own override never sees `mine`'s
        let other = Arc::new(Registry::new());
        let o2 = other.clone();
        std::thread::spawn(move || {
            with_registry(o2, || counter_add("iso_total", 7));
        })
        .join()
        .unwrap();
        assert_eq!(other.snapshot().counters["iso_total"], 7);
        assert_eq!(mine.snapshot().counters["iso_total"], 11);
    }

    #[test]
    fn spans_record_nesting_and_ring_is_bounded() {
        let _g = TRACE_LOCK.lock().unwrap();
        let r = Arc::new(Registry::new());
        set_tracing(true);
        with_registry(r.clone(), || {
            let outer = span("outer");
            let outer_id = outer.id();
            assert_ne!(outer_id, 0);
            {
                let inner = span("inner");
                assert_ne!(inner.id(), outer_id);
                assert_eq!(inner.trace(), outer.trace());
            }
            drop(outer);
            let spans = r.recent_spans(10);
            assert_eq!(spans.len(), 2);
            assert_eq!(spans[0].name, "inner");
            assert_eq!(spans[0].parent, outer_id, "inner parents to outer");
            assert_eq!(spans[1].name, "outer");
            assert_eq!(spans[1].parent, 0);
            assert!(spans[1].dur_us >= spans[0].dur_us);
            for _ in 0..SPAN_RING_CAP + 5 {
                span("fill");
            }
            assert_eq!(r.recent_spans(usize::MAX).len(), SPAN_RING_CAP);
        });
        set_tracing(false);
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _g = TRACE_LOCK.lock().unwrap();
        set_tracing(false);
        let r = Arc::new(Registry::new());
        with_registry(r.clone(), || {
            let s = span("nothing");
            assert_eq!(s.id(), 0);
            drop(s);
        });
        assert!(r.recent_spans(10).is_empty());
    }

    #[test]
    fn span_in_adopts_wire_identity() {
        let _g = TRACE_LOCK.lock().unwrap();
        let r = Arc::new(Registry::new());
        set_tracing(true);
        with_registry(r.clone(), || {
            let s = span_in("server.score", 0xabc, 0x12);
            assert_eq!(s.trace(), 0xabc);
            drop(s);
        });
        set_tracing(false);
        let spans = r.recent_spans(1);
        assert_eq!(spans[0].trace, 0xabc);
        assert_eq!(spans[0].parent, 0x12);
    }

    #[test]
    fn prometheus_text_shape() {
        let r = Registry::new();
        r.counter_add("scan_rows_total{bits=\"1\"}", 3);
        r.counter_add("scan_rows_total{bits=\"8\"}", 4);
        r.gauge_set("queue_depth{pool=\"scan\"}", 2);
        r.observe_us("score_us", 400);
        let text = r.snapshot().prometheus();
        assert_eq!(text.matches("# TYPE qless_scan_rows_total counter").count(), 1);
        assert!(text.contains("qless_scan_rows_total{bits=\"1\"} 3"));
        assert!(text.contains("qless_scan_rows_total{bits=\"8\"} 4"));
        assert!(text.contains("# TYPE qless_queue_depth gauge"));
        assert!(text.contains("qless_queue_depth{pool=\"scan\"} 2"));
        assert!(text.contains("# TYPE qless_score_us histogram"));
        assert!(text.contains("qless_score_us_bucket{le=\"500\"} 1"));
        assert!(text.contains("qless_score_us_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("qless_score_us_sum 400"));
        assert!(text.contains("qless_score_us_count 1"));
    }
}
