//! Foundation utilities every other module builds on.
//!
//! The offline vendor set has no `rand`, `serde`, `log`, `clap`, `criterion`
//! or `proptest`, so this module provides the substrates ourselves:
//! deterministic RNG with Python parity, a structured logger, a minimal JSON
//! reader/writer, aligned/markdown table rendering, timing statistics, f16
//! conversions, a small property-testing harness, and a std-only
//! metrics/tracing registry (`obs`).

pub mod bits;
pub mod cpu;
pub mod json;
pub mod logging;
pub mod obs;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use logging::{log_enabled, set_verbosity, Level};
pub use rng::Rng;
pub use stats::Timer;
