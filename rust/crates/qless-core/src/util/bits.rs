//! Low-level numeric helpers: bf16/f16 conversions and popcount utilities.
//!
//! bf16 is the storage format of the LESS 16-bit baseline datastore (the
//! paper stores fp16-class precision); the 1-bit influence fast path works
//! on packed sign words with XNOR+popcount (see `influence::native`).

/// f32 → bf16 (round-to-nearest-even), returned as the raw u16 pattern.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    // round to nearest even on the truncated 16 bits
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits + round) >> 16) as u16
}

/// bf16 (raw u16) → f32.
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// f32 → IEEE f16 raw bits (round-to-nearest-even, handles inf/nan/denorm).
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xFF) as i32;
    let mant = b & 0x007F_FFFF;
    if exp == 0xFF {
        // inf / nan
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow -> 0
        }
        // subnormal
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = m >> shift;
        let rem = m & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = half + ((rem > halfway) || (rem == halfway && (half & 1) == 1)) as u32;
        return sign | rounded as u16;
    }
    let half = mant >> 13;
    let rem = mant & 0x1FFF;
    let rounded =
        half + ((rem > 0x1000) || (rem == 0x1000 && (half & 1) == 1)) as u32;
    let (e, rounded) = if rounded == 0x400 { (e + 1, 0) } else { (e, rounded) };
    if e >= 0x1F {
        return sign | 0x7C00;
    }
    sign | ((e as u16) << 10) | rounded as u16
}

/// IEEE f16 raw bits → f32.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = 127 - 15 - 10;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (((e + 10) as u32) << 23) | ((m & 0x3FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Hamming-style agreement count between packed sign words: number of bit
/// positions where `a` and `b` agree (XNOR popcount).
#[inline(always)]
pub fn agree_bits(a: u64, b: u64) -> u32 {
    (!(a ^ b)).count_ones()
}

/// Add one packed sign row into per-bit-position counters — the
/// accumulation half of the k-majority centroid update (the IVF index's
/// Lloyd step). `counts[i]` gains 1 iff bit `i` of `row` is set
/// (little-endian bit order within bytes, matching `quant::pack`); only
/// the first `counts.len()` positions are read, so a row's zero padding
/// bits never need masking.
#[inline]
pub fn accumulate_bits(row: &[u8], counts: &mut [u32]) {
    debug_assert!(counts.len() <= row.len() * 8, "counters exceed the packed row");
    for (i, c) in counts.iter_mut().enumerate() {
        *c += u32::from((row[i / 8] >> (i % 8)) & 1);
    }
}

/// Collapse per-bit-position counters into a packed majority bitmap: bit
/// `i` of the result is set iff a **strict** majority of the `n_rows`
/// accumulated rows had it set (`2·counts[i] > n_rows` — ties resolve to
/// 0, deterministically). Padding bits past `counts.len()` stay 0, so the
/// result is a valid zero-padded packed sign row.
pub fn majority_bitmap(counts: &[u32], n_rows: u32) -> Vec<u8> {
    let mut out = vec![0u8; counts.len().div_ceil(8)];
    for (i, &c) in counts.iter().enumerate() {
        if 2 * c as u64 > n_rows as u64 {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_roundtrip_exact_for_representables() {
        for x in [0.0f32, 1.0, -1.0, 0.5, 2.0, -3.25, 1e10, -1e-10] {
            let back = bf16_to_f32(f32_to_bf16(x));
            let rel = if x == 0.0 { back.abs() } else { ((back - x) / x).abs() };
            assert!(rel < 0.01, "{x} -> {back}");
        }
    }

    #[test]
    fn bf16_error_bounded() {
        let mut r = crate::util::Rng::new(1);
        for _ in 0..1000 {
            let x = (r.normal() * 100.0) as f32;
            let back = bf16_to_f32(f32_to_bf16(x));
            if x != 0.0 {
                assert!(((back - x) / x).abs() < 1.0 / 128.0, "{x} {back}");
            }
        }
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16(0.0), 0);
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.0), 0xC000);
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0x7C00), f32::INFINITY);
        assert!(f16_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn f16_roundtrip_error_bounded() {
        let mut r = crate::util::Rng::new(2);
        for _ in 0..1000 {
            let x = (r.normal()) as f32;
            let back = f16_to_f32(f32_to_f16(x));
            if x.abs() > 1e-4 {
                assert!(((back - x) / x).abs() < 1.0 / 1024.0, "{x} {back}");
            }
        }
    }

    #[test]
    fn f16_overflow_to_inf_underflow_to_zero() {
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(1e-10)), 0.0);
    }

    #[test]
    fn agree_bits_basics() {
        assert_eq!(agree_bits(0, 0), 64);
        assert_eq!(agree_bits(u64::MAX, 0), 0);
        assert_eq!(agree_bits(0b1010, 0b1000), 63);
    }

    #[test]
    fn majority_vote_roundtrip() {
        // three rows over k=10: bit set in the majority iff ≥ 2 of 3 rows set it
        let rows: [&[u8]; 3] = [&[0b1100_1111, 0b10], &[0b0000_1111, 0b11], &[0b1100_0000, 0b00]];
        let mut counts = vec![0u32; 10];
        for r in rows {
            accumulate_bits(r, &mut counts);
        }
        assert_eq!(counts, vec![2, 2, 2, 2, 1, 1, 2, 2, 2, 1]);
        let maj = majority_bitmap(&counts, 3);
        assert_eq!(maj, vec![0b1100_1111, 0b01]);
        // padding bits (10..16) stay 0
        assert_eq!(maj[1] >> 2, 0);
    }

    #[test]
    fn majority_ties_resolve_to_zero() {
        let mut counts = vec![0u32; 4];
        accumulate_bits(&[0b0011], &mut counts);
        accumulate_bits(&[0b0101], &mut counts);
        // bits 0 (2/2) set, bits 1,2 (1/2 — tie) clear, bit 3 (0/2) clear
        assert_eq!(majority_bitmap(&counts, 2), vec![0b0001]);
    }

    #[test]
    fn accumulate_ignores_bits_past_counters() {
        let mut counts = vec![0u32; 3];
        accumulate_bits(&[0xFF], &mut counts); // bits 3..8 never read
        assert_eq!(counts, vec![1, 1, 1]);
    }
}
