//! Minimal JSON reader/writer (no `serde` in the offline vendor set).
//!
//! Reads the AOT `artifacts/manifest.json` and writes experiment reports.
//! Supports the full JSON grammar except `\u` surrogate pairs are decoded
//! but not re-encoded (reports are ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    // ---- accessors --------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).with_context(|| format!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // ---- encode -----------------------------------------------------------
    pub fn encode(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn encode_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    // ---- decode -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }
}

impl Default for Json {
    fn default() -> Json {
        Json::obj()
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().context("unexpected end of JSON")
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, got '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = &self.b[self.i - 1..self.i - 1 + len];
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.i += len - 1;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.req("c").unwrap().req("d").unwrap().as_f64().unwrap(), -2500.0);
        let re = Json::parse(&v.encode()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_manifest_like() {
        let src = r#"{"models":{"tiny":{"d_base":26688,"artifacts":{"x":{"file":"tiny/x.hlo.txt","inputs":[{"shape":[2,2],"dtype":"float32"}]}}}},"version":2}"#;
        let v = Json::parse(src).unwrap();
        let tiny = v.req("models").unwrap().req("tiny").unwrap();
        assert_eq!(tiny.req("d_base").unwrap().as_usize().unwrap(), 26688);
        let shape = tiny
            .req("artifacts").unwrap().req("x").unwrap().req("inputs").unwrap()
            .as_arr().unwrap()[0]
            .req("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let mut o = Json::obj();
        o.set("weird", "a\"b\\c\nd\te\u{1}");
        let re = Json::parse(&o.encode()).unwrap();
        assert_eq!(re.req("weird").unwrap().as_str().unwrap(), "a\"b\\c\nd\te\u{1}");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse(r#"{"s":"héllo ✓"}"#).unwrap();
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#"{"s":"Aé"}"#).unwrap();
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "Aé");
    }

    #[test]
    fn pretty_encode_parses_back() {
        let mut o = Json::obj();
        o.set("xs", Json::Arr(vec![1usize.into(), 2usize.into()]));
        o.set("name", "run");
        let pretty = o.encode_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), o);
    }

    #[test]
    fn int_encoding_is_integral() {
        assert_eq!(Json::Num(5.0).encode(), "5");
        assert_eq!(Json::Num(5.5).encode(), "5.5");
    }
}
