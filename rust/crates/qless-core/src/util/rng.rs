//! Deterministic RNG with exact Python parity (`python/compile/rng.py`).
//!
//! Element `i` (0-based) of the stream for `seed` is
//! `mix64(seed + (i+1)*GOLDEN)` — classic splitmix64 unrolled into a
//! counter-based form so it can be generated out of order, sliced, and
//! reproduced identically in numpy. The Rademacher projection matrix `R`
//! used for gradient features is derived from this stream and fed to the
//! AOT graphs as an input buffer, so Rust and Python always agree on it.

pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

#[inline(always)]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Element `i` of the splitmix64 stream for `seed` (0-based).
#[inline(always)]
pub fn stream(seed: u64, i: u64) -> u64 {
    mix64(seed.wrapping_add((i + 1).wrapping_mul(GOLDEN)))
}

/// Sequential convenience wrapper over [`stream`] plus the usual
/// distribution helpers. Statefulness is just a moving index, so any state
/// can be reproduced from `(seed, index)`.
#[derive(Debug, Clone)]
pub struct Rng {
    seed: u64,
    i: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { seed, i: 0 }
    }

    /// Derive an independent stream (for per-worker / per-purpose seeding).
    pub fn fork(&self, tag: u64) -> Rng {
        Rng::new(mix64(self.seed ^ mix64(tag.wrapping_add(GOLDEN))))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let v = stream(self.seed, self.i);
        self.i += 1;
        v
    }

    /// Uniform in `[0, 1)` from the top 53 bits (matches `rng.uniform01`).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (n > 0) via 128-bit multiply (unbiased
    /// enough for data generation; not used where exactness matters).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        // Fisher–Yates.
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// The QLESS projection matrix R ∈ {−1,+1}^{d×k} / √k, row-major flat.
/// Must bit-match `compile.rng.rademacher_projection`.
pub fn rademacher_projection(seed: u64, d: usize, k: usize) -> Vec<f32> {
    let scale = 1.0 / (k as f32).sqrt();
    let n = d * k;
    let mut out = vec![0f32; n];
    for (i, o) in out.iter_mut().enumerate() {
        let bit = stream(seed, i as u64) >> 63;
        *o = if bit == 1 { -scale } else { scale };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pinned vectors duplicated in python/tests/test_rng.py::PINNED.
    #[test]
    fn parity_vectors() {
        assert_eq!(stream(1234, 0), 0xBB0C_F61B_2F18_1CDB);
        assert_eq!(stream(1234, 1), 0x97C7_A136_4DF0_6524);
        assert_eq!(stream(1234, 7), 0x3A46_5F3F_8F9C_E09F);
    }

    #[test]
    fn stream_is_counter_based() {
        let mut r = Rng::new(7);
        let seq: Vec<u64> = (0..10).map(|_| r.next_u64()).collect();
        let direct: Vec<u64> = (0..10).map(|i| stream(7, i)).collect();
        assert_eq!(seq, direct);
    }

    #[test]
    fn projection_values_and_scale() {
        let r = rademacher_projection(99, 8, 4);
        let scale = 1.0 / 2.0; // 1/sqrt(4)
        assert_eq!(r.len(), 32);
        assert!(r.iter().all(|&v| v == scale || v == -scale));
    }

    #[test]
    fn projection_deterministic_seed_sensitive() {
        let a = rademacher_projection(5, 16, 8);
        let b = rademacher_projection(5, 16, 8);
        let c = rademacher_projection(6, 16, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn projection_sign_balance() {
        let r = rademacher_projection(1, 128, 128);
        let pos = r.iter().filter(|&&v| v > 0.0).count() as f64 / r.len() as f64;
        assert!(pos > 0.45 && pos < 0.55, "{pos}");
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(11);
        let us: Vec<f64> = (0..1000).map(|_| r.next_f64()).collect();
        assert!(us.iter().all(|&u| (0.0..1.0).contains(&u)));
        let mean = us.iter().sum::<f64>() / us.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "{mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let xs: Vec<f64> = (0..4000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.08, "{mean}");
        assert!((var - 1.0).abs() < 0.15, "{var}");
    }

    #[test]
    fn fork_decorrelates() {
        let r = Rng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
