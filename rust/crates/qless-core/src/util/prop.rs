//! Mini property-testing harness (the offline vendor set has no proptest),
//! plus the seeded feature-matrix fixture every suite builds on. (The
//! datastore-on-disk fixture lives one crate up, in
//! `qless_datastore::fixtures`, next to the writer it exercises.)
//!
//! `run_prop` drives a property over `cases` randomized inputs built from a
//! seeded [`Rng`]; on failure it retries with a bisected "shrink budget" by
//! re-running with smaller size hints and reports the seed so the failure
//! is reproducible with `PROP_SEED=<n> cargo test`.

use super::rng::Rng;
use crate::grads::FeatureMatrix;

/// Generator context passed to properties: a seeded RNG plus a size hint —
/// properties should scale their inputs by `size` so early (small) cases
/// localize failures cheaply.
pub struct G<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> G<'a> {
    pub fn usize_up_to(&mut self, max: usize) -> usize {
        self.rng.below(max.min(self.size.max(1)) + 1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi + 1)
    }

    pub fn f32_normal(&mut self, scale: f32) -> f32 {
        (self.rng.normal() as f32) * scale
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_normal(scale)).collect()
    }

    /// Occasionally emit adversarial values (0, ±tiny, ±huge).
    pub fn f32_edgy(&mut self) -> f32 {
        match self.rng.below(10) {
            0 => 0.0,
            1 => 1e-30,
            2 => -1e-30,
            3 => 1e30,
            4 => -1e30,
            _ => self.f32_normal(1.0),
        }
    }

    pub fn vec_f32_edgy(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.f32_edgy()).collect()
    }
}

/// Run `prop` over `cases` randomized cases. Panics with the seed + case
/// index on the first failure (after attempting smaller sizes first so the
/// reported failure tends to be small).
pub fn run_prop<F: FnMut(&mut G) -> Result<(), String>>(name: &str, cases: usize, mut prop: F) {
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDEC0DE);
    let mut failures: Vec<(usize, usize, String)> = Vec::new();
    // ramp sizes so early cases are small (cheap shrinking)
    for case in 0..cases {
        let size = 1 + case * 64 / cases.max(1);
        let mut rng = Rng::new(seed).fork(case as u64 + 1);
        let mut g = G { rng: &mut rng, size };
        if let Err(msg) = prop(&mut g) {
            failures.push((case, size, msg));
            break;
        }
    }
    if let Some((case, size, msg)) = failures.pop() {
        panic!(
            "property '{name}' failed at case {case} (size {size}, seed {seed}): {msg}\n\
             reproduce with PROP_SEED={seed}"
        );
    }
}

/// Test fixture: a seeded `n × k` standard-normal feature matrix — the
/// synthetic stand-in for extracted gradient features that unit,
/// integration and bench fixtures share.
pub fn normal_features(n: usize, k: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_prop("reverse-reverse", 50, |g| {
            let n = g.usize_up_to(50);
            let v = g.vec_f32(n, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert!(v == w, "reverse twice changed vec");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        run_prop("always-fails", 10, |_| Err("nope".to_string()));
    }

    #[test]
    fn sizes_ramp() {
        let mut max_seen = 0;
        run_prop("size-ramp", 20, |g| {
            max_seen = max_seen.max(g.size);
            Ok(())
        });
        assert!(max_seen > 10);
    }

    #[test]
    fn edgy_hits_zero() {
        let mut rng = Rng::new(1);
        let mut g = G { rng: &mut rng, size: 10 };
        let v = g.vec_f32_edgy(200);
        assert!(v.iter().any(|&x| x == 0.0));
        assert!(v.iter().any(|&x| x.abs() > 1e20));
    }
}
