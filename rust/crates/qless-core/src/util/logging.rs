//! Tiny leveled logger (the vendor set has no `log`/`env_logger`).
//!
//! Verbosity is a process-global set once from the CLI (`-v/-q`); the
//! macros stamp elapsed wall time since process start so pipeline stage
//! timings are readable straight from the console.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_verbosity(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

pub fn log_enabled(level: Level) -> bool {
    level as u8 <= VERBOSITY.load(Ordering::Relaxed)
}

pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: Level, args: std::fmt::Arguments) {
    if log_enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{:8.2}s {}] {}", elapsed(), tag, args);
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn verbosity_gates() {
        set_verbosity(Level::Warn);
        assert!(log_enabled(Level::Error));
        assert!(log_enabled(Level::Warn));
        assert!(!log_enabled(Level::Info));
        set_verbosity(Level::Info);
        assert!(log_enabled(Level::Info));
    }
}
