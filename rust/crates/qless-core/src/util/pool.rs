//! Persistent scan worker pool.
//!
//! The influence scan used to spawn a fresh `std::thread::scope` per
//! checkpoint block, capped at 16 threads, with static chunking — spawn
//! cost per call, idle cores above 16, and stragglers when rows vary in
//! cost. This pool fixes all three: worker threads are spawned once
//! (lazily, on the first parallel scan) and parked on a condvar between
//! jobs, the thread count follows `QLESS_SCORE_THREADS` or the machine's
//! full parallelism (no cap), and rows are claimed work-stealing-style
//! from a shared atomic cursor so fast workers absorb slow rows.
//!
//! Three entry points share one job engine: [`par_fill_f32`] fills
//! `out[i] = f(i)` (one float per index), [`par_fill_rows`] fills
//! `out[i*width .. (i+1)*width]` per index — the multi-query scan's shape,
//! where each datastore row produces one score per validation task — and
//! [`par_for`] runs a pure side-effect `f(i)` (the streaming builder's
//! quantize stage, packing rows into disjoint byte slots) with an optional
//! per-call concurrency cap. The caller participates in the job and blocks
//! until every claimed chunk is done, which is what makes the
//! borrowed-closure lifetime erasure below sound: `f` and `out` are only
//! ever touched between job publication and the caller's return.
//!
//! A second, independent primitive lives alongside the scan pool:
//! [`TaskPool`], a plain fixed-size worker pool over a bounded queue of
//! boxed `FnOnce` tasks. The scan pool is a data-parallel fork/join engine
//! (one job at a time, caller participates); `TaskPool` is a task-parallel
//! executor (many independent long-lived tasks, caller continues) — the
//! serving layer (`service::server`) runs one connection handler per task
//! on it, with the bounded queue providing accept-loop backpressure.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};

/// Worker threads a scan may use: `QLESS_SCORE_THREADS` if set, else the
/// machine's available parallelism. Always ≥ 1.
pub fn scan_threads() -> usize {
    std::env::var("QLESS_SCORE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1))
        .max(1)
}

/// One parallel-for job. Workers claim `grain`-sized index chunks from
/// `next` until the range is exhausted; `f` and `out` are lifetime-erased
/// raw pointers kept alive by the caller blocking in [`par_fill_rows`] /
/// [`par_for`].
struct Job {
    next: AtomicUsize,
    /// Logical index count (rows, not floats).
    n: usize,
    grain: usize,
    /// Floats written per index; `out` is `n × width` floats. Width 0 is
    /// the side-effect-only [`par_for`] shape: `out` is null and never
    /// dereferenced.
    width: usize,
    out: *mut f32,
    f: *const (dyn Fn(usize, &mut [f32]) + Sync),
    /// Participants (workers + caller) currently inside `run`.
    running: AtomicUsize,
    panicked: AtomicBool,
}

// SAFETY: the raw pointers are only dereferenced for chunk indices claimed
// from `next`, and the caller does not return (ending the pointees'
// lifetimes) until `next >= n` and `running == 0` — after which no
// participant can claim a chunk, so the pointers are never used again.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and compute chunks until the range is exhausted.
    fn run(&self) {
        loop {
            let start = self.next.fetch_add(self.grain, Ordering::Relaxed);
            if start >= self.n {
                return;
            }
            let end = (start + self.grain).min(self.n);
            let res = catch_unwind(AssertUnwindSafe(|| {
                // SAFETY: see the Send/Sync justification above; chunk
                // indices are disjoint across participants by fetch_add,
                // so the `width`-float output slices never alias. At
                // width 0 (`par_for`) the null `out` is never touched.
                let f = unsafe { &*self.f };
                for i in start..end {
                    if self.width == 0 {
                        f(i, &mut []);
                    } else {
                        let row = unsafe {
                            std::slice::from_raw_parts_mut(self.out.add(i * self.width), self.width)
                        };
                        f(i, row);
                    }
                }
            }));
            if res.is_err() {
                self.panicked.store(true, Ordering::Relaxed);
                // keep claiming so the cursor drains and everyone exits
            }
        }
    }
}

struct State {
    job: Option<Arc<Job>>,
    /// Bumped on every new job so parked workers adopt it exactly once.
    epoch: u64,
}

struct Shared {
    state: Mutex<State>,
    work: Condvar,
    done: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    /// One scan at a time; concurrent callers serialize here.
    scan_lock: Mutex<()>,
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, epoch: 0 }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        // The caller participates too, so spawn threads - 1 workers.
        let workers = scan_threads().saturating_sub(1);
        for _ in 0..workers {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("qless-scan".into())
                .spawn(move || worker_loop(shared))
                .expect("spawning scan worker");
        }
        Pool { shared, scan_lock: Mutex::new(()), workers }
    })
}

fn worker_loop(shared: Arc<Shared>) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.epoch != last_epoch {
                    if let Some(j) = st.job.clone() {
                        last_epoch = st.epoch;
                        j.running.fetch_add(1, Ordering::SeqCst);
                        break j;
                    }
                    last_epoch = st.epoch;
                }
                st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        job.run();
        let before = job.running.fetch_sub(1, Ordering::SeqCst);
        if before == 1 {
            // last participant out: wake the caller (lock orders the notify
            // after the caller's predicate check, avoiding a lost wakeup)
            let _st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            shared.done.notify_all();
        }
    }
}

/// Fill `out[i] = f(i)` for all `i` using the persistent pool. The calling
/// thread participates, so this also works with zero pool workers
/// (single-core machines) — it just runs serially.
pub fn par_fill_f32(out: &mut [f32], f: &(dyn Fn(usize) -> f32 + Sync)) {
    par_fill_rows(out, 1, &|i: usize, row: &mut [f32]| row[0] = f(i));
}

/// Fill `out[i*width .. (i+1)*width]` with `f(i, chunk)` for each logical
/// index `i` in `0 .. out.len()/width`, in parallel on the persistent
/// pool. `width` must divide `out.len()`. This is the multi-query scan
/// primitive: one datastore row in, `width` per-task scores out, with the
/// row's expensive decode shared across all of them.
pub fn par_fill_rows(out: &mut [f32], width: usize, f: &(dyn Fn(usize, &mut [f32]) + Sync)) {
    assert!(width >= 1, "par_fill_rows: width must be >= 1");
    assert_eq!(out.len() % width, 0, "par_fill_rows: out length not a multiple of width");
    let n = out.len() / width;
    run_job(n, 0, width, out.as_mut_ptr(), f);
}

/// Run `f(i)` for every `i in 0..n` on the persistent pool, for callers
/// whose output is a side effect (e.g. packing quantized rows into
/// disjoint byte slots) rather than an f32 array. `max_workers` caps
/// *concurrency* without touching the global pool size: the index range is
/// split into at most `max_workers` chunks, so at most that many
/// participants ever hold work (0 = no cap, default chunking). The calling
/// thread participates, so this runs serially on single-core machines.
pub fn par_for(n: usize, max_workers: usize, f: &(dyn Fn(usize) + Sync)) {
    let grain = if max_workers == 0 { 0 } else { n.div_ceil(max_workers).max(1) };
    run_job(n, grain, 0, std::ptr::null_mut(), &|i: usize, _row: &mut [f32]| f(i));
}

/// Fill `out` (row-major `n × width` floats) in parallel, handing each
/// participant a whole *block* of up to `rows_per_block` consecutive rows:
/// `f(start_row, block)` gets a mutable slice covering rows
/// `start_row .. start_row + block.len()/width`. This is the blocked scan
/// kernels' shape — a block of rows is unpacked once into an L1-resident
/// tile and dotted against every task column before eviction, so the
/// parallel grain must be the tile, not the row. The final block may be
/// short (`n % rows_per_block` rows).
pub fn par_fill_row_blocks(
    out: &mut [f32],
    width: usize,
    rows_per_block: usize,
    f: &(dyn Fn(usize, &mut [f32]) + Sync),
) {
    assert!(width >= 1, "par_fill_row_blocks: width must be >= 1");
    assert!(rows_per_block >= 1, "par_fill_row_blocks: rows_per_block must be >= 1");
    assert_eq!(out.len() % width, 0, "par_fill_row_blocks: out length not a multiple of width");
    let n = out.len() / width;
    if n == 0 {
        return;
    }
    let n_blocks = n.div_ceil(rows_per_block);
    // usize-erase the base pointer so the closure is Sync without capturing
    // a &mut; each block index maps to a disjoint row range.
    let base = out.as_mut_ptr() as usize;
    par_for(n_blocks, 0, &move |b| {
        let start = b * rows_per_block;
        let rows = rows_per_block.min(n - start);
        // SAFETY: block `b` covers rows `[start, start+rows)`; blocks are
        // disjoint by construction and `par_for` does not return until every
        // block is done, so `out` outlives all writes and no two
        // participants ever alias a float.
        let block = unsafe {
            std::slice::from_raw_parts_mut((base as *mut f32).add(start * width), rows * width)
        };
        f(start, block);
    });
}

/// Shared job engine behind [`par_fill_rows`] and [`par_for`]: publish one
/// job, participate, and block until every participant is done. `grain` 0
/// picks the default chunking (~8 chunks per participant).
fn run_job(
    n: usize,
    grain: usize,
    width: usize,
    out: *mut f32,
    f: &(dyn Fn(usize, &mut [f32]) + Sync),
) {
    if n == 0 {
        return;
    }
    let p = pool();
    let _scan = p.scan_lock.lock().unwrap_or_else(|e| e.into_inner());
    let parts = p.workers + 1;
    // ~8 chunks per participant: dynamic enough to absorb stragglers,
    // coarse enough that the atomic cursor never contends.
    let grain = if grain == 0 { n.div_ceil(parts * 8).max(1) } else { grain };
    // SAFETY (lifetime erasure): the Arc<Job> may outlive this call in a
    // late worker's hand, but `run` dereferences the pointers only for
    // chunks claimed while `next < n`, and we do not return until the
    // cursor is exhausted AND `running == 0`.
    let f_erased: *const (dyn Fn(usize, &mut [f32]) + Sync) = unsafe {
        std::mem::transmute::<
            &(dyn Fn(usize, &mut [f32]) + Sync),
            *const (dyn Fn(usize, &mut [f32]) + Sync),
        >(f)
    };
    let job = Arc::new(Job {
        next: AtomicUsize::new(0),
        n,
        grain,
        width,
        out,
        f: f_erased,
        running: AtomicUsize::new(1), // the caller
        panicked: AtomicBool::new(false),
    });
    {
        let mut st = p.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        st.job = Some(job.clone());
        st.epoch = st.epoch.wrapping_add(1);
    }
    p.shared.work.notify_all();
    job.run();
    job.running.fetch_sub(1, Ordering::SeqCst);
    {
        let mut st = p.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        while job.running.load(Ordering::SeqCst) > 0 {
            st = p.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
    }
    if job.panicked.load(Ordering::Relaxed) {
        panic!("scan closure panicked in worker pool");
    }
}

// ---------------------------------------------------------------------------
// task pool (independent tasks, bounded queue)
// ---------------------------------------------------------------------------

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool over a bounded task queue.
///
/// `execute` enqueues a boxed closure; when the queue is full it **blocks**
/// until a worker frees a slot — deliberate backpressure for producers like
/// an accept loop. Workers survive task panics (each task runs under
/// `catch_unwind`). Dropping the pool closes the queue, lets queued tasks
/// drain, and joins every worker — so tests and server shutdown are
/// deterministic.
pub struct TaskPool {
    tx: Option<mpsc::SyncSender<Task>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Tasks enqueued but not yet dequeued — a metrics gauge shared with
    /// the workers, labeled by pool name in the process registry.
    depth: Arc<std::sync::atomic::AtomicI64>,
    /// Times `execute` found the queue full and had to block.
    saturated: Arc<std::sync::atomic::AtomicU64>,
}

impl TaskPool {
    /// Spawn `workers` named threads (floored at 1) over a queue holding at
    /// most `queue_cap` pending tasks (floored at 1).
    pub fn new(name: &str, workers: usize, queue_cap: usize) -> TaskPool {
        let (tx, rx) = mpsc::sync_channel::<Task>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let reg = super::obs::reg();
        let depth = reg.gauge(&format!("taskpool_queue_depth{{pool=\"{name}\"}}"));
        let saturated = reg.counter(&format!("taskpool_saturation_total{{pool=\"{name}\"}}"));
        let handles = (0..workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let depth = Arc::clone(&depth);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        // hold the receiver lock only for the dequeue
                        let task = {
                            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
                            guard.recv()
                        };
                        match task {
                            Ok(t) => {
                                depth.fetch_sub(1, Ordering::Relaxed);
                                let _ = catch_unwind(AssertUnwindSafe(t));
                            }
                            Err(_) => return, // queue closed: pool dropped
                        }
                    })
                    .expect("spawning task-pool worker")
            })
            .collect();
        TaskPool { tx: Some(tx), handles, depth, saturated }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Enqueue a task; blocks while the queue is full. Returns an error
    /// only if the pool is already shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> anyhow::Result<()> {
        let tx = self.tx.as_ref().ok_or_else(|| anyhow::anyhow!("task pool closed"))?;
        let closed = || anyhow::anyhow!("task pool closed");
        // count the task as queued before handing it over so the gauge
        // never under-reports a full queue; undo on a closed pool
        self.depth.fetch_add(1, Ordering::Relaxed);
        match tx.try_send(Box::new(f)) {
            Ok(()) => Ok(()),
            Err(mpsc::TrySendError::Full(t)) => {
                self.saturated.fetch_add(1, Ordering::Relaxed);
                tx.send(t).map_err(|_| {
                    self.depth.fetch_sub(1, Ordering::Relaxed);
                    closed()
                })
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(closed())
            }
        }
    }
}

impl Drop for TaskPool {
    fn drop(&mut self) {
        // closing the sender ends every worker's recv loop after the queue
        // drains; join so no task outlives the pool
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_every_index() {
        for n in [0usize, 1, 7, 255, 4096] {
            let mut out = vec![0f32; n];
            par_fill_f32(&mut out, &|i| i as f32 * 2.0);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i as f32 * 2.0, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn fills_row_chunks() {
        for (n, w) in [(0usize, 3usize), (1, 1), (7, 2), (300, 3), (1024, 4)] {
            let mut out = vec![0f32; n * w];
            par_fill_rows(&mut out, w, &|i: usize, row: &mut [f32]| {
                assert_eq!(row.len(), w);
                for (j, x) in row.iter_mut().enumerate() {
                    *x = (i * 10 + j) as f32;
                }
            });
            for i in 0..n {
                for j in 0..w {
                    assert_eq!(out[i * w + j], (i * 10 + j) as f32, "n={n} w={w} i={i} j={j}");
                }
            }
        }
    }

    #[test]
    fn fills_row_blocks_including_short_tail() {
        for (n, w, tile) in
            [(0, 3, 4), (1, 1, 8), (7, 2, 3), (300, 3, 16), (1024, 4, 64), (5, 2, 100)]
        {
            let mut out = vec![0f32; n * w];
            par_fill_row_blocks(&mut out, w, tile, &|start: usize, block: &mut [f32]| {
                assert_eq!(block.len() % w, 0);
                let rows = block.len() / w;
                assert!(rows >= 1 && rows <= tile);
                assert_eq!(start % tile, 0, "blocks start on tile boundaries");
                for (r, row) in block.chunks_exact_mut(w).enumerate() {
                    for (j, x) in row.iter_mut().enumerate() {
                        *x = ((start + r) * 10 + j) as f32;
                    }
                }
            });
            for i in 0..n {
                for j in 0..w {
                    assert_eq!(out[i * w + j], (i * 10 + j) as f32, "n={n} w={w} tile={tile}");
                }
            }
        }
    }

    #[test]
    fn par_for_visits_every_index_once() {
        for n in [0usize, 1, 7, 255, 4096] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            par_for(n, 0, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn par_for_worker_cap_bounds_concurrency() {
        // With max_workers = 1 the whole range is one chunk, so exactly one
        // participant runs it: indices must arrive strictly in order.
        let order = std::sync::Mutex::new(Vec::new());
        par_for(100, 1, &|i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), (0..100).collect::<Vec<_>>());
        // A cap above n still works (chunks clamp to >= 1 index each).
        let count = AtomicUsize::new(0);
        par_for(3, 64, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let mut a = vec![0f32; 1000];
        let mut b = vec![0f32; 999];
        par_fill_f32(&mut a, &|i| i as f32);
        par_fill_f32(&mut b, &|i| -(i as f32));
        assert_eq!(a[999], 999.0);
        assert_eq!(b[998], -998.0);
    }

    #[test]
    fn concurrent_callers_serialize_safely() {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut out = vec![0f32; 2048];
                    par_fill_f32(&mut out, &move |i| (i + t) as f32);
                    out.iter().enumerate().all(|(i, &v)| v == (i + t) as f32)
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap());
        }
    }

    #[test]
    fn scan_threads_env_override() {
        // can't mutate the env safely under parallel tests; just check the
        // default is sane
        assert!(scan_threads() >= 1);
    }

    #[test]
    fn task_pool_runs_all_tasks_and_joins_on_drop() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = TaskPool::new("qless-test", 3, 4);
            assert_eq!(pool.workers(), 3);
            for _ in 0..32 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
                .unwrap();
            }
            // drop blocks until the queue drains and workers exit
        }
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn task_pool_survives_panicking_task() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = TaskPool::new("qless-test-panic", 1, 4);
            pool.execute(|| panic!("task panic must not kill the worker")).unwrap();
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn task_pool_floors_workers_and_capacity() {
        let pool = TaskPool::new("qless-test-floor", 0, 0);
        assert_eq!(pool.workers(), 1);
        let done = Arc::new(AtomicBool::new(false));
        let d = Arc::clone(&done);
        pool.execute(move || d.store(true, Ordering::SeqCst)).unwrap();
        drop(pool);
        assert!(done.load(Ordering::SeqCst));
    }
}
