//! `artifacts/manifest.json` parsing + validation.
//!
//! The manifest is the contract between the Python AOT path and this
//! runtime: model dimensions, static batch shapes, kernel tile sizes and
//! optimizer hyperparameters. Everything is validated eagerly so a stale
//! or mismatched artifacts directory fails at startup with a clear error.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub lora_rank: usize,
    pub lora_alpha: f64,
    pub d_base: usize,
    pub d_lora: usize,
    pub proj_dim: usize,
    pub batch_train: usize,
    pub batch_grad: usize,
    pub batch_eval: usize,
    pub tile_q: usize,
    pub tile_v: usize,
    pub quant_block: usize,
    pub adam_b1: f64,
    pub adam_b2: f64,
    pub adam_eps: f64,
    pub absmean_c: f64,
    /// artifact name → hlo file path (relative to the artifacts dir).
    pub artifacts: BTreeMap<String, String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab_table: Vec<String>,
    pub models: BTreeMap<String, ModelInfo>,
}

/// Artifact names every model entry must provide.
pub const REQUIRED_ARTIFACTS: [&str; 14] = [
    "pretrain_step",
    "train_step",
    "grad_train",
    "grad_val",
    "loss_eval",
    "decode_step",
    "quantize_absmax_8",
    "quantize_absmax_4",
    "quantize_absmax_2",
    "quantize_absmean_8",
    "quantize_absmean_4",
    "quantize_absmean_2",
    "quantize_sign_1",
    "influence",
];

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — did you run `make artifacts`?")
        })?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.req("version")?.as_usize()?;
        if version < 2 {
            bail!("manifest version {version} too old; re-run `make artifacts`");
        }
        let vocab_table: Vec<String> = j
            .req("vocab")?
            .as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect::<Result<_>>()?;

        let mut models = BTreeMap::new();
        for (name, entry) in j.req("models")?.as_obj()? {
            models.insert(name.clone(), ModelInfo::from_json(name, entry)?);
        }
        if models.is_empty() {
            bail!("manifest has no models");
        }
        Ok(Manifest { dir: dir.to_path_buf(), vocab_table, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).with_context(|| {
            format!(
                "model '{name}' not in manifest (available: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact_path(&self, model: &ModelInfo, artifact: &str) -> Result<PathBuf> {
        let rel = model
            .artifacts
            .get(artifact)
            .with_context(|| format!("artifact '{artifact}' missing for model {}", model.name))?;
        let p = self.dir.join(rel);
        if !p.exists() {
            bail!("artifact file {p:?} does not exist; re-run `make artifacts`");
        }
        Ok(p)
    }
}

impl ModelInfo {
    fn from_json(name: &str, j: &Json) -> Result<ModelInfo> {
        let us = |k: &str| -> Result<usize> { j.req(k)?.as_usize() };
        let fl = |k: &str| -> Result<f64> { j.req(k)?.as_f64() };
        let mut artifacts = BTreeMap::new();
        for (aname, a) in j.req("artifacts")?.as_obj()? {
            artifacts.insert(aname.clone(), a.req("file")?.as_str()?.to_string());
        }
        let info = ModelInfo {
            name: name.to_string(),
            vocab: us("vocab")?,
            seq: us("seq")?,
            d_model: us("d_model")?,
            n_layers: us("n_layers")?,
            n_heads: us("n_heads")?,
            d_ff: us("d_ff")?,
            lora_rank: us("lora_rank")?,
            lora_alpha: fl("lora_alpha")?,
            d_base: us("d_base")?,
            d_lora: us("d_lora")?,
            proj_dim: us("proj_dim")?,
            batch_train: us("batch_train")?,
            batch_grad: us("batch_grad")?,
            batch_eval: us("batch_eval")?,
            tile_q: us("tile_q")?,
            tile_v: us("tile_v")?,
            quant_block: us("quant_block")?,
            adam_b1: fl("adam_b1")?,
            adam_b2: fl("adam_b2")?,
            adam_eps: fl("adam_eps")?,
            absmean_c: fl("absmean_c")?,
            artifacts,
        };
        info.validate()?;
        Ok(info)
    }

    fn validate(&self) -> Result<()> {
        if self.vocab != 64 {
            bail!("model {}: vocab {} != 64", self.name, self.vocab);
        }
        if self.d_model % self.n_heads != 0 {
            bail!("model {}: d_model % n_heads != 0", self.name);
        }
        let expect_lora = self.n_layers * 4 * 2 * self.d_model * self.lora_rank;
        if self.d_lora != expect_lora {
            bail!("model {}: d_lora {} != expected {expect_lora}", self.name, self.d_lora);
        }
        for a in REQUIRED_ARTIFACTS {
            if !self.artifacts.contains_key(a) {
                bail!("model {}: missing artifact '{a}'", self.name);
            }
        }
        Ok(())
    }

    /// Name of the quantize artifact for a scheme/bits pair.
    pub fn quantize_artifact(&self, scheme: &str, bits: u8) -> String {
        if bits == 1 {
            "quantize_sign_1".to_string()
        } else {
            format!("quantize_{scheme}_{bits}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn loads_built_manifest_if_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("tiny"));
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.vocab, 64);
        for a in REQUIRED_ARTIFACTS {
            m.artifact_path(tiny, a).unwrap();
        }
        crate::corpus::Tokenizer::default()
            .check_manifest_vocab(&m.vocab_table)
            .unwrap();
    }

    #[test]
    fn missing_dir_is_informative() {
        let err = Manifest::load(Path::new("/nonexistent/xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn model_lookup_error_lists_available() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let err = m.model("enormous").unwrap_err();
        assert!(format!("{err:#}").contains("tiny"));
    }

    #[test]
    fn quantize_artifact_names() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let t = m.model("tiny").unwrap();
        assert_eq!(t.quantize_artifact("absmax", 8), "quantize_absmax_8");
        assert_eq!(t.quantize_artifact("absmean", 1), "quantize_sign_1");
    }
}
