//! Thread-shareable PJRT client wrapper + compiled-executable cache.
//!
//! The `xla` crate's wrappers hold `Rc<PjRtClientInternal>` clones that are
//! created/dropped on every execute and buffer operation, so genuinely
//! concurrent access from multiple threads would race the refcounts. We
//! therefore funnel **every** PJRT call (upload, execute, output readback,
//! buffer drop) through one process-wide [`pjrt_lock`]. This serializes the
//! host↔device boundary but NOT the compute: the TFRT CPU client
//! parallelizes each execution internally across all cores, so the worker
//! pool's job is to overlap host-side work (batch encode, quantize, pack,
//! datastore writes) with the single in-flight device call — the same
//! discipline as a one-GPU-stream runtime. DESIGN.md §8 records the
//! limitation.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{Context, Result};

use super::exec::Exec;
use super::manifest::{Manifest, ModelInfo};

static PJRT_LOCK: Mutex<()> = Mutex::new(());

/// Acquire the global PJRT lock. Every xla-crate call must happen while
/// holding this (poisoning is ignored: a panic inside PJRT is fatal anyway).
pub(crate) fn pjrt_lock() -> MutexGuard<'static, ()> {
    PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

pub(crate) struct SyncClient(pub xla::PjRtClient);
// SAFETY: all uses of the wrapped client go through pjrt_lock(), so the
// non-atomic Rc bookkeeping inside the crate is never raced.
unsafe impl Send for SyncClient {}
unsafe impl Sync for SyncClient {}

pub(crate) struct SyncExe(pub xla::PjRtLoadedExecutable);
// SAFETY: as above — execute calls are serialized by pjrt_lock().
unsafe impl Send for SyncExe {}
unsafe impl Sync for SyncExe {}

/// A device-resident buffer whose lifecycle (creation, use, drop) respects
/// the PJRT lock. Safe to move/share across worker threads.
pub struct DeviceBuf {
    inner: Option<xla::PjRtBuffer>,
}

// SAFETY: the raw buffer is only touched under pjrt_lock() (run_b holds the
// lock; Drop re-acquires it).
unsafe impl Send for DeviceBuf {}
unsafe impl Sync for DeviceBuf {}

impl DeviceBuf {
    pub(crate) fn new(buf: xla::PjRtBuffer) -> DeviceBuf {
        DeviceBuf { inner: Some(buf) }
    }

    /// Raw buffer reference — caller must hold the PJRT lock.
    pub(crate) fn raw(&self) -> &xla::PjRtBuffer {
        self.inner.as_ref().expect("DeviceBuf already dropped")
    }
}

impl Drop for DeviceBuf {
    fn drop(&mut self) {
        let _g = pjrt_lock();
        self.inner.take();
    }
}

/// Process-wide runtime: one PJRT CPU client, the artifact manifest, and a
/// cache of compiled executables keyed by `(model, artifact)`.
pub struct Runtime {
    pub(crate) client: Arc<SyncClient>,
    pub manifest: Manifest,
    cache: Mutex<HashMap<(String, String), Arc<Exec>>>,
}

impl Runtime {
    /// Create the CPU runtime and load + validate the manifest.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        crate::corpus::Tokenizer::default()
            .check_manifest_vocab(&manifest.vocab_table)
            .context("tokenizer / manifest vocab mismatch")?;
        let _g = pjrt_lock();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client: Arc::new(SyncClient(client)), manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn model(&self, name: &str) -> Result<ModelInfo> {
        Ok(self.manifest.model(name)?.clone())
    }

    /// Load (or fetch from cache) the compiled executable for an artifact.
    pub fn exec(&self, model: &ModelInfo, artifact: &str) -> Result<Arc<Exec>> {
        let key = (model.name.clone(), artifact.to_string());
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let path = self.manifest.artifact_path(model, artifact)?;
        let exec = Arc::new(Exec::load(self.client.clone(), &path, artifact)?);
        self.cache
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| exec.clone());
        Ok(exec)
    }

    /// Upload a host f32 slice as a persistent device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuf> {
        let _g = pjrt_lock();
        Ok(DeviceBuf::new(
            self.client
                .0
                .buffer_from_host_buffer(data, dims, None)
                .context("uploading f32 buffer")?,
        ))
    }

    /// Upload a host i32 slice as a persistent device buffer.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuf> {
        let _g = pjrt_lock();
        Ok(DeviceBuf::new(
            self.client
                .0
                .buffer_from_host_buffer(data, dims, None)
                .context("uploading i32 buffer")?,
        ))
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    pub fn cached_execs(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn runtime_loads_and_caches() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.platform(), "cpu");
        let tiny = rt.model("tiny").unwrap();
        let a = rt.exec(&tiny, "influence").unwrap();
        let b = rt.exec(&tiny, "influence").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(rt.cached_execs(), 1);
    }
}
