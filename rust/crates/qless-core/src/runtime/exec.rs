//! Typed execution of one compiled HLO artifact.
//!
//! All AOT graphs are lowered with `return_tuple=True`, so every execution
//! returns a tuple literal that is decomposed into per-output `Vec<f32>`.
//! Two call paths:
//!
//! * [`Exec::run`] — host-slice args ([`Arg`]); convenient, copies per call.
//! * [`Exec::run_b`] — all-device-buffer args; used with persistent buffers
//!   for checkpoint-lifetime operands (params, Adam state, projection
//!   matrix), which cuts per-batch host→device traffic by ~99% for the
//!   gradient-extraction graphs (see EXPERIMENTS.md §Perf).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::client::{pjrt_lock, DeviceBuf, SyncClient, SyncExe};

/// A host-side argument for [`Exec::run`].
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl<'a> Arg<'a> {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Arg::F32(data, dims) => shaped(xla::Literal::vec1(*data), data.len(), dims)?,
            Arg::I32(data, dims) => shaped(xla::Literal::vec1(*data), data.len(), dims)?,
            Arg::ScalarF32(v) => xla::Literal::scalar(*v),
            Arg::ScalarI32(v) => xla::Literal::scalar(*v),
        })
    }
}

fn shaped(lit: xla::Literal, len: usize, dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != len {
        bail!("arg has {len} elements but dims {dims:?} = {n}");
    }
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// One compiled artifact, executable from any thread.
pub struct Exec {
    client: Arc<SyncClient>,
    exe: SyncExe,
    pub name: String,
}

impl Exec {
    pub(crate) fn load(client: Arc<SyncClient>, path: &Path, name: &str) -> Result<Exec> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let _g = pjrt_lock();
        let exe = client.0.compile(&comp).with_context(|| format!("compiling {name}"))?;
        Ok(Exec { client, exe: SyncExe(exe), name: name.to_string() })
    }

    /// Execute with host args; returns each tuple element as `Vec<f32>`.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let _g = pjrt_lock();
        let out = self
            .exe
            .0
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        self.collect_f32(out) // output buffers drop inside the lock
    }

    /// Execute with device-buffer args (persistent-operand hot path).
    pub fn run_b(&self, args: &[&DeviceBuf]) -> Result<Vec<Vec<f32>>> {
        let _g = pjrt_lock();
        let raw: Vec<&xla::PjRtBuffer> = args.iter().map(|b| b.raw()).collect();
        let out = self
            .exe
            .0
            .execute_b::<&xla::PjRtBuffer>(&raw)
            .with_context(|| format!("executing(b) {}", self.name))?;
        self.collect_f32(out)
    }

    /// Like [`run`], but returns raw output literals (for i8/i32 outputs).
    pub fn run_literals(&self, args: &[Arg]) -> Result<Vec<xla::Literal>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let _g = pjrt_lock();
        let out = self
            .exe
            .0
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        Self::tuple_elems(out)
    }

    fn collect_f32(&self, out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Vec<f32>>> {
        let elems = Self::tuple_elems(out)?;
        elems
            .into_iter()
            .map(|lit| {
                // Convert non-f32 leaves (e.g. int8 codes) to f32 on the host.
                let ty = lit.ty()?;
                let lit = if ty == xla::ElementType::F32 {
                    lit
                } else {
                    lit.convert(xla::PrimitiveType::F32)?
                };
                Ok(lit.to_vec::<f32>()?)
            })
            .collect()
    }

    fn tuple_elems(out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        let buf = out
            .first()
            .and_then(|r| r.first())
            .context("execution returned no outputs")?;
        let lit = buf.to_literal_sync()?;
        // return_tuple=True → single tuple output; decompose into leaves.
        Ok(lit.to_tuple()?)
    }

    /// Upload a host f32 slice as a device buffer (persistent operand).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuf> {
        let _g = pjrt_lock();
        Ok(DeviceBuf::new(self.client.0.buffer_from_host_buffer(data, dims, None)?))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuf> {
        let _g = pjrt_lock();
        Ok(DeviceBuf::new(self.client.0.buffer_from_host_buffer(data, dims, None)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use std::path::PathBuf;

    fn rt() -> Option<Runtime> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Runtime::new(&p).unwrap())
    }

    #[test]
    fn influence_artifact_runs_and_matches_cosine() {
        let Some(rt) = rt() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let tiny = rt.model("tiny").unwrap();
        let exec = rt.exec(&tiny, "influence").unwrap();
        let (tq, tv, k) = (tiny.tile_q, tiny.tile_v, tiny.proj_dim);
        let mut rng = crate::util::Rng::new(1);
        let qt: Vec<f32> = (0..tq * k).map(|_| rng.normal() as f32).collect();
        let qv: Vec<f32> = (0..tv * k).map(|_| rng.normal() as f32).collect();
        let out = exec
            .run(&[Arg::F32(&qt, &[tq, k]), Arg::F32(&qv, &[tv, k])])
            .unwrap();
        assert_eq!(out.len(), 1);
        let sims = &out[0];
        assert_eq!(sims.len(), tq * tv);
        // check one entry against host cosine
        let dot: f32 = (0..k).map(|i| qt[i] * qv[i]).sum();
        let nt: f32 = (0..k).map(|i| qt[i] * qt[i]).sum::<f32>().sqrt();
        let nv: f32 = (0..k).map(|i| qv[i] * qv[i]).sum::<f32>().sqrt();
        let want = dot / (nt * nv);
        assert!((sims[0] - want).abs() < 1e-4, "{} vs {want}", sims[0]);
        assert!(sims.iter().all(|s| s.abs() <= 1.0 + 1e-4));
    }

    #[test]
    fn run_b_matches_run() {
        let Some(rt) = rt() else {
            return;
        };
        let tiny = rt.model("tiny").unwrap();
        let exec = rt.exec(&tiny, "influence").unwrap();
        let (tq, tv, k) = (tiny.tile_q, tiny.tile_v, tiny.proj_dim);
        let qt = vec![0.5f32; tq * k];
        let qv = vec![-0.25f32; tv * k];
        let a = exec.run(&[Arg::F32(&qt, &[tq, k]), Arg::F32(&qv, &[tv, k])]).unwrap();
        let bt = exec.upload_f32(&qt, &[tq, k]).unwrap();
        let bv = exec.upload_f32(&qv, &[tv, k]).unwrap();
        let b = exec.run_b(&[&bt, &bv]).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn bad_arg_shape_errors() {
        let Some(rt) = rt() else {
            return;
        };
        let tiny = rt.model("tiny").unwrap();
        let exec = rt.exec(&tiny, "influence").unwrap();
        let qt = vec![0f32; 10];
        assert!(exec.run(&[Arg::F32(&qt, &[3, 5])]).is_err());
    }
}
