//! PJRT runtime: loads the AOT HLO-text artifacts and executes them from
//! the Rust hot path. Python is never involved at this layer.
//!
//! * [`manifest`] — parses/validates `artifacts/manifest.json` (static dims,
//!   batch shapes, hyperparameters agreed with the Python build path).
//! * [`client`]   — thread-safe PJRT CPU client + executable cache.
//! * [`exec`]     — typed execute helpers: host slices in, `Vec<f32>` out,
//!   plus persistent device buffers for checkpoint-lifetime operands
//!   (params, optimizer state, projection matrix) so large inputs are
//!   uploaded once per checkpoint, not once per batch.

pub mod client;
pub mod exec;
pub mod manifest;

pub use client::{DeviceBuf, Runtime};
pub use exec::{Arg, Exec};
pub use manifest::{Manifest, ModelInfo};
