//! # qless-core — QLESS foundation layer
//!
//! The bottom crate of the QLESS workspace (see the workspace
//! `ARCHITECTURE.md` for the crate map). Everything here is free of
//! datastore / serving / pipeline concerns so the higher crates
//! (`qless-datastore`, `qless-service`, `qless`) can depend on it without
//! cycles:
//!
//! * [`quant`] — absmax / sign quantization schemes, bit-packing, batch
//!   quantizers and the weight-quantization path;
//! * [`select`] — deterministic top-k selection, the merge-friendly
//!   comparator the distributed scatter-gather coordinator relies on;
//! * [`grads`] — the [`grads::FeatureMatrix`] container shared by every
//!   layer (extraction itself lives in the top crate, next to the model);
//! * [`runtime`] — PJRT C-API runtime executing the AOT-lowered HLO
//!   artifacts;
//! * [`corpus`] — synthetic corpus generator + tokenizer (the runtime
//!   validates manifest vocabularies against it);
//! * [`util`] — the zero-dependency substrate: RNG, JSON, logging, thread
//!   pool, property-test harness, stats, tables.
#![warn(missing_docs)]
#![deny(unsafe_op_in_unsafe_fn)]

// Modules below carry `allow(missing_docs)` until their rustdoc pass lands
// (same debt markers as before the workspace split); `quant` and `select`
// are fully documented and the crate-level warn keeps them that way.
#[allow(missing_docs)]
pub mod corpus;
pub mod grads;
pub mod quant;
#[allow(missing_docs)]
pub mod runtime;
pub mod select;
#[allow(missing_docs)]
pub mod util;

/// Default scan memory budget in MiB, shared by the scoring engine, the
/// serving layer and the CLI `--mem-budget-mb` default so every layer
/// agrees on what "unconfigured" means.
pub const DEFAULT_MEM_BUDGET_MB: usize = 64;

pub use anyhow::{anyhow, bail, Context, Result};
