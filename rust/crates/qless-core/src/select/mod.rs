//! Deterministic ranking — the core of QLESS step 4 (top-p% selection).
//!
//! Only the ranking primitives live here: top-k with reproducible
//! tie-breaking and the scatter-gather merge built on the same comparator.
//! The corpus-aware analyses (subset composition for Fig. 5, budget sweeps
//! for Fig. 4) need the corpus model and live in the top `qless` crate's
//! `select` module, which re-exports everything below.

pub mod topk;

pub use topk::{
    merge_top_k, select_top_frac, sorted_union, top_k_indices, top_k_scored,
    top_k_scored_among, top_k_scored_since,
};
