//! Top-k selection with deterministic tie-breaking.
//!
//! Ranking must be reproducible across runs and scoring paths: ties are
//! broken by sample index (lower id wins), and NaN scores are rejected
//! loudly rather than silently sorted.

/// Indices of the `k` highest-scoring samples, ordered by descending score
/// (ties: ascending index). `k` is clamped to the score count, so an empty
/// slice yields an empty selection. Panics on NaN — a NaN influence score
/// means an upstream numerical bug, never a valid ranking input.
///
/// ```
/// use qless_core::select::top_k_indices;
///
/// let scores = [0.1, 0.9, -0.5, 0.9, 0.3];
/// // ties broken by ascending index: 1 beats 3 despite equal scores
/// assert_eq!(top_k_indices(&scores, 3), vec![1, 3, 4]);
/// // k larger than n clamps; empty input stays empty
/// assert_eq!(top_k_indices(&scores, 99).len(), 5);
/// assert!(top_k_indices(&[], 4).is_empty());
/// ```
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    assert!(
        scores.iter().all(|s| !s.is_nan()),
        "NaN influence score — upstream numerical bug"
    );
    let k = k.min(scores.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    // full sort keeps the output deterministic AND descending-ordered;
    // selection sizes here are small enough that O(n log n) is fine.
    idx.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// [`top_k_indices`] paired with each index's score — the serving layer's
/// per-task response shape, where every query carries its own `k`.
///
/// ```
/// use qless_core::select::top_k_scored;
///
/// let scores = [0.1, 0.9, -0.5];
/// assert_eq!(top_k_scored(&scores, 2), vec![(1, 0.9), (0, 0.1)]);
/// assert!(top_k_scored(&scores, 0).is_empty());
/// ```
pub fn top_k_scored(scores: &[f32], k: usize) -> Vec<(usize, f32)> {
    top_k_indices(scores, k).into_iter().map(|i| (i, scores[i])).collect()
}

/// [`top_k_scored`] restricted to sample indices `>= first_row` — the
/// incremental-selection shape: after an ingest, "the best k rows newer
/// than generation G" is a top-k over the tail that begins at G's first
/// newer row (the serving layer resolves `since_gen` to `first_row`
/// through its generation→row map). Tie-breaking stays by ascending
/// global index; `first_row` past the end yields an empty selection.
///
/// ```
/// use qless_core::select::top_k_scored_since;
///
/// let scores = [0.9, 0.1, 0.5, 0.8];
/// assert_eq!(top_k_scored_since(&scores, 2, 2), vec![(3, 0.8), (2, 0.5)]);
/// assert_eq!(top_k_scored_since(&scores, 2, 0), vec![(0, 0.9), (3, 0.8)]);
/// assert!(top_k_scored_since(&scores, 2, 4).is_empty());
/// ```
pub fn top_k_scored_since(scores: &[f32], k: usize, first_row: usize) -> Vec<(usize, f32)> {
    let first = first_row.min(scores.len());
    top_k_scored(&scores[first..], k).into_iter().map(|(i, s)| (i + first, s)).collect()
}

/// Merge per-range top-k candidate lists into the global top-k — the
/// scatter-gather reduction. Each part must hold [`top_k_scored`] (or
/// [`top_k_scored_since`]) results over a *disjoint* slice of the global
/// row space, with indices already offset to global positions; because
/// every part retains its own k best rows, no global top-k member can have
/// been dropped, and re-sorting the union with the exact [`top_k_indices`]
/// comparator (descending score, ascending index, NaN panics) reproduces
/// the single-node ranking bit-for-bit.
///
/// ```
/// use qless_core::select::{merge_top_k, top_k_scored};
///
/// let scores = [0.1f32, 0.9, -0.5, 0.8];
/// // two workers, rows [0,2) and [2,4), each reporting its local top-2
/// let left = top_k_scored(&scores[..2], 2);
/// let right: Vec<(usize, f32)> =
///     top_k_scored(&scores[2..], 2).into_iter().map(|(i, s)| (i + 2, s)).collect();
/// assert_eq!(merge_top_k(&[left, right], 2), top_k_scored(&scores, 2));
/// ```
pub fn merge_top_k(parts: &[Vec<(usize, f32)>], k: usize) -> Vec<(usize, f32)> {
    let mut all: Vec<(usize, f32)> = parts.iter().flatten().copied().collect();
    assert!(
        all.iter().all(|(_, s)| !s.is_nan()),
        "NaN influence score — upstream numerical bug"
    );
    all.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    all.truncate(k);
    all
}

/// Sorted, deduplicated union of per-task index lists — the candidate
/// coalescing step shared by the precision cascade and the IVF index scan:
/// each task keeps its own candidate set for ranking, but I/O runs once
/// over the union. Input lists need not be sorted.
///
/// ```
/// use qless_core::select::sorted_union;
///
/// let per_task: Vec<Vec<usize>> = vec![vec![4, 1, 7], vec![1, 9], vec![]];
/// assert_eq!(sorted_union(&per_task), vec![1, 4, 7, 9]);
/// assert!(sorted_union(&[]).is_empty());
/// ```
pub fn sorted_union(lists: &[Vec<usize>]) -> Vec<usize> {
    let mut union: Vec<usize> = lists.iter().flatten().copied().collect();
    union.sort_unstable();
    union.dedup();
    union
}

/// Top-k over an explicit **candidate set** of `(index, score)` pairs —
/// the precision cascade's final selection: stage 2 re-scores only the
/// probe stage's candidates, so the ranking input is a sparse subset of
/// the row space, not a dense score vector. The comparator is exactly
/// [`top_k_indices`]'s (descending score, ascending index, NaN panics),
/// which is what makes cascade(probe, rerank, c·k ≥ n) byte-identical to
/// the exhaustive rerank scan: same pairs in, same order out. Duplicate
/// indices are a caller bug; pairs need not arrive sorted.
///
/// ```
/// use qless_core::select::{top_k_scored, top_k_scored_among};
///
/// let scores = [0.1f32, 0.9, -0.5, 0.8];
/// // candidates = every row  ⇒  identical to the dense top-k
/// let all: Vec<(usize, f32)> = scores.iter().copied().enumerate().collect();
/// assert_eq!(top_k_scored_among(&all, 2), top_k_scored(&scores, 2));
/// // a strict subset ranks only within itself
/// assert_eq!(top_k_scored_among(&[(0, 0.1), (2, -0.5)], 1), vec![(0, 0.1)]);
/// assert!(top_k_scored_among(&[], 3).is_empty());
/// ```
pub fn top_k_scored_among(pairs: &[(usize, f32)], k: usize) -> Vec<(usize, f32)> {
    let mut all = pairs.to_vec();
    assert!(
        all.iter().all(|(_, s)| !s.is_nan()),
        "NaN influence score — upstream numerical bug"
    );
    all.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    all.truncate(k);
    all
}

/// Select ⌈frac·n⌉ samples (paper: top 5%; Fig. 4 sweeps 0.1%–10%),
/// flooring at one sample for any non-empty input (`frac = 0.0` still
/// selects the single best sample). Panics on `frac` outside `[0, 1]`.
pub fn select_top_frac(scores: &[f32], frac: f64) -> Vec<usize> {
    assert!((0.0..=1.0).contains(&frac), "frac {frac}");
    let k = ((scores.len() as f64) * frac).ceil() as usize;
    top_k_indices(scores, k.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    #[test]
    fn picks_highest() {
        let s = [0.1, 0.9, -0.5, 0.9, 0.3];
        assert_eq!(top_k_indices(&s, 3), vec![1, 3, 4]); // tie 1 vs 3 → lower id first
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        assert_eq!(top_k_indices(&[1.0, 2.0], 10), vec![1, 0]);
    }

    #[test]
    fn scored_pairs_match_indices() {
        let s = [0.3f32, 0.9, 0.9, -1.0];
        assert_eq!(top_k_scored(&s, 3), vec![(1, 0.9), (2, 0.9), (0, 0.3)]);
        assert_eq!(top_k_scored(&s, 99).len(), 4);
        assert!(top_k_scored(&[], 5).is_empty());
    }

    #[test]
    fn since_restricts_to_the_tail() {
        let s = [0.9f32, 0.1, 0.5, 0.8, 0.5];
        assert_eq!(top_k_scored_since(&s, 10, 0), top_k_scored(&s, 10));
        assert_eq!(top_k_scored_since(&s, 2, 3), vec![(3, 0.8), (4, 0.5)]);
        // ties in the tail still break by ascending global index
        assert_eq!(top_k_scored_since(&s, 2, 2), vec![(3, 0.8), (2, 0.5)]);
        assert!(top_k_scored_since(&s, 3, 5).is_empty());
        assert!(top_k_scored_since(&s, 3, 99).is_empty(), "past the end clamps");
        assert!(top_k_scored_since(&[], 3, 0).is_empty());
    }

    #[test]
    fn empty_scores_select_nothing() {
        // An empty datastore scan must not panic anywhere in selection.
        assert!(top_k_indices(&[], 0).is_empty());
        assert!(top_k_indices(&[], 5).is_empty());
        assert!(select_top_frac(&[], 0.0).is_empty());
        assert!(select_top_frac(&[], 0.05).is_empty());
        assert!(select_top_frac(&[], 1.0).is_empty());
    }

    #[test]
    fn frac_boundaries_exact() {
        let s: Vec<f32> = (0..10).map(|i| i as f32).collect();
        // frac = 0.0 floors at one sample (the best one)
        assert_eq!(select_top_frac(&s, 0.0), vec![9]);
        // frac = 1.0 selects everything, best first
        let all = select_top_frac(&s, 1.0);
        assert_eq!(all.len(), 10);
        assert_eq!(all[0], 9);
        assert_eq!(all[9], 0);
    }

    #[test]
    #[should_panic(expected = "frac")]
    fn frac_above_one_rejected() {
        select_top_frac(&[1.0], 1.5);
    }

    #[test]
    fn all_equal_scores_tie_break_deterministically() {
        // every score identical: selection must be the index prefix, at
        // every k, so reruns and scoring-path changes can't reshuffle it
        let s = vec![0.25f32; 8];
        for k in 0..=8 {
            let want: Vec<usize> = (0..k).collect();
            assert_eq!(top_k_indices(&s, k), want, "k={k}");
        }
        assert_eq!(select_top_frac(&s, 0.5), vec![0, 1, 2, 3]);
    }

    #[test]
    fn frac_rounds_up_and_floors_at_one() {
        let s = vec![0.0f32; 100];
        assert_eq!(select_top_frac(&s, 0.05).len(), 5);
        assert_eq!(select_top_frac(&s, 0.001).len(), 1); // ⌈0.1⌉
        assert_eq!(select_top_frac(&s, 0.0).len(), 1); // floor at 1
        assert_eq!(select_top_frac(&s, 1.0).len(), 100);
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn nan_rejected() {
        top_k_indices(&[0.0, f32::NAN], 1);
    }

    #[test]
    fn prop_selected_scores_dominate_rest() {
        run_prop("topk-dominates", 100, |g| {
            let n = 2 + g.usize_up_to(200);
            let scores = g.vec_f32(n, 1.0);
            let k = 1 + g.rng.below(n);
            let top = top_k_indices(&scores, k);
            prop_assert!(top.len() == k, "len");
            let min_top = top.iter().map(|&i| scores[i]).fold(f32::MAX, f32::min);
            for i in 0..n {
                if !top.contains(&i) {
                    prop_assert!(
                        scores[i] <= min_top,
                        "unselected {i} ({}) beats selected min {min_top}",
                        scores[i]
                    );
                }
            }
            // unique
            let mut u = top.clone();
            u.sort_unstable();
            u.dedup();
            prop_assert!(u.len() == k, "duplicates");
            Ok(())
        });
    }

    #[test]
    fn prop_monotone_in_frac() {
        // Fig. 4 invariant: a larger budget is a superset of a smaller one.
        run_prop("topk-monotone", 60, |g| {
            let n = 10 + g.usize_up_to(100);
            let scores = g.vec_f32(n, 1.0);
            let small = select_top_frac(&scores, 0.05);
            let large = select_top_frac(&scores, 0.20);
            for i in &small {
                prop_assert!(large.contains(i), "small selection not ⊆ large");
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_under_permuted_ties() {
        let s = vec![0.5f32; 10];
        assert_eq!(top_k_indices(&s, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn merge_of_single_part_is_identity() {
        let s = [0.3f32, 0.9, 0.9, -1.0];
        let top = top_k_scored(&s, 3);
        assert_eq!(merge_top_k(&[top.clone()], 3), top);
        assert!(merge_top_k(&[], 3).is_empty());
        assert!(merge_top_k(&[vec![]], 3).is_empty());
    }

    #[test]
    fn merge_breaks_cross_part_ties_by_global_index() {
        // equal scores landing on different workers must still rank by
        // ascending global index, exactly like the single-node sort
        let left = vec![(1usize, 0.5f32), (0, 0.1)];
        let right = vec![(2usize, 0.5f32), (3, 0.5)];
        assert_eq!(merge_top_k(&[right, left], 3), vec![(1, 0.5), (2, 0.5), (3, 0.5)]);
    }

    #[test]
    fn among_full_candidate_set_matches_dense_topk() {
        let s = [0.3f32, 0.9, 0.9, -1.0];
        let all: Vec<(usize, f32)> = s.iter().copied().enumerate().collect();
        for k in 0..=5 {
            assert_eq!(top_k_scored_among(&all, k), top_k_scored(&s, k), "k={k}");
        }
        // ties among candidates break by ascending index regardless of
        // the order the pairs arrive in
        assert_eq!(
            top_k_scored_among(&[(2, 0.9), (1, 0.9), (0, 0.3)], 2),
            vec![(1, 0.9), (2, 0.9)]
        );
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn among_rejects_nan() {
        top_k_scored_among(&[(0, f32::NAN)], 1);
    }

    #[test]
    fn prop_merge_equals_single_node_topk() {
        // the scatter-gather acceptance invariant, in miniature: any
        // contiguous partition of the row space, any k, any number of
        // parts — merging per-part top-k's IS the global top-k
        run_prop("merge-topk-exact", 100, |g| {
            let n = 1 + g.usize_up_to(200);
            let scores = g.vec_f32(n, 1.0);
            let k = g.rng.below(n + 2);
            // random contiguous partition into 1..=5 parts
            let parts_n = 1 + g.rng.below(5);
            let mut cuts: Vec<usize> = (0..parts_n - 1).map(|_| g.rng.below(n + 1)).collect();
            cuts.push(0);
            cuts.push(n);
            cuts.sort_unstable();
            let parts: Vec<Vec<(usize, f32)>> = cuts
                .windows(2)
                .map(|w| {
                    top_k_scored(&scores[w[0]..w[1]], k)
                        .into_iter()
                        .map(|(i, s)| (i + w[0], s))
                        .collect()
                })
                .collect();
            let merged = merge_top_k(&parts, k);
            let want = top_k_scored(&scores, k);
            prop_assert!(
                merged.len() == want.len()
                    && merged
                        .iter()
                        .zip(&want)
                        .all(|(a, b)| a.0 == b.0 && a.1.to_bits() == b.1.to_bits()),
                "merged {merged:?} != single-node {want:?} (n={n}, k={k}, cuts={cuts:?})"
            );
            Ok(())
        });
    }
}
