//! The gradient-feature container shared by every workspace layer.
//!
//! Extraction itself (sharded per-sample LoRA gradients through the PJRT
//! worker pool) lives in the top `qless` crate next to the model and data
//! plumbing; this module holds only the dense matrix type those features
//! travel in, so the datastore and serving crates can consume features
//! without depending on the extraction stack.

/// Dense `[n × k]` feature matrix for one checkpoint.
#[derive(Debug, Clone)]
pub struct FeatureMatrix {
    /// Number of rows (samples).
    pub n: usize,
    /// Projected feature dimension.
    pub k: usize,
    /// Row-major `n × k` values.
    pub data: Vec<f32>,
}

impl FeatureMatrix {
    /// Borrow row `i` as a `k`-length slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.k..(i + 1) * self.k]
    }
}
