//! # qless-service — QLESS serving layer
//!
//! The serving crate of the QLESS workspace (see the workspace
//! `ARCHITECTURE.md` for the crate map): everything that keeps a
//! datastore warm in a process and answers influence queries over TCP.
//! One module tree, [`service`], holds the resident [`service::Session`],
//! the micro-[`service::Batcher`], the JSON-lines wire protocol
//! (`PROTOCOL.md` in this crate is compiled into [`service::proto`]'s
//! rustdoc), the single-node [`service::Server`], and the distributed
//! scatter-gather [`service::Coordinator`].
//!
//! Below this crate sit `qless-datastore` (storage + fused scans) and
//! `qless-core` (quant, select, util); the CLI and pipeline live above it
//! in the top `qless` crate.
#![warn(missing_docs)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod service;

pub use qless_core::{corpus, grads, quant, runtime, select};
pub use qless_core::{debug, info, prop_assert, warn_, DEFAULT_MEM_BUDGET_MB};
pub use qless_datastore::{datastore, fixtures, influence, util};

pub use anyhow::{anyhow, bail, Context, Result};
