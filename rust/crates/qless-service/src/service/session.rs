//! The resident influence session: one **live** datastore opened (and
//! validated) once, per-checkpoint η weights read once, recently-scanned
//! shards pinned in a byte-budgeted LRU cache so repeat scans hit RAM
//! instead of disk, and a score cache keyed by task digest so identical
//! queries never rescan at all.
//!
//! [`Session::answer_batch`] is the serving hot path: poll the generation
//! manifest (an ingest bumps it — new segment members attach **in
//! place**), resolve score-cache hits, deduplicate identical queries
//! within the batch, then run **one** fused [`MultiScan`] pass over the
//! store for every distinct uncached task. Shards come from the cache
//! when pinned and from `ShardReader::seek_to_row` random-access reads
//! when not; either way the scoring kernels see the same
//! [`crate::datastore::RowsView`] bytes, so served scores are
//! bit-identical to the one-shot `--multi-scan` pipeline
//! (`influence::score_datastore_tasks` /
//! [`crate::influence::score_live_tasks`]), which the e2e suites assert.
//!
//! Generations invalidate **only affected ranges**: shard-cache keys
//! include the member (segment) index, so every shard pinned before an
//! ingest stays pinned and valid after it; a score-cache entry from
//! before an ingest is a *prefix* of the new answer, extended by a fused
//! **tail scan** over just the newly ingested rows rather than
//! recomputed. The session is owned by one scoring worker
//! ([`super::batcher`]), so an in-flight batch always finishes against
//! the generation it started on — reloads happen between batches.
//!
//! [`Session::answer_cascade`] is the serving face of the two-stage
//! precision cascade ([`crate::influence::cascade`]): sibling precision
//! stores of the run directory are resolved on demand and share the
//! pinned shard cache under store-scoped keys, so a warm cascade touches
//! no disk at either precision. Its worker-verb halves —
//! [`Session::answer_range_at`] (ranged probe) and
//! [`Session::answer_rerank_rows`] (sparse rerank) — are what the
//! scatter-gather coordinator drives on each worker.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::datastore::{
    default_store_path, run_dir_precisions, Header, LiveStore, OwnedShard, QuantIndex,
};
use crate::grads::FeatureMatrix;
use crate::influence::{cascade, index as ivf, MultiScan, ScanStats, ScoreOpts};
use crate::select::{top_k_scored, top_k_scored_among};
use crate::util::obs;
use crate::{info, warn_};

use super::cache::{task_digest, LruCache};

/// Knobs of a resident session (a subset of `ServeOpts`, usable without
/// the TCP front end — tests and the in-process path build these directly).
#[derive(Debug, Clone, Copy)]
pub struct SessionOpts {
    /// Fixed rows per shard; 0 = derive from `mem_budget_mb`.
    pub shard_rows: usize,
    /// Shard-cache byte budget in MiB; also bounds the scan's streaming
    /// shard size (the same contract as the batch pipeline's
    /// `--mem-budget-mb`, so peak residency is ≈ 2× this: one streaming
    /// buffer + the pinned cache).
    pub mem_budget_mb: usize,
    /// Score-cache capacity in entries (each entry is one per-sample
    /// score vector); 0 disables score caching.
    pub score_cache_entries: usize,
}

impl Default for SessionOpts {
    fn default() -> SessionOpts {
        SessionOpts {
            shard_rows: 0,
            mem_budget_mb: crate::DEFAULT_MEM_BUDGET_MB,
            score_cache_entries: 64,
        }
    }
}

/// Cumulative accounting of a session — the payload of the wire `stats`
/// op. Cache-efficacy counters are the interesting part: a warm repeat
/// query moves `score_cache_hits` (or `shard_cache_hits`) without moving
/// `disk_shard_reads`, and after an ingest a repeat query moves
/// `score_cache_extends` with a pass that only reads the new rows.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Score queries answered (including cache hits).
    pub queries: u64,
    /// `answer_batch` calls (micro-batches admitted).
    pub batches: u64,
    /// Fused datastore passes executed (0-miss batches skip it; a batch
    /// mixing cold misses and post-ingest extensions runs two).
    pub fused_passes: u64,
    /// Queries answered from the score cache without any scan.
    pub score_cache_hits: u64,
    /// Score-cache prefix hits extended by a tail scan over newly
    /// ingested rows only (never a full rescan).
    pub score_cache_extends: u64,
    /// Shards served from the RAM cache during scans.
    pub shard_cache_hits: u64,
    /// Shards read from the datastore files (cold misses).
    pub disk_shard_reads: u64,
    /// Bytes currently pinned by the shard cache.
    pub shard_cache_bytes: u64,
    /// Rows scored across all fused passes.
    pub rows_scored: u64,
    /// Generation bumps picked up live (ingests served without restart).
    pub reloads: u64,
    /// Queries answered through the IVF index sidecar path (including
    /// fallbacks — see `index_fallbacks`).
    pub index_queries: u64,
    /// Indexed queries served by an exhaustive scan because no usable
    /// sidecar was loaded (missing, rejected on open, or dropped after a
    /// failed refresh).
    pub index_fallbacks: u64,
    /// Rows assigned to clusters in memory since the sidecar was built —
    /// the index staleness gauge; `qless reindex` resets it to 0.
    pub index_stale_rows: u64,
    /// Clusters of the loaded sidecar (0 = no index loaded) — what the
    /// coordinator partitions the cluster list against.
    pub index_clusters: u64,
}

/// One influence query: raw (unquantized) validation gradient features per
/// warmup checkpoint, in checkpoint order — exactly the per-task shape
/// [`crate::influence::score_datastore_tasks`] takes.
#[derive(Debug, Clone)]
pub struct ScoreQuery {
    /// One feature matrix per checkpoint (`val[ci]` is `n_val × k`).
    pub val: Vec<FeatureMatrix>,
}

impl ScoreQuery {
    /// The score-cache key for this query's features (see
    /// [`task_digest`]).
    pub fn digest(&self) -> u64 {
        task_digest(&self.val)
    }

    /// Cheap admission-time validation against the served store's
    /// geometry: checkpoint count, feature dimension, non-empty matrices,
    /// flat-data length, finiteness. Runs before the query is enqueued so
    /// one malformed query gets its own error response instead of failing
    /// a whole batch. Geometry here is ingest-invariant (ingest only adds
    /// rows), so validation never races a reload.
    pub fn validate(&self, header: &Header) -> Result<()> {
        let c = header.n_checkpoints as usize;
        anyhow::ensure!(
            self.val.len() == c,
            "query has {} checkpoint feature sets, datastore has {c}",
            self.val.len()
        );
        for (ci, m) in self.val.iter().enumerate() {
            anyhow::ensure!(
                m.k == header.k as usize,
                "checkpoint {ci}: feature dim {} != datastore k {}",
                m.k,
                header.k
            );
            anyhow::ensure!(m.n > 0, "checkpoint {ci}: empty validation features");
            // checked: n and k come off the wire, and an n·k that wraps in
            // release builds could pass an unchecked equality against a
            // tiny data length and then drive an n-sized allocation
            let expect = m.n.checked_mul(m.k);
            anyhow::ensure!(
                expect == Some(m.data.len()),
                "checkpoint {ci}: {} values for {}×{} features",
                m.data.len(),
                m.n,
                m.k
            );
            if let Some(j) = m.data.iter().position(|x| !x.is_finite()) {
                bail!("checkpoint {ci}: non-finite validation feature {} at index {j}", m.data[j]);
            }
        }
        Ok(())
    }
}

/// One answered query: the full per-sample score vector (shared, so cache
/// hits are pointer clones) plus provenance — the generation it was
/// computed against, whether it came from the score cache and, if not,
/// the fused pass that produced it.
#[derive(Debug, Clone)]
pub struct Answer {
    /// Influence score of every training sample, in sample order, over
    /// the full live row space of [`Answer::generation`].
    pub scores: Arc<Vec<f32>>,
    /// Manifest generation of the store state that produced this answer.
    pub generation: u64,
    /// `(generation, first global row)` of every store member at answer
    /// time — the map a `since_gen` filter resolves rows against.
    pub gen_rows: Arc<Vec<(u64, usize)>>,
    /// True when served from the score cache without any scan.
    pub cached: bool,
    /// Distinct tasks fused into the producing pass (0 on a cache hit).
    pub batched: usize,
    /// I/O accounting of the producing pass (zeroed on a cache hit). All
    /// answers of one micro-batch's pass share it, which is how the e2e
    /// test asserts a burst of Q queries cost one datastore traversal —
    /// and how a post-ingest extension proves it only read the new rows.
    pub pass: ScanStats,
    /// Cascade-only payload: the final `(global row, rerank score)` pairs
    /// — ranked top-k from [`Session::answer_cascade`], candidate pairs in
    /// request row order from [`Session::answer_rerank_rows`]. `None` on
    /// every exhaustive-scan path, whose ranking happens downstream over
    /// [`Answer::scores`] (a cascade never materializes a full vector, so
    /// for it `scores` is empty and this field is the answer).
    pub top: Option<Vec<(usize, f32)>>,
}

impl Answer {
    /// First scored row strictly newer than `generation`, resolved
    /// against the member map of the exact store state that produced this
    /// answer (race-free across concurrent ingests); `scores.len()` when
    /// nothing is newer. The wire `since_gen` filter — "rank only rows
    /// newer than generation G" — is `top_k_scored_since` from here.
    pub fn first_row_after(&self, generation: u64) -> usize {
        self.gen_rows
            .iter()
            .filter(|(g, _)| *g > generation)
            .map(|(_, row)| *row)
            .min()
            .unwrap_or(self.scores.len())
    }
}

/// A sibling-precision store of the served run, opened lazily for
/// cascade stages and kept warm (its shards share the session's pinned
/// cache under store-scoped keys).
struct AuxStore {
    /// Storage bitwidth this store was resolved for.
    bits: u8,
    live: LiveStore,
    rows_per_shard: usize,
}

/// The two-stage plan of a served cascade query (the session-level shape
/// of the wire `cascade` object's client form).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CascadePlan {
    /// Probe-stage storage bitwidth (cheap full scan).
    pub probe: u8,
    /// Rerank-stage storage bitwidth (candidate re-scoring).
    pub rerank: u8,
    /// Candidate multiplier `c`: the probe keeps `c·top_k` rows per task.
    pub mult: usize,
}

/// A warm, long-lived handle over one live datastore (see the module
/// docs).
pub struct Session {
    live: LiveStore,
    etas: Vec<f32>,
    rows_per_shard: usize,
    opts: SessionOpts,
    /// Directory the served store lives in — where cascade stages resolve
    /// sibling precisions (`None` for a bare relative path with no parent).
    run_dir: Option<PathBuf>,
    /// Lazily opened sibling-precision stores, in resolution order;
    /// store index `i + 1` in shard-cache keys (the base store is 0).
    aux: Vec<AuxStore>,
    /// Pinned shards keyed by (store, member index, checkpoint, shard
    /// index) — member-scoped, so an ingest invalidates nothing below the
    /// old row count; store-scoped, so cascade stages at other precisions
    /// never alias base-store shards.
    shard_cache: LruCache<(usize, usize, usize, usize), Arc<OwnedShard>>,
    /// Full score vectors keyed by task digest; an entry's *length* is
    /// the row count it covers (always a generation boundary).
    score_cache: LruCache<u64, Arc<Vec<f32>>>,
    gen_rows: Arc<Vec<(u64, usize)>>,
    /// The IVF index sidecar of the served store, if a valid one sits
    /// next to it (`<stem>.qidx`) — refreshed on every generation bump
    /// (new rows assigned to nearest centroids in memory), dropped (never
    /// served) if a refresh fails. `None` ⇒ indexed queries fall back to
    /// exhaustive scans.
    index: Option<QuantIndex>,
    stats: ServiceStats,
}

impl Session {
    /// Open and validate the datastore at `path` — plus every ingested
    /// segment its directory's manifest lists — read every checkpoint's η
    /// once, and size the caches from `opts`. After this, a fully-warm
    /// query touches no file I/O at all.
    pub fn open(path: &Path, opts: SessionOpts) -> Result<Session> {
        let live = LiveStore::open(path)
            .with_context(|| format!("opening served datastore {path:?}"))?;
        let etas = live.etas().to_vec();
        let rows_per_shard = live.rows_per_shard(opts.shard_rows, opts.mem_budget_mb.max(1));
        let cache_budget = opts.mem_budget_mb.max(1) << 20;
        let gen_rows = Arc::new(member_map(&live));
        let index = QuantIndex::open_for(path, &live);
        if let Some(idx) = &index {
            info!(
                "session: index sidecar loaded ({} clusters over {} rows, {} stale)",
                idx.n_clusters(),
                idx.n_rows(),
                idx.stale_rows()
            );
        }
        info!(
            "session: {} rows × k={} × {} checkpoints at {} (generation {}, {} member \
             file(s), {rows_per_shard} rows/shard, {} MiB shard cache, {} score-cache entries)",
            live.n_rows(),
            live.header().k,
            etas.len(),
            live.header().precision.label(),
            live.generation(),
            live.members().len(),
            opts.mem_budget_mb.max(1),
            opts.score_cache_entries,
        );
        Ok(Session {
            live,
            etas,
            rows_per_shard,
            opts,
            run_dir: path
                .parent()
                .filter(|d| !d.as_os_str().is_empty())
                .map(Path::to_path_buf),
            aux: Vec::new(),
            shard_cache: LruCache::new(cache_budget),
            score_cache: LruCache::new(opts.score_cache_entries),
            gen_rows,
            index,
            stats: ServiceStats::default(),
        })
    }

    /// The served store's header (geometry + precision). `n_samples` is
    /// the **base** store's row count; [`Session::n_rows`] is the live
    /// total.
    pub fn header(&self) -> &Header {
        self.live.header()
    }

    /// The manifest generation currently served (0 = frozen base store).
    /// Bumped in place when [`Session::answer_batch`] detects an ingest;
    /// responses echo it so clients can track the row space they scored
    /// against.
    pub fn generation(&self) -> u64 {
        self.live.generation()
    }

    /// Total rows currently served (base + every attached segment).
    pub fn n_rows(&self) -> usize {
        self.live.n_rows()
    }

    /// `(generation, first global row)` per store member, for resolving
    /// generation filters (shared snapshot; rebuilt on reload).
    pub fn gen_rows(&self) -> Arc<Vec<(u64, usize)>> {
        Arc::clone(&self.gen_rows)
    }

    /// Rows per streamed/cached shard, resolved from the session's opts.
    pub fn rows_per_shard(&self) -> usize {
        self.rows_per_shard
    }

    /// Cumulative session accounting (the `stats` op's payload).
    pub fn stats(&self) -> ServiceStats {
        let mut s = self.stats;
        s.shard_cache_bytes = self.shard_cache.weight() as u64;
        if let Some(idx) = &self.index {
            s.index_clusters = idx.n_clusters() as u64;
            s.index_stale_rows = idx.stale_rows();
        }
        s
    }

    /// Whether a usable index sidecar is loaded (indexed queries without
    /// one fall back to exhaustive scans; cluster-window worker verbs
    /// error instead).
    pub fn has_index(&self) -> bool {
        self.index.is_some()
    }

    /// Poll the generation manifest and attach any newly ingested
    /// segments in place. Errors are downgraded to a warning — the
    /// session keeps serving the generation it has (a torn ingest must
    /// not take queries down with it).
    fn poll_generation(&mut self) {
        match self.live.refresh() {
            Ok(true) => {
                self.stats.reloads += 1;
                self.gen_rows = Arc::new(member_map(&self.live));
                info!(
                    "session: picked up generation {} ({} rows, {} members) without restart",
                    self.live.generation(),
                    self.live.n_rows(),
                    self.live.members().len()
                );
                // assign the ingested rows to their nearest centroids so
                // indexed queries keep covering the whole live row space;
                // a failed refresh drops the index (never served stale)
                let mut drop_index = false;
                if let Some(idx) = self.index.as_mut() {
                    match idx.refresh(&self.live) {
                        Ok(()) => {
                            obs::gauge_set("index_stale_rows", idx.stale_rows() as i64);
                        }
                        Err(e) => {
                            warn_!(
                                "session: index refresh failed ({e:#}); serving exhaustive \
                                 scans until `qless reindex`"
                            );
                            obs::counter_add("index_open_failures_total", 1);
                            drop_index = true;
                        }
                    }
                }
                if drop_index {
                    self.index = None;
                }
            }
            Ok(false) => {}
            Err(e) => warn_!(
                "session: manifest refresh failed ({e:#}); still serving generation {}",
                self.live.generation()
            ),
        }
        // per-session freshness gauges: what the fleet's generation-lag
        // metric is computed against (coordinator subtracts the max)
        obs::gauge_set("session_generation", self.live.generation() as i64);
        obs::gauge_set("session_rows", self.live.n_rows() as i64);
    }

    /// Answer one micro-batch of (already validated) queries: score-cache
    /// hits are answered instantly, identical queries within the batch are
    /// deduplicated, and every remaining distinct task rides **one** fused
    /// pass over the store — a full pass for cold tasks, and a tail pass
    /// over only the newly ingested rows for tasks whose pre-ingest
    /// answer is still cached. Returns one [`Answer`] per query, in
    /// order. A bumped generation is picked up here, before the batch
    /// scans, so in-flight passes always finish against one generation.
    pub fn answer_batch(&mut self, queries: &[ScoreQuery]) -> Result<Vec<Answer>> {
        let _sp = obs::span("session.answer_batch");
        self.poll_generation();
        self.stats.batches += 1;
        self.stats.queries += queries.len() as u64;
        let n = self.live.n_rows();
        let generation = self.live.generation();
        let digests: Vec<u64> = queries.iter().map(|q| q.digest()).collect();
        let mut answers: Vec<Option<Answer>> = vec![None; queries.len()];
        // distinct uncached digests, in arrival order (batch sizes are
        // small — max_batch_tasks — so linear dedup beats a map here);
        // `partials` carries the cached pre-ingest prefix to extend
        let mut misses: Vec<u64> = Vec::new();
        let mut partials: Vec<(u64, Arc<Vec<f32>>)> = Vec::new();
        for (i, d) in digests.iter().enumerate() {
            if let Some(scores) = self.score_cache.get(d) {
                if scores.len() == n {
                    self.stats.score_cache_hits += 1;
                    obs::counter_add("score_cache_hits_total", 1);
                    answers[i] = Some(Answer {
                        scores,
                        generation,
                        gen_rows: Arc::clone(&self.gen_rows),
                        cached: true,
                        batched: 0,
                        pass: ScanStats::default(),
                        top: None,
                    });
                    continue;
                }
                // a shorter vector is a pre-ingest prefix: extend it with
                // a tail scan if it ends exactly at a generation boundary
                if self.live.is_generation_boundary(scores.len()) {
                    if !partials.iter().any(|(pd, _)| pd == d) {
                        partials.push((*d, scores));
                    }
                    continue;
                }
            }
            if !misses.contains(d) {
                misses.push(*d);
            }
        }
        let rep = |d: &u64| -> usize {
            digests.iter().position(|x| x == d).expect("digest from this batch")
        };
        if !misses.is_empty() {
            obs::counter_add("score_cache_misses_total", misses.len() as u64);
            let tasks: Vec<&[FeatureMatrix]> =
                misses.iter().map(|d| queries[rep(d)].val.as_slice()).collect();
            let (totals, pass) = self.scan_fused(&tasks, 0)?;
            let shared: Vec<Arc<Vec<f32>>> = totals.into_iter().map(Arc::new).collect();
            for (d, scores) in misses.iter().zip(&shared) {
                let evicted = self.score_cache.insert(*d, Arc::clone(scores), 1);
                obs::counter_add("score_cache_evicted_total", evicted as u64);
            }
            for (i, d) in digests.iter().enumerate() {
                if answers[i].is_none() {
                    if let Some(t) = misses.iter().position(|x| x == d) {
                        answers[i] = Some(Answer {
                            scores: Arc::clone(&shared[t]),
                            generation,
                            gen_rows: Arc::clone(&self.gen_rows),
                            cached: false,
                            batched: misses.len(),
                            pass,
                            top: None,
                        });
                    }
                }
            }
        }
        if !partials.is_empty() {
            let tail_start =
                partials.iter().map(|(_, s)| s.len()).min().expect("partials non-empty");
            let tasks: Vec<&[FeatureMatrix]> =
                partials.iter().map(|(d, _)| queries[rep(d)].val.as_slice()).collect();
            let (tails, pass) = self.scan_fused(&tasks, tail_start)?;
            let batched = partials.len();
            for ((d, prefix), tail) in partials.iter().zip(&tails) {
                let mut full = Vec::with_capacity(n);
                full.extend_from_slice(prefix);
                full.extend_from_slice(&tail[prefix.len() - tail_start..]);
                let shared = Arc::new(full);
                let evicted = self.score_cache.insert(*d, Arc::clone(&shared), 1);
                obs::counter_add("score_cache_evicted_total", evicted as u64);
                self.stats.score_cache_extends += 1;
                obs::counter_add("score_cache_extends_total", 1);
                for (i, di) in digests.iter().enumerate() {
                    if answers[i].is_none() && di == d {
                        answers[i] = Some(Answer {
                            scores: Arc::clone(&shared),
                            generation,
                            gen_rows: Arc::clone(&self.gen_rows),
                            cached: false,
                            batched,
                            pass,
                            top: None,
                        });
                    }
                }
            }
        }
        Ok(answers.into_iter().map(|a| a.expect("every query answered")).collect())
    }

    /// Answer one micro-batch of (already validated) queries over the
    /// global row range `start .. start + len` **only** — the worker half
    /// of scatter-gather serving ([`super::coordinator`]). Identical
    /// queries within the batch are deduplicated into one fused ranged
    /// pass; shards overlapping the range are served from the same pinned
    /// shard cache as full scans (whole shards are cached, so a worker
    /// re-assigned a neighbouring range after a peer failure reuses
    /// everything it already has), and each fed shard is clipped to the
    /// range intersection with a zero-copy
    /// [`crate::datastore::RowsView::slice`], so the pass reads and scores
    /// exactly `len` rows per checkpoint.
    ///
    /// Returned answers are range-local: `scores[j]` is global row
    /// `start + j`, and `scores.len() == len`. The full-vector score
    /// cache is bypassed (`cached` is always false) — merged-answer
    /// caching is the coordinator's job, at its own layer.
    pub fn answer_range(
        &mut self,
        queries: &[ScoreQuery],
        start: usize,
        len: usize,
    ) -> Result<Vec<Answer>> {
        let bits = self.live.header().precision.bits;
        self.answer_range_at(queries, start, len, bits)
    }

    /// [`Session::answer_range`] generalized over the serving precision:
    /// the ranged scan runs against the run's `bits`-bit store (resolved
    /// like a cascade stage — the base store, or a sibling opened on
    /// demand). This is the cascade **probe** worker verb: the
    /// coordinator's wave-1 sub-queries probe each worker's row range at
    /// the cheap precision before the merged candidate pool is reranked.
    pub fn answer_range_at(
        &mut self,
        queries: &[ScoreQuery],
        start: usize,
        len: usize,
        bits: u8,
    ) -> Result<Vec<Answer>> {
        let _sp = obs::span("session.answer_range");
        self.poll_generation();
        self.stats.batches += 1;
        self.stats.queries += queries.len() as u64;
        let store = self.resolve_store(bits)?;
        self.refresh_store(store);
        let n = self.store_n_rows(store);
        ensure!(len > 0, "empty row range");
        let end = start
            .checked_add(len)
            .filter(|e| *e <= n)
            .with_context(|| format!("row range {start}+{len} exceeds live rows {n}"))?;
        debug_assert!(end <= n);
        let generation = self.live.generation();
        let (digests, distinct, tasks) = dedup_tasks(queries);
        let (totals, pass) = self.scan_store_range(store, &tasks, start, len)?;
        let shared: Vec<Arc<Vec<f32>>> = totals.into_iter().map(Arc::new).collect();
        let batched = distinct.len();
        Ok(digests
            .iter()
            .map(|d| {
                let t = distinct.iter().position(|x| x == d).expect("distinct covers digests");
                Answer {
                    scores: Arc::clone(&shared[t]),
                    generation,
                    gen_rows: Arc::clone(&self.gen_rows),
                    cached: false,
                    batched,
                    pass,
                    top: None,
                }
            })
            .collect())
    }

    /// Answer one micro-batch of (already validated) queries with the
    /// two-stage precision cascade: one fused probe pass over **all**
    /// live rows at `plan.probe` bits, per-task top `plan.mult × top_k`
    /// candidate selection, then one fused rerank pass over the deduped
    /// candidate union at `plan.rerank` bits — both passes served from
    /// the same pinned shard cache as exhaustive scans (store-scoped
    /// keys). Each query's final `top_k` is ranked over its **own**
    /// candidates only (`top_k_scored_among`), so an answer is
    /// bit-identical to [`crate::influence::cascade_live_tasks`] no
    /// matter which other queries share the batch — the union only
    /// coalesces I/O.
    ///
    /// Cascade answers bypass the full-vector score cache (`cached` is
    /// always false, `scores` is empty): the ranked pairs live in
    /// [`Answer::top`].
    pub fn answer_cascade(
        &mut self,
        queries: &[ScoreQuery],
        plan: CascadePlan,
        top_k: usize,
    ) -> Result<Vec<Answer>> {
        let _sp = obs::span("session.answer_cascade");
        self.poll_generation();
        self.stats.batches += 1;
        self.stats.queries += queries.len() as u64;
        ensure!(top_k >= 1, "cascade needs top_k >= 1 final selections per task");
        ensure!(plan.mult >= 1, "cascade candidate multiplier must be >= 1");
        ensure!(
            plan.probe != plan.rerank,
            "cascade probe and rerank precisions must differ (got {}-bit twice)",
            plan.probe
        );
        let probe = self.resolve_store(plan.probe)?;
        let rerank = self.resolve_store(plan.rerank)?;
        self.refresh_store(probe);
        self.refresh_store(rerank);
        let n = self.store_n_rows(probe);
        ensure!(
            self.store_n_rows(rerank) == n,
            "cascade stores disagree on live rows ({}-bit has {}, {}-bit has {}): \
             torn ingest in the run directory — retry after it completes",
            plan.probe,
            n,
            plan.rerank,
            self.store_n_rows(rerank)
        );
        ensure!(n > 0, "cascade over an empty store");
        let generation = self.live.generation();
        let (digests, distinct, tasks) = dedup_tasks(queries);
        let ck = top_k.saturating_mul(plan.mult).min(n);
        let (probe_totals, probe_pass) = self.scan_store_range(probe, &tasks, 0, n)?;
        let (cands, union) = cascade::probe_candidates(&probe_totals, ck);
        let (rr_scores, rerank_pass) = self.scan_store_rows(rerank, &tasks, &union)?;
        // the cascade's whole value claim is this split — make it scrapeable
        obs::counter_add("cascade_probe_rows_total", probe_pass.rows_read);
        obs::counter_add("cascade_rerank_rows_total", rerank_pass.rows_read);
        let pass = cascade::combine_stats(probe_pass, rerank_pass);
        let tops: Vec<Vec<(usize, f32)>> = cands
            .iter()
            .zip(&rr_scores)
            .map(|(rows, scored)| {
                let pairs: Vec<(usize, f32)> = rows
                    .iter()
                    .map(|&r| {
                        let j = union.binary_search(&r).expect("candidate in union");
                        (r, scored[j])
                    })
                    .collect();
                top_k_scored_among(&pairs, top_k)
            })
            .collect();
        let batched = distinct.len();
        let empty = Arc::new(Vec::new());
        Ok(digests
            .iter()
            .map(|d| {
                let t = distinct.iter().position(|x| x == d).expect("distinct covers digests");
                Answer {
                    scores: Arc::clone(&empty),
                    generation,
                    gen_rows: Arc::clone(&self.gen_rows),
                    cached: false,
                    batched,
                    pass,
                    top: Some(tops[t].clone()),
                }
            })
            .collect())
    }

    /// Re-score exactly `rows` (global indices, strictly increasing) at
    /// the run's `bits`-bit store — the cascade **rerank** worker verb.
    /// Each answer's [`Answer::top`] holds one `(row, score)` pair per
    /// requested row, in request order (no ranking — the coordinator
    /// ranks after merging); `scores` is empty.
    pub fn answer_rerank_rows(
        &mut self,
        queries: &[ScoreQuery],
        rows: &[usize],
        bits: u8,
    ) -> Result<Vec<Answer>> {
        let _sp = obs::span("session.answer_rerank");
        self.poll_generation();
        self.stats.batches += 1;
        self.stats.queries += queries.len() as u64;
        let store = self.resolve_store(bits)?;
        self.refresh_store(store);
        let n = self.store_n_rows(store);
        ensure!(!rows.is_empty(), "rerank needs at least one row");
        ensure!(
            rows.windows(2).all(|w| w[0] < w[1]),
            "rerank rows must be strictly increasing"
        );
        let last = *rows.last().expect("non-empty");
        ensure!(last < n, "rerank row {last} exceeds live rows {n}");
        let generation = self.live.generation();
        let (digests, distinct, tasks) = dedup_tasks(queries);
        let (scored, pass) = self.scan_store_rows(store, &tasks, rows)?;
        let batched = distinct.len();
        let empty = Arc::new(Vec::new());
        Ok(digests
            .iter()
            .map(|d| {
                let t = distinct.iter().position(|x| x == d).expect("distinct covers digests");
                Answer {
                    scores: Arc::clone(&empty),
                    generation,
                    gen_rows: Arc::clone(&self.gen_rows),
                    cached: false,
                    batched,
                    pass,
                    top: Some(rows.iter().copied().zip(scored[t].iter().copied()).collect()),
                }
            })
            .collect())
    }

    /// Answer one micro-batch of (already validated) queries through the
    /// IVF index sidecar ([`crate::influence::index`]): rank every cluster
    /// per task with the centroid probe, scan only the top-`nprobe`
    /// clusters' rows, and return each task's top-`top_k` `(row, score)`
    /// pairs in [`Answer::top`] (`scores` stays empty — indexed answers
    /// never materialize a full vector). `clusters = Some((start, len))`
    /// restricts the scan to that window of cluster-list *positions*: the
    /// coordinator partitions the deterministic cluster ranking, not the
    /// row space, and merges worker windows with `merge_top_k`.
    ///
    /// Without a usable sidecar the plain verb **falls back** to an
    /// exhaustive scan (counted in `index_fallbacks`; the top list is then
    /// exact by construction), while the windowed worker verb errors —
    /// a window only means something against the index's cluster ranking.
    pub fn answer_index(
        &mut self,
        queries: &[ScoreQuery],
        nprobe: usize,
        top_k: usize,
        clusters: Option<(usize, usize)>,
    ) -> Result<Vec<Answer>> {
        let _sp = obs::span("session.answer_index");
        ensure!(top_k >= 1, "indexed scoring needs top_k >= 1");
        ensure!(nprobe >= 1, "indexed scoring needs nprobe >= 1");
        self.poll_generation();
        if self.index.is_none() {
            ensure!(
                clusters.is_none(),
                "cluster-window scoring needs an index sidecar on the server — \
                 run `qless reindex` (or drop the 'clusters' field)"
            );
            self.stats.index_queries += queries.len() as u64;
            self.stats.index_fallbacks += queries.len() as u64;
            obs::counter_add("index_fallbacks_total", queries.len() as u64);
            warn_!(
                "session: indexed query without a usable sidecar — serving an \
                 exhaustive scan (run `qless reindex` to build one)"
            );
            let answers = self.answer_batch(queries)?;
            let empty = Arc::new(Vec::new());
            return Ok(answers
                .into_iter()
                .map(|mut a| {
                    a.top = Some(top_k_scored(&a.scores, top_k));
                    a.scores = Arc::clone(&empty);
                    a
                })
                .collect());
        }
        self.stats.batches += 1;
        self.stats.queries += queries.len() as u64;
        self.stats.index_queries += queries.len() as u64;
        obs::counter_add("index_queries_total", queries.len() as u64);
        let generation = self.live.generation();
        let (digests, distinct, tasks) = dedup_tasks(queries);
        let opts = ivf::IndexOpts {
            k: top_k,
            nprobe,
            scan: ScoreOpts {
                use_xla: false,
                shard_rows: self.opts.shard_rows,
                mem_budget_mb: self.opts.mem_budget_mb,
            },
        };
        let idx = self.index.as_ref().expect("checked above");
        let out = match clusters {
            Some((at, len)) => {
                ensure!(len >= 1, "empty cluster window");
                ivf::index_scan_live_tasks_at(&self.live, idx, &tasks, &opts, (at, len))?
            }
            None => ivf::index_scan_live_tasks(&self.live, idx, &tasks, &opts)?,
        };
        obs::counter_add("index_probe_rows_total", out.probe_pass.rows_read);
        obs::counter_add("index_scan_rows_total", out.scan_pass.rows_read);
        self.stats.fused_passes += 2; // centroid probe + cluster scan
        self.stats.rows_scored += out.scan_pass.rows_read;
        let pass = out.combined_pass();
        let batched = distinct.len();
        let empty = Arc::new(Vec::new());
        Ok(digests
            .iter()
            .map(|d| {
                let t = distinct.iter().position(|x| x == d).expect("distinct covers digests");
                Answer {
                    scores: Arc::clone(&empty),
                    generation,
                    gen_rows: Arc::clone(&self.gen_rows),
                    cached: false,
                    batched,
                    pass,
                    top: Some(out.top[t].clone()),
                }
            })
            .collect())
    }

    /// [`Session::answer_cascade`] with the probe stage restricted to the
    /// index sidecar's `nprobe` closest clusters per task
    /// ([`crate::influence::index_cascade_live_tasks`]); the exact
    /// high-precision rerank is unchanged. At `nprobe >=` the cluster
    /// count this degenerates to the plain cascade exactly. Without a
    /// usable sidecar it **falls back** to the plain cascade — an exact
    /// superset of the restricted probe — counted in `index_fallbacks`.
    pub fn answer_index_cascade(
        &mut self,
        queries: &[ScoreQuery],
        plan: CascadePlan,
        top_k: usize,
        nprobe: usize,
    ) -> Result<Vec<Answer>> {
        let _sp = obs::span("session.answer_index_cascade");
        ensure!(top_k >= 1, "cascade needs top_k >= 1 final selections per task");
        ensure!(nprobe >= 1, "indexed scoring needs nprobe >= 1");
        ensure!(plan.mult >= 1, "cascade candidate multiplier must be >= 1");
        ensure!(
            plan.probe != plan.rerank,
            "cascade probe and rerank precisions must differ (got {}-bit twice)",
            plan.probe
        );
        self.poll_generation();
        if self.index.is_none() {
            self.stats.index_queries += queries.len() as u64;
            self.stats.index_fallbacks += queries.len() as u64;
            obs::counter_add("index_fallbacks_total", queries.len() as u64);
            warn_!(
                "session: indexed cascade without a usable sidecar — probing every \
                 live row (run `qless reindex` to build one)"
            );
            return self.answer_cascade(queries, plan, top_k);
        }
        self.stats.batches += 1;
        self.stats.queries += queries.len() as u64;
        self.stats.index_queries += queries.len() as u64;
        obs::counter_add("index_queries_total", queries.len() as u64);
        let probe = self.resolve_store(plan.probe)?;
        let rerank = self.resolve_store(plan.rerank)?;
        self.refresh_store(probe);
        self.refresh_store(rerank);
        let generation = self.live.generation();
        let (digests, distinct, tasks) = dedup_tasks(queries);
        let opts = cascade::CascadeOpts {
            k: top_k,
            mult: plan.mult,
            scan: ScoreOpts {
                use_xla: false,
                shard_rows: self.opts.shard_rows,
                mem_budget_mb: self.opts.mem_budget_mb,
            },
        };
        let idx = self.index.as_ref().expect("checked above");
        let probe_live = match probe {
            0 => &self.live,
            s => &self.aux[s - 1].live,
        };
        let rerank_live = match rerank {
            0 => &self.live,
            s => &self.aux[s - 1].live,
        };
        let out =
            ivf::index_cascade_live_tasks(probe_live, rerank_live, idx, &tasks, &opts, nprobe)?;
        obs::counter_add("index_probe_rows_total", out.probe_pass.rows_read);
        obs::counter_add("index_rerank_rows_total", out.rerank_pass.rows_read);
        self.stats.fused_passes += 2; // restricted probe + exact rerank
        self.stats.rows_scored += out.rerank_pass.rows_read;
        let pass = out.combined_pass();
        let batched = distinct.len();
        let empty = Arc::new(Vec::new());
        Ok(digests
            .iter()
            .map(|d| {
                let t = distinct.iter().position(|x| x == d).expect("distinct covers digests");
                Answer {
                    scores: Arc::clone(&empty),
                    generation,
                    gen_rows: Arc::clone(&self.gen_rows),
                    cached: false,
                    batched,
                    pass,
                    top: Some(out.top[t].clone()),
                }
            })
            .collect())
    }

    /// One fused multi-task pass over the live rows `from_row ..
    /// n_rows()` (`from_row` must be a generation boundary; 0 = the whole
    /// store). The range degenerates to whole shards here, so this is the
    /// clip-free fast path the full-store and tail-extension scans ride.
    fn scan_fused(
        &mut self,
        tasks: &[&[FeatureMatrix]],
        from_row: usize,
    ) -> Result<(Vec<Vec<f32>>, ScanStats)> {
        debug_assert!(self.live.is_generation_boundary(from_row));
        let n = self.live.n_rows();
        self.scan_store_range(0, tasks, from_row, n - from_row)
    }

    /// The served store a cascade stage's bitwidth names: 0 is the base
    /// store; sibling precisions are resolved against the run directory's
    /// default-named stores, opened once, geometry/η-validated against
    /// the base, and kept warm for later queries. A bitwidth the run
    /// directory does not hold is a clean error naming what it does —
    /// never a silent fallback to the base precision.
    fn resolve_store(&mut self, bits: u8) -> Result<usize> {
        if self.live.header().precision.bits == bits {
            return Ok(0);
        }
        if let Some(i) = self.aux.iter().position(|a| a.bits == bits) {
            return Ok(i + 1);
        }
        let dir = self.run_dir.clone().with_context(|| {
            format!("served store has no parent directory to resolve a {bits}-bit sibling in")
        })?;
        let available = run_dir_precisions(&dir)
            .with_context(|| format!("listing precisions of run dir {dir:?}"))?;
        let matches: Vec<_> = available.iter().filter(|p| p.bits == bits).collect();
        let p = match matches.len() {
            0 => {
                let have: Vec<String> =
                    available.iter().map(|p| p.label().to_string()).collect();
                let have = if have.is_empty() {
                    "none".to_string()
                } else {
                    have.join(", ")
                };
                bail!(
                    "run dir {dir:?} holds no {bits}-bit store (available: {have}); \
                     build the run with --bits listing every cascade precision"
                )
            }
            1 => *matches[0],
            _ => bail!(
                "run dir {dir:?} holds {} different {bits}-bit stores — a bitwidth \
                 must name one store unambiguously",
                matches.len()
            ),
        };
        let path = default_store_path(&dir, p);
        let live = LiveStore::open(&path)
            .with_context(|| format!("opening cascade-stage store {path:?}"))?;
        let (base, aux) = (self.live.header(), live.header());
        ensure!(
            aux.k == base.k,
            "{bits}-bit store projects to k={}, served store to k={}",
            aux.k,
            base.k
        );
        ensure!(
            aux.n_checkpoints == base.n_checkpoints,
            "{bits}-bit store has {} checkpoints, served store {}",
            aux.n_checkpoints,
            base.n_checkpoints
        );
        let etas = live.etas();
        ensure!(
            etas.len() == self.etas.len()
                && etas.iter().zip(&self.etas).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{bits}-bit store's η schedule differs from the served store's — \
             not the same warmup run"
        );
        let rows_per_shard =
            live.rows_per_shard(self.opts.shard_rows, self.opts.mem_budget_mb.max(1));
        info!(
            "session: resolved {bits}-bit cascade store {path:?} ({} rows, \
             {rows_per_shard} rows/shard)",
            live.n_rows()
        );
        self.aux.push(AuxStore { bits, live, rows_per_shard });
        Ok(self.aux.len())
    }

    /// Poll an aux store's generation manifest (the base store is polled
    /// by [`Session::poll_generation`]); like it, failures downgrade to a
    /// warning and the session keeps serving what it has.
    fn refresh_store(&mut self, store: usize) {
        if store == 0 {
            return;
        }
        let a = &mut self.aux[store - 1];
        if let Err(e) = a.live.refresh() {
            warn_!(
                "session: {}-bit store refresh failed ({e:#}); still serving generation {}",
                a.bits,
                a.live.generation()
            );
        }
    }

    fn store_header(&self, store: usize) -> &Header {
        match store {
            0 => self.live.header(),
            s => self.aux[s - 1].live.header(),
        }
    }

    fn store_n_rows(&self, store: usize) -> usize {
        match store {
            0 => self.live.n_rows(),
            s => self.aux[s - 1].live.n_rows(),
        }
    }

    /// One fused multi-task pass over the global rows `start .. start +
    /// len`, preferring pinned shards: cache hits feed the scan straight
    /// from RAM; misses are read with a seek-based
    /// [`crate::datastore::ShardReader`], fed, and pinned for the next
    /// pass (LRU-evicted under the byte budget). Members outside the
    /// range are skipped entirely, and within an overlapping member only
    /// the shards intersecting the range are touched; a shard straddling
    /// a range edge is fed through a clipped
    /// [`crate::datastore::RowsView::slice`] (the cache still pins the
    /// whole shard, so neighbouring ranges share it). Stats therefore
    /// count exactly the rows inside the range.
    fn scan_store_range(
        &mut self,
        store: usize,
        tasks: &[&[FeatureMatrix]],
        start: usize,
        len: usize,
    ) -> Result<(Vec<Vec<f32>>, ScanStats)> {
        let mut scan = MultiScan::try_new_range(self.store_header(store), tasks, start, len)?;
        for ci in 0..self.etas.len() {
            self.feed_range(store, &mut scan, ci, start, len)?;
        }
        self.stats.fused_passes += 1;
        let (totals, pass) = scan.finish();
        self.stats.rows_scored += pass.rows_read;
        Ok((totals, pass))
    }

    /// One fused multi-task pass over exactly the global `rows` (strictly
    /// increasing) of `store` — the cascade rerank primitive. Accumulators
    /// cover the full row space (candidate sets are sparse but global);
    /// only the contiguous runs of `rows` are read, through the same
    /// pinned shard cache as ranged scans. Returns per-task scores
    /// **gathered to `rows` order** (`scored[t][j]` is global row
    /// `rows[j]`), plus the pass stats (`rows_read == rows.len()` per
    /// checkpoint — what the rerank actually cost).
    fn scan_store_rows(
        &mut self,
        store: usize,
        tasks: &[&[FeatureMatrix]],
        rows: &[usize],
    ) -> Result<(Vec<Vec<f32>>, ScanStats)> {
        let n = self.store_n_rows(store);
        let runs = cascade::contiguous_runs(rows);
        let mut scan = MultiScan::try_new_range(self.store_header(store), tasks, 0, n)?;
        for ci in 0..self.etas.len() {
            for &(start, len) in &runs {
                self.feed_range(store, &mut scan, ci, start, len)?;
            }
        }
        self.stats.fused_passes += 1;
        let (totals, pass) = scan.finish();
        self.stats.rows_scored += pass.rows_read;
        let gathered =
            totals.iter().map(|t| rows.iter().map(|&r| t[r]).collect()).collect();
        Ok((gathered, pass))
    }

    /// Feed every `store` shard overlapping global rows `start .. start +
    /// len` of checkpoint `ci` into `scan`, clipped to the range —
    /// cache-pinned shards from RAM, misses via seek-based reads (then
    /// pinned). The shared inner loop of ranged, fused and sparse scans.
    fn feed_range(
        &mut self,
        store: usize,
        scan: &mut MultiScan,
        ci: usize,
        start: usize,
        len: usize,
    ) -> Result<()> {
        let end = start + len;
        let eta = self.etas[ci];
        let (live, rows_per_shard) = match store {
            0 => (&self.live, self.rows_per_shard),
            s => (&self.aux[s - 1].live, self.aux[s - 1].rows_per_shard),
        };
        for (mi, member) in live.members().iter().enumerate() {
            let m_rows = member.ds.n_samples();
            let m_lo = member.start_row;
            if m_lo + m_rows <= start || m_lo >= end {
                continue;
            }
            // shard indices of this member intersecting [start, end)
            let lo_local = start.saturating_sub(m_lo);
            let hi_local = (end - m_lo).min(m_rows);
            let si_lo = lo_local / rows_per_shard;
            let si_hi = hi_local.div_ceil(rows_per_shard);
            let mut reader = None;
            for si in si_lo..si_hi {
                let key = (store, mi, ci, si);
                let owned = if let Some(shard) = self.shard_cache.get(&key) {
                    self.stats.shard_cache_hits += 1;
                    obs::counter_add("shard_cache_hits_total", 1);
                    shard
                } else {
                    if reader.is_none() {
                        reader = Some(member.ds.shard_reader(ci, rows_per_shard)?);
                    }
                    let r = reader.as_mut().expect("reader just opened");
                    r.seek_to_row(si * rows_per_shard);
                    let shard = r.next_shard()?.with_context(|| {
                        format!("shard {si} of checkpoint {ci} (member {mi}) out of range")
                    })?;
                    let owned = Arc::new(shard.to_owned_shard());
                    self.stats.disk_shard_reads += 1;
                    obs::counter_add("shard_cache_misses_total", 1);
                    let weight = owned.byte_weight();
                    let evicted = self.shard_cache.insert(key, Arc::clone(&owned), weight);
                    obs::counter_add("shard_cache_evicted_bytes_total", evicted as u64);
                    obs::gauge_set("shard_cache_bytes", self.shard_cache.weight() as i64);
                    owned
                };
                let view = owned.rows();
                let s_lo = m_lo + owned.start;
                let a = start.max(s_lo) - s_lo;
                let b = (end.min(s_lo + view.n())) - s_lo;
                scan.feed(ci, eta, s_lo + a, &view.slice(a, b));
            }
        }
        Ok(())
    }
}

/// Per-batch query dedup: `(digest per query, distinct digests in arrival
/// order, one task slice per distinct digest)` — batch sizes are small
/// (`max_batch_tasks`), so linear dedup beats a map.
fn dedup_tasks(queries: &[ScoreQuery]) -> (Vec<u64>, Vec<u64>, Vec<&[FeatureMatrix]>) {
    let digests: Vec<u64> = queries.iter().map(|q| q.digest()).collect();
    let mut distinct: Vec<u64> = Vec::new();
    for d in &digests {
        if !distinct.contains(d) {
            distinct.push(*d);
        }
    }
    let tasks: Vec<&[FeatureMatrix]> = distinct
        .iter()
        .map(|d| {
            let i = digests.iter().position(|x| x == d).expect("digest from this batch");
            queries[i].val.as_slice()
        })
        .collect();
    (digests, distinct, tasks)
}

/// The `(generation, start_row)` member map shared with answers.
fn member_map(live: &LiveStore) -> Vec<(u64, usize)> {
    live.members().iter().map(|m| (m.generation, m.start_row)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::{default_store_path, SegmentWriter};
    use crate::influence::{score_datastore_tasks, ScoreOpts};
    use crate::quant::{Precision, Scheme};
    use crate::util::prop::{normal_features as feats, seeded_datastore};
    use std::path::PathBuf;

    fn build_store(bits: u8, n: usize, k: usize, etas: &[f32], tag: &str) -> PathBuf {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = std::env::temp_dir().join(format!(
            "qless_sess_{tag}_{bits}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ));
        seeded_datastore(&path, p, n, k, etas, 0);
        path
    }

    fn task(k: usize, seed: u64, ckpts: usize) -> Vec<FeatureMatrix> {
        (0..ckpts).map(|ci| feats(3, k, seed + ci as u64)).collect()
    }

    #[test]
    fn session_scores_match_batch_pipeline_exactly() {
        let (n, k) = (23usize, 64usize);
        let path = build_store(4, n, k, &[0.7, 0.3], "exact");
        let ds = crate::datastore::Datastore::open(&path).unwrap();
        let t0 = task(k, 100, 2);
        let t1 = task(k, 200, 2);
        let (want, _) = score_datastore_tasks(
            &ds,
            &[&t0, &t1],
            ScoreOpts { shard_rows: 5, ..Default::default() },
            None,
        )
        .unwrap();
        let opts = SessionOpts { shard_rows: 5, mem_budget_mb: 4, score_cache_entries: 8 };
        let mut sess = Session::open(&path, opts).unwrap();
        assert_eq!(sess.rows_per_shard(), 5);
        assert_eq!(sess.generation(), 0, "frozen store serves generation 0");
        assert_eq!(sess.n_rows(), n);
        let queries = vec![ScoreQuery { val: t0.clone() }, ScoreQuery { val: t1.clone() }];
        for q in &queries {
            q.validate(sess.header()).unwrap();
        }
        let answers = sess.answer_batch(&queries).unwrap();
        assert_eq!(answers.len(), 2);
        for (t, a) in answers.iter().enumerate() {
            assert!(!a.cached);
            assert_eq!(a.batched, 2, "both tasks fused into one pass");
            assert_eq!(a.pass.tasks, 2);
            assert_eq!(a.generation, 0);
            assert_eq!(*a.scores, want[t], "task {t}: served vs pipeline scores");
        }
        // both answers share one pass: shard traffic of a single scan
        assert_eq!(answers[0].pass, answers[1].pass);
        assert_eq!(answers[0].pass.shards_read, 2 * n.div_ceil(5));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn warm_queries_skip_disk_and_identical_queries_skip_scans() {
        let (n, k) = (16usize, 64usize);
        let path = build_store(8, n, k, &[1.0], "warm");
        let opts = SessionOpts { shard_rows: 4, mem_budget_mb: 16, score_cache_entries: 4 };
        let mut sess = Session::open(&path, opts).unwrap();
        let q0 = ScoreQuery { val: task(k, 300, 1) };
        let a0 = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        let cold = sess.stats();
        assert_eq!(cold.disk_shard_reads, 4, "cold pass reads every shard");
        assert_eq!(cold.fused_passes, 1);
        // identical query: score cache answers without any scan
        let a1 = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        assert!(a1[0].cached);
        assert_eq!(a1[0].scores, a0[0].scores);
        let s1 = sess.stats();
        assert_eq!(s1.score_cache_hits, 1);
        assert_eq!(s1.fused_passes, 1, "no new pass");
        assert_eq!(s1.disk_shard_reads, cold.disk_shard_reads);
        // different task, warm shard cache: a scan, but zero disk reads
        let q1 = ScoreQuery { val: task(k, 301, 1) };
        let a2 = sess.answer_batch(std::slice::from_ref(&q1)).unwrap();
        assert!(!a2[0].cached);
        let s2 = sess.stats();
        assert_eq!(s2.fused_passes, 2);
        assert_eq!(s2.disk_shard_reads, cold.disk_shard_reads, "warm scan is RAM-only");
        assert_eq!(s2.shard_cache_hits, 4);
        assert!(s2.shard_cache_bytes > 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn batch_dedup_fuses_identical_queries_into_one_task() {
        let (n, k) = (12usize, 64usize);
        let path = build_store(2, n, k, &[0.5], "dedup");
        let mut sess = Session::open(
            &path,
            SessionOpts { shard_rows: 0, mem_budget_mb: 8, score_cache_entries: 0 },
        )
        .unwrap();
        let a = ScoreQuery { val: task(k, 400, 1) };
        let b = ScoreQuery { val: task(k, 401, 1) };
        let batch = vec![a.clone(), b.clone(), a.clone(), a.clone()];
        let answers = sess.answer_batch(&batch).unwrap();
        for ans in &answers {
            assert_eq!(ans.batched, 2, "4 queries, 2 distinct tasks");
            assert_eq!(ans.pass.tasks, 2);
        }
        assert_eq!(answers[0].scores, answers[2].scores);
        assert_eq!(answers[0].scores, answers[3].scores);
        assert_ne!(answers[0].scores, answers[1].scores);
        // score cache disabled: the same batch rescans, same results
        let again = sess.answer_batch(&batch).unwrap();
        assert_eq!(again[0].scores, answers[0].scores);
        assert!(!again[0].cached);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn ranged_answers_match_full_scan_slices_bit_exactly() {
        // The scatter-gather worker contract: scores for rows
        // `start..start+len` must equal the same slice of a full-store
        // scan, bit for bit, for ranges that straddle shard boundaries
        // (shards are 5 rows here, ranges deliberately are not).
        let (n, k) = (23usize, 64usize);
        let path = build_store(4, n, k, &[0.7, 0.3], "range");
        let opts = SessionOpts { shard_rows: 5, mem_budget_mb: 4, score_cache_entries: 8 };
        let mut sess = Session::open(&path, opts).unwrap();
        let q = ScoreQuery { val: task(k, 700, 2) };
        let full = sess.answer_batch(std::slice::from_ref(&q)).unwrap();
        for (start, len) in [(0usize, n), (0, 7), (3, 9), (7, 11), (20, 3), (22, 1)] {
            let part = sess.answer_range(std::slice::from_ref(&q), start, len).unwrap();
            assert!(!part[0].cached, "ranged answers bypass the score cache");
            assert_eq!(part[0].scores.len(), len);
            assert_eq!(
                part[0].scores[..],
                full[0].scores[start..start + len],
                "range {start}+{len} vs full-scan slice"
            );
            assert_eq!(
                part[0].pass.rows_read,
                (2 * len) as u64,
                "range {start}+{len} must score only its own rows"
            );
        }
        // batch dedup still applies on the ranged path
        let pair = vec![q.clone(), q.clone()];
        let both = sess.answer_range(&pair, 3, 9).unwrap();
        assert_eq!(both[0].batched, 1, "identical ranged queries fuse");
        assert_eq!(both[0].scores, both[1].scores);
        // malformed ranges fail cleanly
        assert!(sess.answer_range(std::slice::from_ref(&q), 0, 0).is_err());
        assert!(sess.answer_range(std::slice::from_ref(&q), 20, 4).is_err());
        assert!(sess.answer_range(std::slice::from_ref(&q), usize::MAX, 2).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn validate_rejects_malformed_queries() {
        let (n, k) = (8usize, 64usize);
        let path = build_store(8, n, k, &[1.0, 1.0], "val");
        let sess = Session::open(&path, SessionOpts::default()).unwrap();
        let h = *sess.header();
        // wrong checkpoint count
        assert!(ScoreQuery { val: task(k, 1, 1) }.validate(&h).is_err());
        // wrong k
        assert!(ScoreQuery { val: task(32, 1, 2) }.validate(&h).is_err());
        // empty matrix
        let empty = vec![
            FeatureMatrix { n: 0, k, data: vec![] },
            FeatureMatrix { n: 0, k, data: vec![] },
        ];
        assert!(ScoreQuery { val: empty }.validate(&h).is_err());
        // flat-length mismatch
        let mut bad = task(k, 1, 2);
        bad[0].data.pop();
        assert!(ScoreQuery { val: bad }.validate(&h).is_err());
        // n·k that wraps to 0 in release builds: checked_mul must reject,
        // or a hostile wire request drives an n-sized allocation
        let huge = vec![
            FeatureMatrix { n: usize::MAX / 2 + 1, k, data: vec![] },
            FeatureMatrix { n: usize::MAX / 2 + 1, k, data: vec![] },
        ];
        assert!(ScoreQuery { val: huge }.validate(&h).is_err());
        // non-finite
        let mut nan = task(k, 1, 2);
        nan[1].data[5] = f32::NAN;
        let err = ScoreQuery { val: nan }.validate(&h).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
        // a good one passes
        ScoreQuery { val: task(k, 1, 2) }.validate(&h).unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cascade_answers_match_direct_cascade_and_share_the_shard_cache() {
        // Serve-side cascade vs the library path, bit for bit — and the
        // second cascade batch must run entirely from pinned shards.
        let (n, k) = (29usize, 64usize);
        let etas = [0.7f32, 0.3];
        let dir = std::env::temp_dir().join(format!(
            "qless_sess_casc_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        let probe_path = default_store_path(&dir, p1);
        let rerank_path = default_store_path(&dir, p8);
        // same seed at both precisions = aligned row spaces
        seeded_datastore(&probe_path, p1, n, k, &etas, 0);
        seeded_datastore(&rerank_path, p8, n, k, &etas, 0);

        let t0 = task(k, 800, 2);
        let t1 = task(k, 801, 2);
        let opts = crate::influence::CascadeOpts {
            k: 3,
            mult: 2,
            scan: ScoreOpts { shard_rows: 5, ..Default::default() },
        };
        let probe_live = crate::datastore::LiveStore::open(&probe_path).unwrap();
        let rerank_live = crate::datastore::LiveStore::open(&rerank_path).unwrap();
        let want = crate::influence::cascade_live_tasks(
            &probe_live,
            &rerank_live,
            &[&t0, &t1],
            opts,
        )
        .unwrap();

        let sopts = SessionOpts { shard_rows: 5, mem_budget_mb: 8, score_cache_entries: 4 };
        let mut sess = Session::open(&probe_path, sopts).unwrap();
        let plan = CascadePlan { probe: 1, rerank: 8, mult: 2 };
        let queries = vec![ScoreQuery { val: t0.clone() }, ScoreQuery { val: t1.clone() }];
        let answers = sess.answer_cascade(&queries, plan, 3).unwrap();
        assert_eq!(answers.len(), 2);
        for (t, a) in answers.iter().enumerate() {
            assert!(!a.cached, "cascade answers bypass the score cache");
            assert_eq!(a.batched, 2);
            assert!(a.scores.is_empty(), "no full vector on a cascade answer");
            let top = a.top.as_ref().expect("cascade answers carry top");
            assert_eq!(top.len(), want.top[t].len());
            for (got, w) in top.iter().zip(&want.top[t]) {
                assert_eq!(got.0, w.0, "task {t}: row order");
                assert_eq!(got.1.to_bits(), w.1.to_bits(), "task {t}: bit-exact score");
            }
            // rows/bytes mirror the library cascade exactly; shard counts
            // may differ (the cache feeds fixed shards, clipped)
            let lib = want.combined_pass();
            assert_eq!(a.pass.rows_read, lib.rows_read);
            assert_eq!(a.pass.bytes_read, lib.bytes_read);
        }
        // the serving answer of one task alone equals its batched answer:
        // final top-k ranks only the task's OWN candidates, so batch
        // composition cannot change an answer (the union is I/O-only)
        let solo = sess
            .answer_cascade(&[ScoreQuery { val: t0.clone() }], plan, 3)
            .unwrap();
        assert_eq!(solo[0].top, answers[0].top);
        // warm repeat: both stages read zero shards from disk
        let before = sess.stats();
        let again = sess.answer_cascade(&queries, plan, 3).unwrap();
        assert_eq!(again[0].top, answers[0].top);
        let after = sess.stats();
        assert_eq!(after.disk_shard_reads, before.disk_shard_reads, "warm cascade is RAM-only");
        assert!(after.shard_cache_hits > before.shard_cache_hits);
        // exhaustive queries on the same session still work (store 0)
        let full = sess.answer_batch(&queries).unwrap();
        assert_eq!(full[0].scores.len(), n);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cascade_worker_verbs_cover_probe_and_rerank_stores() {
        let (n, k) = (17usize, 64usize);
        let etas = [1.0f32];
        let dir = std::env::temp_dir().join(format!(
            "qless_sess_verbs_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        seeded_datastore(&default_store_path(&dir, p1), p1, n, k, &etas, 0);
        let rerank_path = default_store_path(&dir, p8);
        seeded_datastore(&rerank_path, p8, n, k, &etas, 0);
        // serve the 8-bit store; the 1-bit sibling resolves on demand
        let mut sess = Session::open(
            &rerank_path,
            SessionOpts { shard_rows: 4, mem_budget_mb: 8, score_cache_entries: 0 },
        )
        .unwrap();
        let q = ScoreQuery { val: task(k, 900, 1) };
        // ranged probe at 1-bit == the 1-bit store's full scan slice
        let probe_ds = crate::datastore::Datastore::open(&default_store_path(&dir, p1)).unwrap();
        let (want1, _) = score_datastore_tasks(
            &probe_ds,
            &[q.val.as_slice()],
            ScoreOpts { shard_rows: 4, ..Default::default() },
            None,
        )
        .unwrap();
        let part = sess.answer_range_at(std::slice::from_ref(&q), 3, 9, 1).unwrap();
        assert_eq!(part[0].scores[..], want1[0][3..12], "1-bit ranged probe slice");
        // sparse rerank at 8-bit == gathered full-scan values
        let rerank_ds = crate::datastore::Datastore::open(&rerank_path).unwrap();
        let (want8, _) = score_datastore_tasks(
            &rerank_ds,
            &[q.val.as_slice()],
            ScoreOpts { shard_rows: 4, ..Default::default() },
            None,
        )
        .unwrap();
        let rows = vec![0usize, 5, 6, 7, 16];
        let rr = sess.answer_rerank_rows(std::slice::from_ref(&q), &rows, 8).unwrap();
        let top = rr[0].top.as_ref().unwrap();
        assert_eq!(top.len(), rows.len());
        for (j, &(row, score)) in top.iter().enumerate() {
            assert_eq!(row, rows[j]);
            assert_eq!(score.to_bits(), want8[0][row].to_bits());
        }
        assert_eq!(rr[0].pass.rows_read, rows.len() as u64, "rerank reads only listed rows");
        // malformed rerank row lists fail cleanly
        assert!(sess.answer_rerank_rows(std::slice::from_ref(&q), &[], 8).is_err());
        assert!(sess.answer_rerank_rows(std::slice::from_ref(&q), &[4, 4], 8).is_err());
        assert!(sess.answer_rerank_rows(std::slice::from_ref(&q), &[n], 8).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cascade_on_a_single_precision_run_is_a_clean_error() {
        let (n, k) = (8usize, 64usize);
        let etas = [1.0f32];
        let dir = std::env::temp_dir().join(format!(
            "qless_sess_single_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        let path = default_store_path(&dir, p8);
        seeded_datastore(&path, p8, n, k, &etas, 0);
        let mut sess = Session::open(&path, SessionOpts::default()).unwrap();
        let q = ScoreQuery { val: task(k, 1000, 1) };
        let plan = CascadePlan { probe: 1, rerank: 8, mult: 2 };
        let err = sess.answer_cascade(std::slice::from_ref(&q), plan, 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("no 1-bit store"), "{msg}");
        assert!(msg.contains("8-bit"), "error lists what IS available: {msg}");
        // degenerate plans are rejected before any store resolution
        let same = CascadePlan { probe: 8, rerank: 8, mult: 2 };
        assert!(sess.answer_cascade(std::slice::from_ref(&q), same, 2).is_err());
        assert!(sess
            .answer_cascade(std::slice::from_ref(&q), plan, 0)
            .unwrap_err()
            .to_string()
            .contains("top_k"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn indexed_answers_match_library_path_and_fall_back_without_sidecar() {
        use crate::datastore::{index_path, reindex_store, IndexBuildOpts, LiveStore, QuantIndex};
        let (n, k) = (48usize, 64usize);
        let etas = [0.7f32, 0.3];
        let path = build_store(1, n, k, &etas, "idx");
        let sopts = SessionOpts { shard_rows: 5, mem_budget_mb: 8, score_cache_entries: 4 };
        let q = ScoreQuery { val: task(k, 1100, 2) };

        // no sidecar yet: the plain verb falls back to an exhaustive scan
        let mut sess = Session::open(&path, sopts).unwrap();
        assert!(!sess.has_index());
        let fb = sess.answer_index(std::slice::from_ref(&q), 2, 5, None).unwrap();
        assert!(fb[0].scores.is_empty(), "indexed answers carry top lists only");
        let full = sess.answer_batch(std::slice::from_ref(&q)).unwrap();
        let want_fb = top_k_scored(&full[0].scores, 5);
        assert_eq!(fb[0].top.as_ref().unwrap(), &want_fb, "fallback = exhaustive top-k");
        let s = sess.stats();
        assert_eq!((s.index_queries, s.index_fallbacks), (1, 1));
        assert_eq!(s.index_clusters, 0, "no index loaded");
        // a cluster window without an index is an error, not a fallback
        assert!(sess.answer_index(std::slice::from_ref(&q), 2, 5, Some((0, 1))).is_err());
        // degenerate knobs are rejected up front
        assert!(sess.answer_index(std::slice::from_ref(&q), 0, 5, None).is_err());
        assert!(sess.answer_index(std::slice::from_ref(&q), 2, 0, None).is_err());

        // build the sidecar; a fresh session serves through it, bit-exact
        // against the library path
        let idx = reindex_store(&path, &IndexBuildOpts { n_clusters: 6, max_iters: 4 }).unwrap();
        assert_eq!(idx.n_clusters(), 6);
        let mut sess = Session::open(&path, sopts).unwrap();
        assert!(sess.has_index());
        let live = LiveStore::open(&path).unwrap();
        let owned = vec![q.val.clone()];
        let tasks: Vec<&[FeatureMatrix]> = owned.iter().map(|t| t.as_slice()).collect();
        let iopts = crate::influence::IndexOpts {
            k: 5,
            nprobe: 3,
            scan: ScoreOpts { shard_rows: 5, mem_budget_mb: 8, ..Default::default() },
        };
        let want = crate::influence::index_scan_live_tasks(&live, &idx, &tasks, &iopts).unwrap();
        let got = sess.answer_index(std::slice::from_ref(&q), 3, 5, None).unwrap();
        let top = got[0].top.as_ref().unwrap();
        assert_eq!(top.len(), want.top[0].len());
        for (a, b) in top.iter().zip(&want.top[0]) {
            assert_eq!(a.0, b.0, "served indexed rows");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "served indexed scores are bit-exact");
        }
        assert_eq!(
            got[0].pass.rows_read,
            want.combined_pass().rows_read,
            "served pass costs exactly what the library path costs"
        );
        let s = sess.stats();
        assert_eq!((s.index_queries, s.index_fallbacks), (1, 0));
        assert_eq!((s.index_clusters, s.index_stale_rows), (6, 0));
        // disjoint cluster-list windows merge to the whole query
        let a = sess.answer_index(std::slice::from_ref(&q), 3, 5, Some((0, 2))).unwrap();
        let b = sess.answer_index(std::slice::from_ref(&q), 3, 5, Some((2, 1))).unwrap();
        let merged = crate::select::merge_top_k(
            &[a[0].top.clone().unwrap(), b[0].top.clone().unwrap()],
            5,
        );
        assert_eq!(&merged, top, "windowed worker answers merge exactly");

        // live ingest: new rows are assigned to centroids in memory and
        // served (staleness surfaces in stats; answers stay bit-exact
        // against a freshly refreshed library index)
        // build_store writes an arbitrary stem; indexed ingest needs the
        // default-named store the manifest binds to — move both files into
        // a fresh run directory under the canonical name
        let dir = std::env::temp_dir().join(format!(
            "qless_sess_idxing_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let named = default_store_path(&dir, p1);
        drop(sess);
        drop(live);
        std::fs::rename(&path, &named).unwrap();
        std::fs::rename(index_path(&path), index_path(&named)).unwrap();
        let mut sess = Session::open(&named, sopts).unwrap();
        let add = 6usize;
        let mut sw = SegmentWriter::create(&dir, &[p1], add, 0).unwrap();
        for ci in 0..etas.len() {
            sw.begin_checkpoint().unwrap();
            sw.append_rows(&feats(n + add, k, 40 + ci as u64).data[n * k..]).unwrap();
            sw.end_checkpoint().unwrap();
        }
        sw.finalize().unwrap();
        let got = sess.answer_index(std::slice::from_ref(&q), 3, 5, None).unwrap();
        assert_eq!(got[0].generation, 1, "ingest picked up live");
        let s = sess.stats();
        assert_eq!(s.index_stale_rows, add as u64, "ingested rows are the staleness");
        let live2 = LiveStore::open(&named).unwrap();
        let idx2 = QuantIndex::open(&index_path(&named), &live2).unwrap();
        assert_eq!(idx2.stale_rows(), add as u64);
        let want2 = crate::influence::index_scan_live_tasks(&live2, &idx2, &tasks, &iopts).unwrap();
        let top2 = got[0].top.as_ref().unwrap();
        assert_eq!(top2.len(), want2.top[0].len());
        for (a, b) in top2.iter().zip(&want2.top[0]) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "post-ingest indexed scores are bit-exact");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ingest_reload_extends_cached_scores_with_a_tail_scan() {
        // The generation-aware acceptance test at the session level: an
        // ingest mid-session is picked up without reopening, a cached
        // answer is extended by scanning ONLY the new rows, warm base
        // shards stay pinned, and everything matches a monolithic store
        // holding the same rows.
        let (n0, add, k) = (12usize, 6usize, 64usize);
        let etas = [0.7f32, 0.3];
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "qless_sess_reload_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let base = default_store_path(&dir, p);
        // normal_features draws sequentially, so the monolithic fixture's
        // first n0 rows equal the base store's rows exactly
        seeded_datastore(&base, p, n0, k, &etas, 0);
        let mono_path = dir.join("mono.qlds");
        let mono = seeded_datastore(&mono_path, p, n0 + add, k, &etas, 0);

        let opts = SessionOpts { shard_rows: 4, mem_budget_mb: 16, score_cache_entries: 8 };
        let mut sess = Session::open(&base, opts).unwrap();
        let q0 = ScoreQuery { val: task(k, 500, 2) };
        let before = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        assert_eq!(before[0].scores.len(), n0);
        assert_eq!(before[0].generation, 0);
        let base_digest = std::fs::read(&base).unwrap();
        let cold = sess.stats();

        // ingest `add` rows (the monolithic fixture's tail) mid-session
        let mut sw = SegmentWriter::create(&dir, &[p], add, 0).unwrap();
        for ci in 0..etas.len() {
            sw.begin_checkpoint().unwrap();
            sw.append_rows(&feats(n0 + add, k, ci as u64).data[n0 * k..]).unwrap();
            sw.end_checkpoint().unwrap();
        }
        sw.finalize().unwrap();
        assert_eq!(std::fs::read(&base).unwrap(), base_digest, "ingest never touches the base");

        // repeat query: picked up live, extended by a tail-only pass
        let after = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        assert_eq!(after[0].generation, 1);
        assert_eq!(after[0].scores.len(), n0 + add);
        assert_eq!(after[0].scores[..n0], before[0].scores[..], "prefix reused verbatim");
        assert!(!after[0].cached);
        assert_eq!(
            after[0].pass.rows_read,
            (etas.len() * add) as u64,
            "extension must scan only the ingested rows"
        );
        assert_eq!(*after[0].gen_rows, vec![(0u64, 0usize), (1u64, n0)]);
        let s = sess.stats();
        assert_eq!(s.reloads, 1);
        assert_eq!(s.score_cache_extends, 1);
        assert_eq!(
            s.disk_shard_reads - cold.disk_shard_reads,
            (etas.len() * add.div_ceil(4)) as u64,
            "only segment shards hit disk; warm base shards stay pinned"
        );

        // served values equal a full scan of the monolithic store
        let (want, _) = score_datastore_tasks(
            &mono,
            &[q0.val.as_slice()],
            ScoreOpts { shard_rows: 4, ..Default::default() },
            None,
        )
        .unwrap();
        assert_eq!(*after[0].scores, want[0], "extended scores vs monolithic scan");

        // a brand-new task after the reload scans the full live store
        let q1 = ScoreQuery { val: task(k, 600, 2) };
        let fresh = sess.answer_batch(std::slice::from_ref(&q1)).unwrap();
        assert_eq!(fresh[0].scores.len(), n0 + add);
        assert_eq!(fresh[0].pass.rows_read, (etas.len() * (n0 + add)) as u64);
        // and an exact repeat is a plain cache hit at the new generation
        let hit = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        assert!(hit[0].cached);
        assert_eq!(hit[0].generation, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
