//! The resident influence session: one **live** datastore opened (and
//! validated) once, per-checkpoint η weights read once, recently-scanned
//! shards pinned in a byte-budgeted LRU cache so repeat scans hit RAM
//! instead of disk, and a score cache keyed by task digest so identical
//! queries never rescan at all.
//!
//! [`Session::answer_batch`] is the serving hot path: poll the generation
//! manifest (an ingest bumps it — new segment members attach **in
//! place**), resolve score-cache hits, deduplicate identical queries
//! within the batch, then run **one** fused [`MultiScan`] pass over the
//! store for every distinct uncached task. Shards come from the cache
//! when pinned and from `ShardReader::seek_to_row` random-access reads
//! when not; either way the scoring kernels see the same
//! [`crate::datastore::RowsView`] bytes, so served scores are
//! bit-identical to the one-shot `--multi-scan` pipeline
//! (`influence::score_datastore_tasks` /
//! [`crate::influence::score_live_tasks`]), which the e2e suites assert.
//!
//! Generations invalidate **only affected ranges**: shard-cache keys
//! include the member (segment) index, so every shard pinned before an
//! ingest stays pinned and valid after it; a score-cache entry from
//! before an ingest is a *prefix* of the new answer, extended by a fused
//! **tail scan** over just the newly ingested rows rather than
//! recomputed. The session is owned by one scoring worker
//! ([`super::batcher`]), so an in-flight batch always finishes against
//! the generation it started on — reloads happen between batches.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::datastore::{Header, LiveStore, OwnedShard};
use crate::grads::FeatureMatrix;
use crate::influence::{MultiScan, ScanStats};
use crate::{info, warn_};

use super::cache::{task_digest, LruCache};

/// Knobs of a resident session (a subset of `ServeOpts`, usable without
/// the TCP front end — tests and the in-process path build these directly).
#[derive(Debug, Clone, Copy)]
pub struct SessionOpts {
    /// Fixed rows per shard; 0 = derive from `mem_budget_mb`.
    pub shard_rows: usize,
    /// Shard-cache byte budget in MiB; also bounds the scan's streaming
    /// shard size (the same contract as the batch pipeline's
    /// `--mem-budget-mb`, so peak residency is ≈ 2× this: one streaming
    /// buffer + the pinned cache).
    pub mem_budget_mb: usize,
    /// Score-cache capacity in entries (each entry is one per-sample
    /// score vector); 0 disables score caching.
    pub score_cache_entries: usize,
}

impl Default for SessionOpts {
    fn default() -> SessionOpts {
        SessionOpts {
            shard_rows: 0,
            mem_budget_mb: crate::DEFAULT_MEM_BUDGET_MB,
            score_cache_entries: 64,
        }
    }
}

/// Cumulative accounting of a session — the payload of the wire `stats`
/// op. Cache-efficacy counters are the interesting part: a warm repeat
/// query moves `score_cache_hits` (or `shard_cache_hits`) without moving
/// `disk_shard_reads`, and after an ingest a repeat query moves
/// `score_cache_extends` with a pass that only reads the new rows.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Score queries answered (including cache hits).
    pub queries: u64,
    /// `answer_batch` calls (micro-batches admitted).
    pub batches: u64,
    /// Fused datastore passes executed (0-miss batches skip it; a batch
    /// mixing cold misses and post-ingest extensions runs two).
    pub fused_passes: u64,
    /// Queries answered from the score cache without any scan.
    pub score_cache_hits: u64,
    /// Score-cache prefix hits extended by a tail scan over newly
    /// ingested rows only (never a full rescan).
    pub score_cache_extends: u64,
    /// Shards served from the RAM cache during scans.
    pub shard_cache_hits: u64,
    /// Shards read from the datastore files (cold misses).
    pub disk_shard_reads: u64,
    /// Bytes currently pinned by the shard cache.
    pub shard_cache_bytes: u64,
    /// Rows scored across all fused passes.
    pub rows_scored: u64,
    /// Generation bumps picked up live (ingests served without restart).
    pub reloads: u64,
}

/// One influence query: raw (unquantized) validation gradient features per
/// warmup checkpoint, in checkpoint order — exactly the per-task shape
/// [`crate::influence::score_datastore_tasks`] takes.
#[derive(Debug, Clone)]
pub struct ScoreQuery {
    /// One feature matrix per checkpoint (`val[ci]` is `n_val × k`).
    pub val: Vec<FeatureMatrix>,
}

impl ScoreQuery {
    /// The score-cache key for this query's features (see
    /// [`task_digest`]).
    pub fn digest(&self) -> u64 {
        task_digest(&self.val)
    }

    /// Cheap admission-time validation against the served store's
    /// geometry: checkpoint count, feature dimension, non-empty matrices,
    /// flat-data length, finiteness. Runs before the query is enqueued so
    /// one malformed query gets its own error response instead of failing
    /// a whole batch. Geometry here is ingest-invariant (ingest only adds
    /// rows), so validation never races a reload.
    pub fn validate(&self, header: &Header) -> Result<()> {
        let c = header.n_checkpoints as usize;
        anyhow::ensure!(
            self.val.len() == c,
            "query has {} checkpoint feature sets, datastore has {c}",
            self.val.len()
        );
        for (ci, m) in self.val.iter().enumerate() {
            anyhow::ensure!(
                m.k == header.k as usize,
                "checkpoint {ci}: feature dim {} != datastore k {}",
                m.k,
                header.k
            );
            anyhow::ensure!(m.n > 0, "checkpoint {ci}: empty validation features");
            // checked: n and k come off the wire, and an n·k that wraps in
            // release builds could pass an unchecked equality against a
            // tiny data length and then drive an n-sized allocation
            let expect = m.n.checked_mul(m.k);
            anyhow::ensure!(
                expect == Some(m.data.len()),
                "checkpoint {ci}: {} values for {}×{} features",
                m.data.len(),
                m.n,
                m.k
            );
            if let Some(j) = m.data.iter().position(|x| !x.is_finite()) {
                bail!("checkpoint {ci}: non-finite validation feature {} at index {j}", m.data[j]);
            }
        }
        Ok(())
    }
}

/// One answered query: the full per-sample score vector (shared, so cache
/// hits are pointer clones) plus provenance — the generation it was
/// computed against, whether it came from the score cache and, if not,
/// the fused pass that produced it.
#[derive(Debug, Clone)]
pub struct Answer {
    /// Influence score of every training sample, in sample order, over
    /// the full live row space of [`Answer::generation`].
    pub scores: Arc<Vec<f32>>,
    /// Manifest generation of the store state that produced this answer.
    pub generation: u64,
    /// `(generation, first global row)` of every store member at answer
    /// time — the map a `since_gen` filter resolves rows against.
    pub gen_rows: Arc<Vec<(u64, usize)>>,
    /// True when served from the score cache without any scan.
    pub cached: bool,
    /// Distinct tasks fused into the producing pass (0 on a cache hit).
    pub batched: usize,
    /// I/O accounting of the producing pass (zeroed on a cache hit). All
    /// answers of one micro-batch's pass share it, which is how the e2e
    /// test asserts a burst of Q queries cost one datastore traversal —
    /// and how a post-ingest extension proves it only read the new rows.
    pub pass: ScanStats,
}

impl Answer {
    /// First scored row strictly newer than `generation`, resolved
    /// against the member map of the exact store state that produced this
    /// answer (race-free across concurrent ingests); `scores.len()` when
    /// nothing is newer. The wire `since_gen` filter — "rank only rows
    /// newer than generation G" — is `top_k_scored_since` from here.
    pub fn first_row_after(&self, generation: u64) -> usize {
        self.gen_rows
            .iter()
            .filter(|(g, _)| *g > generation)
            .map(|(_, row)| *row)
            .min()
            .unwrap_or(self.scores.len())
    }
}

/// A warm, long-lived handle over one live datastore (see the module
/// docs).
pub struct Session {
    live: LiveStore,
    etas: Vec<f32>,
    rows_per_shard: usize,
    /// Pinned shards keyed by (member index, checkpoint, shard index) —
    /// member-scoped, so an ingest invalidates nothing below the old row
    /// count.
    shard_cache: LruCache<(usize, usize, usize), Arc<OwnedShard>>,
    /// Full score vectors keyed by task digest; an entry's *length* is
    /// the row count it covers (always a generation boundary).
    score_cache: LruCache<u64, Arc<Vec<f32>>>,
    gen_rows: Arc<Vec<(u64, usize)>>,
    stats: ServiceStats,
}

impl Session {
    /// Open and validate the datastore at `path` — plus every ingested
    /// segment its directory's manifest lists — read every checkpoint's η
    /// once, and size the caches from `opts`. After this, a fully-warm
    /// query touches no file I/O at all.
    pub fn open(path: &Path, opts: SessionOpts) -> Result<Session> {
        let live = LiveStore::open(path)
            .with_context(|| format!("opening served datastore {path:?}"))?;
        let etas = live.etas().to_vec();
        let rows_per_shard = live.rows_per_shard(opts.shard_rows, opts.mem_budget_mb.max(1));
        let cache_budget = opts.mem_budget_mb.max(1) << 20;
        let gen_rows = Arc::new(member_map(&live));
        info!(
            "session: {} rows × k={} × {} checkpoints at {} (generation {}, {} member \
             file(s), {rows_per_shard} rows/shard, {} MiB shard cache, {} score-cache entries)",
            live.n_rows(),
            live.header().k,
            etas.len(),
            live.header().precision.label(),
            live.generation(),
            live.members().len(),
            opts.mem_budget_mb.max(1),
            opts.score_cache_entries,
        );
        Ok(Session {
            live,
            etas,
            rows_per_shard,
            shard_cache: LruCache::new(cache_budget),
            score_cache: LruCache::new(opts.score_cache_entries),
            gen_rows,
            stats: ServiceStats::default(),
        })
    }

    /// The served store's header (geometry + precision). `n_samples` is
    /// the **base** store's row count; [`Session::n_rows`] is the live
    /// total.
    pub fn header(&self) -> &Header {
        self.live.header()
    }

    /// The manifest generation currently served (0 = frozen base store).
    /// Bumped in place when [`Session::answer_batch`] detects an ingest;
    /// responses echo it so clients can track the row space they scored
    /// against.
    pub fn generation(&self) -> u64 {
        self.live.generation()
    }

    /// Total rows currently served (base + every attached segment).
    pub fn n_rows(&self) -> usize {
        self.live.n_rows()
    }

    /// `(generation, first global row)` per store member, for resolving
    /// generation filters (shared snapshot; rebuilt on reload).
    pub fn gen_rows(&self) -> Arc<Vec<(u64, usize)>> {
        Arc::clone(&self.gen_rows)
    }

    /// Rows per streamed/cached shard, resolved from the session's opts.
    pub fn rows_per_shard(&self) -> usize {
        self.rows_per_shard
    }

    /// Cumulative session accounting (the `stats` op's payload).
    pub fn stats(&self) -> ServiceStats {
        let mut s = self.stats;
        s.shard_cache_bytes = self.shard_cache.weight() as u64;
        s
    }

    /// Poll the generation manifest and attach any newly ingested
    /// segments in place. Errors are downgraded to a warning — the
    /// session keeps serving the generation it has (a torn ingest must
    /// not take queries down with it).
    fn poll_generation(&mut self) {
        match self.live.refresh() {
            Ok(true) => {
                self.stats.reloads += 1;
                self.gen_rows = Arc::new(member_map(&self.live));
                info!(
                    "session: picked up generation {} ({} rows, {} members) without restart",
                    self.live.generation(),
                    self.live.n_rows(),
                    self.live.members().len()
                );
            }
            Ok(false) => {}
            Err(e) => warn_!(
                "session: manifest refresh failed ({e:#}); still serving generation {}",
                self.live.generation()
            ),
        }
    }

    /// Answer one micro-batch of (already validated) queries: score-cache
    /// hits are answered instantly, identical queries within the batch are
    /// deduplicated, and every remaining distinct task rides **one** fused
    /// pass over the store — a full pass for cold tasks, and a tail pass
    /// over only the newly ingested rows for tasks whose pre-ingest
    /// answer is still cached. Returns one [`Answer`] per query, in
    /// order. A bumped generation is picked up here, before the batch
    /// scans, so in-flight passes always finish against one generation.
    pub fn answer_batch(&mut self, queries: &[ScoreQuery]) -> Result<Vec<Answer>> {
        self.poll_generation();
        self.stats.batches += 1;
        self.stats.queries += queries.len() as u64;
        let n = self.live.n_rows();
        let generation = self.live.generation();
        let digests: Vec<u64> = queries.iter().map(|q| q.digest()).collect();
        let mut answers: Vec<Option<Answer>> = vec![None; queries.len()];
        // distinct uncached digests, in arrival order (batch sizes are
        // small — max_batch_tasks — so linear dedup beats a map here);
        // `partials` carries the cached pre-ingest prefix to extend
        let mut misses: Vec<u64> = Vec::new();
        let mut partials: Vec<(u64, Arc<Vec<f32>>)> = Vec::new();
        for (i, d) in digests.iter().enumerate() {
            if let Some(scores) = self.score_cache.get(d) {
                if scores.len() == n {
                    self.stats.score_cache_hits += 1;
                    answers[i] = Some(Answer {
                        scores,
                        generation,
                        gen_rows: Arc::clone(&self.gen_rows),
                        cached: true,
                        batched: 0,
                        pass: ScanStats::default(),
                    });
                    continue;
                }
                // a shorter vector is a pre-ingest prefix: extend it with
                // a tail scan if it ends exactly at a generation boundary
                if self.live.is_generation_boundary(scores.len()) {
                    if !partials.iter().any(|(pd, _)| pd == d) {
                        partials.push((*d, scores));
                    }
                    continue;
                }
            }
            if !misses.contains(d) {
                misses.push(*d);
            }
        }
        let rep = |d: &u64| -> usize {
            digests.iter().position(|x| x == d).expect("digest from this batch")
        };
        if !misses.is_empty() {
            let tasks: Vec<&[FeatureMatrix]> =
                misses.iter().map(|d| queries[rep(d)].val.as_slice()).collect();
            let (totals, pass) = self.scan_fused(&tasks, 0)?;
            let shared: Vec<Arc<Vec<f32>>> = totals.into_iter().map(Arc::new).collect();
            for (d, scores) in misses.iter().zip(&shared) {
                self.score_cache.insert(*d, Arc::clone(scores), 1);
            }
            for (i, d) in digests.iter().enumerate() {
                if answers[i].is_none() {
                    if let Some(t) = misses.iter().position(|x| x == d) {
                        answers[i] = Some(Answer {
                            scores: Arc::clone(&shared[t]),
                            generation,
                            gen_rows: Arc::clone(&self.gen_rows),
                            cached: false,
                            batched: misses.len(),
                            pass,
                        });
                    }
                }
            }
        }
        if !partials.is_empty() {
            let tail_start =
                partials.iter().map(|(_, s)| s.len()).min().expect("partials non-empty");
            let tasks: Vec<&[FeatureMatrix]> =
                partials.iter().map(|(d, _)| queries[rep(d)].val.as_slice()).collect();
            let (tails, pass) = self.scan_fused(&tasks, tail_start)?;
            let batched = partials.len();
            for ((d, prefix), tail) in partials.iter().zip(&tails) {
                let mut full = Vec::with_capacity(n);
                full.extend_from_slice(prefix);
                full.extend_from_slice(&tail[prefix.len() - tail_start..]);
                let shared = Arc::new(full);
                self.score_cache.insert(*d, Arc::clone(&shared), 1);
                self.stats.score_cache_extends += 1;
                for (i, di) in digests.iter().enumerate() {
                    if answers[i].is_none() && di == d {
                        answers[i] = Some(Answer {
                            scores: Arc::clone(&shared),
                            generation,
                            gen_rows: Arc::clone(&self.gen_rows),
                            cached: false,
                            batched,
                            pass,
                        });
                    }
                }
            }
        }
        Ok(answers.into_iter().map(|a| a.expect("every query answered")).collect())
    }

    /// Answer one micro-batch of (already validated) queries over the
    /// global row range `start .. start + len` **only** — the worker half
    /// of scatter-gather serving ([`super::coordinator`]). Identical
    /// queries within the batch are deduplicated into one fused ranged
    /// pass; shards overlapping the range are served from the same pinned
    /// shard cache as full scans (whole shards are cached, so a worker
    /// re-assigned a neighbouring range after a peer failure reuses
    /// everything it already has), and each fed shard is clipped to the
    /// range intersection with a zero-copy
    /// [`crate::datastore::RowsView::slice`], so the pass reads and scores
    /// exactly `len` rows per checkpoint.
    ///
    /// Returned answers are range-local: `scores[j]` is global row
    /// `start + j`, and `scores.len() == len`. The full-vector score
    /// cache is bypassed (`cached` is always false) — merged-answer
    /// caching is the coordinator's job, at its own layer.
    pub fn answer_range(
        &mut self,
        queries: &[ScoreQuery],
        start: usize,
        len: usize,
    ) -> Result<Vec<Answer>> {
        self.poll_generation();
        self.stats.batches += 1;
        self.stats.queries += queries.len() as u64;
        let n = self.live.n_rows();
        anyhow::ensure!(len > 0, "empty row range");
        let end = start
            .checked_add(len)
            .filter(|e| *e <= n)
            .with_context(|| format!("row range {start}+{len} exceeds live rows {n}"))?;
        debug_assert!(end <= n);
        let generation = self.live.generation();
        let digests: Vec<u64> = queries.iter().map(|q| q.digest()).collect();
        let mut distinct: Vec<u64> = Vec::new();
        for d in &digests {
            if !distinct.contains(d) {
                distinct.push(*d);
            }
        }
        let tasks: Vec<&[FeatureMatrix]> = distinct
            .iter()
            .map(|d| {
                let i = digests.iter().position(|x| x == d).expect("digest from this batch");
                queries[i].val.as_slice()
            })
            .collect();
        let (totals, pass) = self.scan_range(&tasks, start, len)?;
        let shared: Vec<Arc<Vec<f32>>> = totals.into_iter().map(Arc::new).collect();
        let batched = distinct.len();
        Ok(digests
            .iter()
            .map(|d| {
                let t = distinct.iter().position(|x| x == d).expect("distinct covers digests");
                Answer {
                    scores: Arc::clone(&shared[t]),
                    generation,
                    gen_rows: Arc::clone(&self.gen_rows),
                    cached: false,
                    batched,
                    pass,
                }
            })
            .collect())
    }

    /// One fused multi-task pass over the live rows `from_row ..
    /// n_rows()` (`from_row` must be a generation boundary; 0 = the whole
    /// store). The range degenerates to whole shards here, so this is the
    /// clip-free fast path the full-store and tail-extension scans ride.
    fn scan_fused(
        &mut self,
        tasks: &[&[FeatureMatrix]],
        from_row: usize,
    ) -> Result<(Vec<Vec<f32>>, ScanStats)> {
        debug_assert!(self.live.is_generation_boundary(from_row));
        let n = self.live.n_rows();
        self.scan_range(tasks, from_row, n - from_row)
    }

    /// One fused multi-task pass over the global rows `start .. start +
    /// len`, preferring pinned shards: cache hits feed the scan straight
    /// from RAM; misses are read with a seek-based
    /// [`crate::datastore::ShardReader`], fed, and pinned for the next
    /// pass (LRU-evicted under the byte budget). Members outside the
    /// range are skipped entirely, and within an overlapping member only
    /// the shards intersecting the range are touched; a shard straddling
    /// a range edge is fed through a clipped
    /// [`crate::datastore::RowsView::slice`] (the cache still pins the
    /// whole shard, so neighbouring ranges share it). Stats therefore
    /// count exactly the rows inside the range.
    fn scan_range(
        &mut self,
        tasks: &[&[FeatureMatrix]],
        start: usize,
        len: usize,
    ) -> Result<(Vec<Vec<f32>>, ScanStats)> {
        let end = start + len;
        let mut scan = MultiScan::try_new_range(self.live.header(), tasks, start, len)?;
        for ci in 0..self.etas.len() {
            let eta = self.etas[ci];
            for (mi, member) in self.live.members().iter().enumerate() {
                let m_rows = member.ds.n_samples();
                let m_lo = member.start_row;
                if m_lo + m_rows <= start || m_lo >= end {
                    continue;
                }
                // shard indices of this member intersecting [start, end)
                let lo_local = start.saturating_sub(m_lo);
                let hi_local = (end - m_lo).min(m_rows);
                let si_lo = lo_local / self.rows_per_shard;
                let si_hi = hi_local.div_ceil(self.rows_per_shard);
                let mut reader = None;
                for si in si_lo..si_hi {
                    let key = (mi, ci, si);
                    let owned = if let Some(shard) = self.shard_cache.get(&key) {
                        self.stats.shard_cache_hits += 1;
                        shard
                    } else {
                        if reader.is_none() {
                            reader = Some(member.ds.shard_reader(ci, self.rows_per_shard)?);
                        }
                        let r = reader.as_mut().expect("reader just opened");
                        r.seek_to_row(si * self.rows_per_shard);
                        let shard = r.next_shard()?.with_context(|| {
                            format!("shard {si} of checkpoint {ci} (member {mi}) out of range")
                        })?;
                        let owned = Arc::new(shard.to_owned_shard());
                        self.stats.disk_shard_reads += 1;
                        let weight = owned.byte_weight();
                        self.shard_cache.insert(key, Arc::clone(&owned), weight);
                        owned
                    };
                    let view = owned.rows();
                    let s_lo = m_lo + owned.start;
                    let a = start.max(s_lo) - s_lo;
                    let b = (end.min(s_lo + view.n())) - s_lo;
                    scan.feed(ci, eta, s_lo + a, &view.slice(a, b));
                }
            }
        }
        self.stats.fused_passes += 1;
        let (totals, pass) = scan.finish();
        self.stats.rows_scored += pass.rows_read;
        Ok((totals, pass))
    }
}

/// The `(generation, start_row)` member map shared with answers.
fn member_map(live: &LiveStore) -> Vec<(u64, usize)> {
    live.members().iter().map(|m| (m.generation, m.start_row)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::{default_store_path, SegmentWriter};
    use crate::influence::{score_datastore_tasks, ScoreOpts};
    use crate::quant::{Precision, Scheme};
    use crate::util::prop::{normal_features as feats, seeded_datastore};
    use std::path::PathBuf;

    fn build_store(bits: u8, n: usize, k: usize, etas: &[f32], tag: &str) -> PathBuf {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = std::env::temp_dir().join(format!(
            "qless_sess_{tag}_{bits}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ));
        seeded_datastore(&path, p, n, k, etas, 0);
        path
    }

    fn task(k: usize, seed: u64, ckpts: usize) -> Vec<FeatureMatrix> {
        (0..ckpts).map(|ci| feats(3, k, seed + ci as u64)).collect()
    }

    #[test]
    fn session_scores_match_batch_pipeline_exactly() {
        let (n, k) = (23usize, 64usize);
        let path = build_store(4, n, k, &[0.7, 0.3], "exact");
        let ds = crate::datastore::Datastore::open(&path).unwrap();
        let t0 = task(k, 100, 2);
        let t1 = task(k, 200, 2);
        let (want, _) = score_datastore_tasks(
            &ds,
            &[&t0, &t1],
            ScoreOpts { shard_rows: 5, ..Default::default() },
            None,
        )
        .unwrap();
        let opts = SessionOpts { shard_rows: 5, mem_budget_mb: 4, score_cache_entries: 8 };
        let mut sess = Session::open(&path, opts).unwrap();
        assert_eq!(sess.rows_per_shard(), 5);
        assert_eq!(sess.generation(), 0, "frozen store serves generation 0");
        assert_eq!(sess.n_rows(), n);
        let queries = vec![ScoreQuery { val: t0.clone() }, ScoreQuery { val: t1.clone() }];
        for q in &queries {
            q.validate(sess.header()).unwrap();
        }
        let answers = sess.answer_batch(&queries).unwrap();
        assert_eq!(answers.len(), 2);
        for (t, a) in answers.iter().enumerate() {
            assert!(!a.cached);
            assert_eq!(a.batched, 2, "both tasks fused into one pass");
            assert_eq!(a.pass.tasks, 2);
            assert_eq!(a.generation, 0);
            assert_eq!(*a.scores, want[t], "task {t}: served vs pipeline scores");
        }
        // both answers share one pass: shard traffic of a single scan
        assert_eq!(answers[0].pass, answers[1].pass);
        assert_eq!(answers[0].pass.shards_read, 2 * n.div_ceil(5));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn warm_queries_skip_disk_and_identical_queries_skip_scans() {
        let (n, k) = (16usize, 64usize);
        let path = build_store(8, n, k, &[1.0], "warm");
        let opts = SessionOpts { shard_rows: 4, mem_budget_mb: 16, score_cache_entries: 4 };
        let mut sess = Session::open(&path, opts).unwrap();
        let q0 = ScoreQuery { val: task(k, 300, 1) };
        let a0 = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        let cold = sess.stats();
        assert_eq!(cold.disk_shard_reads, 4, "cold pass reads every shard");
        assert_eq!(cold.fused_passes, 1);
        // identical query: score cache answers without any scan
        let a1 = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        assert!(a1[0].cached);
        assert_eq!(a1[0].scores, a0[0].scores);
        let s1 = sess.stats();
        assert_eq!(s1.score_cache_hits, 1);
        assert_eq!(s1.fused_passes, 1, "no new pass");
        assert_eq!(s1.disk_shard_reads, cold.disk_shard_reads);
        // different task, warm shard cache: a scan, but zero disk reads
        let q1 = ScoreQuery { val: task(k, 301, 1) };
        let a2 = sess.answer_batch(std::slice::from_ref(&q1)).unwrap();
        assert!(!a2[0].cached);
        let s2 = sess.stats();
        assert_eq!(s2.fused_passes, 2);
        assert_eq!(s2.disk_shard_reads, cold.disk_shard_reads, "warm scan is RAM-only");
        assert_eq!(s2.shard_cache_hits, 4);
        assert!(s2.shard_cache_bytes > 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn batch_dedup_fuses_identical_queries_into_one_task() {
        let (n, k) = (12usize, 64usize);
        let path = build_store(2, n, k, &[0.5], "dedup");
        let mut sess = Session::open(
            &path,
            SessionOpts { shard_rows: 0, mem_budget_mb: 8, score_cache_entries: 0 },
        )
        .unwrap();
        let a = ScoreQuery { val: task(k, 400, 1) };
        let b = ScoreQuery { val: task(k, 401, 1) };
        let batch = vec![a.clone(), b.clone(), a.clone(), a.clone()];
        let answers = sess.answer_batch(&batch).unwrap();
        for ans in &answers {
            assert_eq!(ans.batched, 2, "4 queries, 2 distinct tasks");
            assert_eq!(ans.pass.tasks, 2);
        }
        assert_eq!(answers[0].scores, answers[2].scores);
        assert_eq!(answers[0].scores, answers[3].scores);
        assert_ne!(answers[0].scores, answers[1].scores);
        // score cache disabled: the same batch rescans, same results
        let again = sess.answer_batch(&batch).unwrap();
        assert_eq!(again[0].scores, answers[0].scores);
        assert!(!again[0].cached);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn ranged_answers_match_full_scan_slices_bit_exactly() {
        // The scatter-gather worker contract: scores for rows
        // `start..start+len` must equal the same slice of a full-store
        // scan, bit for bit, for ranges that straddle shard boundaries
        // (shards are 5 rows here, ranges deliberately are not).
        let (n, k) = (23usize, 64usize);
        let path = build_store(4, n, k, &[0.7, 0.3], "range");
        let opts = SessionOpts { shard_rows: 5, mem_budget_mb: 4, score_cache_entries: 8 };
        let mut sess = Session::open(&path, opts).unwrap();
        let q = ScoreQuery { val: task(k, 700, 2) };
        let full = sess.answer_batch(std::slice::from_ref(&q)).unwrap();
        for (start, len) in [(0usize, n), (0, 7), (3, 9), (7, 11), (20, 3), (22, 1)] {
            let part = sess.answer_range(std::slice::from_ref(&q), start, len).unwrap();
            assert!(!part[0].cached, "ranged answers bypass the score cache");
            assert_eq!(part[0].scores.len(), len);
            assert_eq!(
                part[0].scores[..],
                full[0].scores[start..start + len],
                "range {start}+{len} vs full-scan slice"
            );
            assert_eq!(
                part[0].pass.rows_read,
                (2 * len) as u64,
                "range {start}+{len} must score only its own rows"
            );
        }
        // batch dedup still applies on the ranged path
        let pair = vec![q.clone(), q.clone()];
        let both = sess.answer_range(&pair, 3, 9).unwrap();
        assert_eq!(both[0].batched, 1, "identical ranged queries fuse");
        assert_eq!(both[0].scores, both[1].scores);
        // malformed ranges fail cleanly
        assert!(sess.answer_range(std::slice::from_ref(&q), 0, 0).is_err());
        assert!(sess.answer_range(std::slice::from_ref(&q), 20, 4).is_err());
        assert!(sess.answer_range(std::slice::from_ref(&q), usize::MAX, 2).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn validate_rejects_malformed_queries() {
        let (n, k) = (8usize, 64usize);
        let path = build_store(8, n, k, &[1.0, 1.0], "val");
        let sess = Session::open(&path, SessionOpts::default()).unwrap();
        let h = *sess.header();
        // wrong checkpoint count
        assert!(ScoreQuery { val: task(k, 1, 1) }.validate(&h).is_err());
        // wrong k
        assert!(ScoreQuery { val: task(32, 1, 2) }.validate(&h).is_err());
        // empty matrix
        let empty = vec![
            FeatureMatrix { n: 0, k, data: vec![] },
            FeatureMatrix { n: 0, k, data: vec![] },
        ];
        assert!(ScoreQuery { val: empty }.validate(&h).is_err());
        // flat-length mismatch
        let mut bad = task(k, 1, 2);
        bad[0].data.pop();
        assert!(ScoreQuery { val: bad }.validate(&h).is_err());
        // n·k that wraps to 0 in release builds: checked_mul must reject,
        // or a hostile wire request drives an n-sized allocation
        let huge = vec![
            FeatureMatrix { n: usize::MAX / 2 + 1, k, data: vec![] },
            FeatureMatrix { n: usize::MAX / 2 + 1, k, data: vec![] },
        ];
        assert!(ScoreQuery { val: huge }.validate(&h).is_err());
        // non-finite
        let mut nan = task(k, 1, 2);
        nan[1].data[5] = f32::NAN;
        let err = ScoreQuery { val: nan }.validate(&h).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
        // a good one passes
        ScoreQuery { val: task(k, 1, 2) }.validate(&h).unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn ingest_reload_extends_cached_scores_with_a_tail_scan() {
        // The generation-aware acceptance test at the session level: an
        // ingest mid-session is picked up without reopening, a cached
        // answer is extended by scanning ONLY the new rows, warm base
        // shards stay pinned, and everything matches a monolithic store
        // holding the same rows.
        let (n0, add, k) = (12usize, 6usize, 64usize);
        let etas = [0.7f32, 0.3];
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "qless_sess_reload_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let base = default_store_path(&dir, p);
        // normal_features draws sequentially, so the monolithic fixture's
        // first n0 rows equal the base store's rows exactly
        seeded_datastore(&base, p, n0, k, &etas, 0);
        let mono_path = dir.join("mono.qlds");
        let mono = seeded_datastore(&mono_path, p, n0 + add, k, &etas, 0);

        let opts = SessionOpts { shard_rows: 4, mem_budget_mb: 16, score_cache_entries: 8 };
        let mut sess = Session::open(&base, opts).unwrap();
        let q0 = ScoreQuery { val: task(k, 500, 2) };
        let before = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        assert_eq!(before[0].scores.len(), n0);
        assert_eq!(before[0].generation, 0);
        let base_digest = std::fs::read(&base).unwrap();
        let cold = sess.stats();

        // ingest `add` rows (the monolithic fixture's tail) mid-session
        let mut sw = SegmentWriter::create(&dir, &[p], add, 0).unwrap();
        for ci in 0..etas.len() {
            sw.begin_checkpoint().unwrap();
            sw.append_rows(&feats(n0 + add, k, ci as u64).data[n0 * k..]).unwrap();
            sw.end_checkpoint().unwrap();
        }
        sw.finalize().unwrap();
        assert_eq!(std::fs::read(&base).unwrap(), base_digest, "ingest never touches the base");

        // repeat query: picked up live, extended by a tail-only pass
        let after = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        assert_eq!(after[0].generation, 1);
        assert_eq!(after[0].scores.len(), n0 + add);
        assert_eq!(after[0].scores[..n0], before[0].scores[..], "prefix reused verbatim");
        assert!(!after[0].cached);
        assert_eq!(
            after[0].pass.rows_read,
            (etas.len() * add) as u64,
            "extension must scan only the ingested rows"
        );
        assert_eq!(*after[0].gen_rows, vec![(0u64, 0usize), (1u64, n0)]);
        let s = sess.stats();
        assert_eq!(s.reloads, 1);
        assert_eq!(s.score_cache_extends, 1);
        assert_eq!(
            s.disk_shard_reads - cold.disk_shard_reads,
            (etas.len() * add.div_ceil(4)) as u64,
            "only segment shards hit disk; warm base shards stay pinned"
        );

        // served values equal a full scan of the monolithic store
        let (want, _) = score_datastore_tasks(
            &mono,
            &[q0.val.as_slice()],
            ScoreOpts { shard_rows: 4, ..Default::default() },
            None,
        )
        .unwrap();
        assert_eq!(*after[0].scores, want[0], "extended scores vs monolithic scan");

        // a brand-new task after the reload scans the full live store
        let q1 = ScoreQuery { val: task(k, 600, 2) };
        let fresh = sess.answer_batch(std::slice::from_ref(&q1)).unwrap();
        assert_eq!(fresh[0].scores.len(), n0 + add);
        assert_eq!(fresh[0].pass.rows_read, (etas.len() * (n0 + add)) as u64);
        // and an exact repeat is a plain cache hit at the new generation
        let hit = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        assert!(hit[0].cached);
        assert_eq!(hit[0].generation, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
