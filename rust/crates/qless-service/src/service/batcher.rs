//! Micro-batching admission queue: concurrent score queries land in a
//! bounded queue; a single scoring worker (which owns the [`Session`])
//! coalesces everything that arrives within a configurable window into
//! **one** fused pass over the datastore.
//!
//! The window starts when the worker sees the first pending query and
//! closes after `window` elapses or `max_batch` queries are waiting,
//! whichever comes first — so an idle service answers a lone query with at
//! most `window` of added latency, while a burst of Q queries costs one
//! datastore traversal instead of Q. A window of zero disables the wait
//! (each batch is whatever queued while the previous one scored, so bursts
//! still coalesce under load).
//!
//! One worker thread is deliberate: the fused scan already row-parallelizes
//! on the crate's scan pool (`util::pool`), so a second concurrent scan
//! would fight it for the same cores; serializing scans and batching
//! admission is the throughput-optimal shape for this workload.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::session::{Answer, CascadePlan, ScoreQuery, ServiceStats, Session};
use crate::util::obs;

/// Outcome delivered to one submitted query: the answer, or the failure
/// message of the batch it rode (stringly so it can be broadcast to every
/// rider of a failed batch).
pub type BatchResult = std::result::Result<Answer, String>;

/// Point-in-time view of the scoring worker's session, published after
/// every batch: cumulative stats plus the live store's identity. The
/// worker owns the session, so readers (the `stats` wire op, the server's
/// accessors) see a lock-free-on-the-hot-path snapshot that is exact as
/// of the most recently scored batch — including any generation the
/// worker picked up from an ingest.
#[derive(Debug, Clone, Copy)]
pub struct SessionView {
    /// Cumulative service accounting.
    pub stats: ServiceStats,
    /// Manifest generation the session served its last batch against.
    pub generation: u64,
    /// Total rows served at that generation (base + ingested segments).
    pub rows: u64,
}

/// Tuning of the admission queue.
#[derive(Debug, Clone, Copy)]
pub struct BatcherOpts {
    /// How long the worker holds the window open after the first pending
    /// query, waiting for more to coalesce.
    pub window: Duration,
    /// Most queries fused into one batch (floored at 1).
    pub max_batch: usize,
    /// Most queries waiting in the queue before submissions are rejected
    /// (backpressure; floored at 1).
    pub queue_cap: usize,
}

impl Default for BatcherOpts {
    fn default() -> BatcherOpts {
        BatcherOpts { window: Duration::from_millis(2), max_batch: 16, queue_cap: 256 }
    }
}

/// The fuse key of a queued job: only jobs with **equal** keys coalesce,
/// so a batch always maps onto exactly one session call — one fused
/// pass (full, ranged, or cascade) over the store. A coordinator fans
/// one logical query out as N identical per-worker keys, so in practice
/// a worker's queue is homogeneous and still fuses fully.
#[derive(Debug, Clone, PartialEq, Eq)]
enum PassKey {
    /// Exhaustive scan over the full live row space.
    Full,
    /// Exhaustive scan over one global row range (scatter-gather worker).
    Range { start: usize, len: usize },
    /// Two-stage precision cascade (client verb).
    Cascade { plan: CascadePlan, top_k: usize },
    /// Cascade probe stage over one row range at `bits` (worker verb).
    Probe { start: usize, len: usize, bits: u8 },
    /// Cascade rerank of exactly `rows` at `bits` (worker verb). The row
    /// list is shared, not cloned per job — fan-in replies reuse it.
    Rerank { rows: Arc<Vec<usize>>, bits: u8 },
    /// IVF-indexed scan: top-`nprobe` clusters per task, optionally
    /// restricted to a window of cluster-list positions (worker verb of a
    /// cluster-partitioned scatter).
    Index { nprobe: usize, top_k: usize, clusters: Option<(usize, usize)> },
    /// Index-restricted cascade: the 1-bit probe scan runs only inside
    /// the `nprobe` probed clusters; the exact rerank is unchanged.
    IndexCascade { plan: CascadePlan, top_k: usize, nprobe: usize },
}

struct Job {
    query: ScoreQuery,
    key: PassKey,
    reply: mpsc::Sender<BatchResult>,
}

struct QState {
    queue: VecDeque<Job>,
    open: bool,
}

struct Shared {
    state: Mutex<QState>,
    arrived: Condvar,
}

/// The admission queue plus its scoring worker (see the module docs).
/// Dropping (or [`Batcher::close`]-ing) stops admissions, drains queued
/// queries, and joins the worker.
pub struct Batcher {
    shared: Arc<Shared>,
    snapshot: Arc<Mutex<SessionView>>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
    queue_cap: usize,
}

/// The view the worker publishes for `session` in its current state.
fn view_of(session: &Session) -> SessionView {
    SessionView {
        stats: session.stats(),
        generation: session.generation(),
        rows: session.n_rows() as u64,
    }
}

impl Batcher {
    /// Move `session` into a new scoring worker and open the queue.
    pub fn new(session: Session, opts: BatcherOpts) -> Batcher {
        let shared = Arc::new(Shared {
            state: Mutex::new(QState { queue: VecDeque::new(), open: true }),
            arrived: Condvar::new(),
        });
        let snapshot = Arc::new(Mutex::new(view_of(&session)));
        let queue_cap = opts.queue_cap.max(1);
        let worker = std::thread::Builder::new()
            .name("qless-batcher".into())
            .spawn({
                let shared = Arc::clone(&shared);
                let snapshot = Arc::clone(&snapshot);
                move || worker_loop(shared, session, opts, snapshot)
            })
            .expect("spawning batcher worker");
        Batcher { shared, snapshot, worker: Mutex::new(Some(worker)), queue_cap }
    }

    /// Enqueue one (already validated) query over the full live row
    /// space. Returns the channel its [`BatchResult`] will arrive on, or
    /// an error when the queue is full or the service is shutting down.
    pub fn submit(&self, query: ScoreQuery) -> Result<mpsc::Receiver<BatchResult>> {
        self.submit_keyed(query, PassKey::Full)
    }

    /// [`Batcher::submit`] restricted to the global row range `[start,
    /// start + len)` when `rows` is `Some` — the scatter-gather worker
    /// path. Ranged jobs coalesce only with jobs carrying the **same**
    /// range.
    pub fn submit_ranged(
        &self,
        query: ScoreQuery,
        rows: Option<(usize, usize)>,
    ) -> Result<mpsc::Receiver<BatchResult>> {
        let key = match rows {
            None => PassKey::Full,
            Some((start, len)) => PassKey::Range { start, len },
        };
        self.submit_keyed(query, key)
    }

    /// Enqueue one cascade query ([`Session::answer_cascade`]): queries
    /// sharing the same `(plan, top_k)` coalesce, so a burst rides one
    /// probe pass and one rerank pass over the candidate union.
    pub fn submit_cascade(
        &self,
        query: ScoreQuery,
        plan: CascadePlan,
        top_k: usize,
    ) -> Result<mpsc::Receiver<BatchResult>> {
        self.submit_keyed(query, PassKey::Cascade { plan, top_k })
    }

    /// Enqueue one cascade **probe** worker sub-query: a ranged scan at
    /// the probe precision ([`Session::answer_range_at`]).
    pub fn submit_probe(
        &self,
        query: ScoreQuery,
        start: usize,
        len: usize,
        bits: u8,
    ) -> Result<mpsc::Receiver<BatchResult>> {
        self.submit_keyed(query, PassKey::Probe { start, len, bits })
    }

    /// Enqueue one cascade **rerank** worker sub-query: re-score exactly
    /// `rows` at `bits` ([`Session::answer_rerank_rows`]).
    pub fn submit_rerank(
        &self,
        query: ScoreQuery,
        rows: Arc<Vec<usize>>,
        bits: u8,
    ) -> Result<mpsc::Receiver<BatchResult>> {
        self.submit_keyed(query, PassKey::Rerank { rows, bits })
    }

    /// Enqueue one IVF-indexed query ([`Session::answer_index`]): queries
    /// sharing the same `(nprobe, top_k, clusters)` coalesce, so a burst
    /// rides one centroid probe and one cluster scan.
    pub fn submit_index(
        &self,
        query: ScoreQuery,
        nprobe: usize,
        top_k: usize,
        clusters: Option<(usize, usize)>,
    ) -> Result<mpsc::Receiver<BatchResult>> {
        self.submit_keyed(query, PassKey::Index { nprobe, top_k, clusters })
    }

    /// Enqueue one index-restricted cascade query
    /// ([`Session::answer_index_cascade`]): queries sharing the same
    /// `(plan, top_k, nprobe)` coalesce.
    pub fn submit_index_cascade(
        &self,
        query: ScoreQuery,
        plan: CascadePlan,
        top_k: usize,
        nprobe: usize,
    ) -> Result<mpsc::Receiver<BatchResult>> {
        self.submit_keyed(query, PassKey::IndexCascade { plan, top_k, nprobe })
    }

    fn submit_keyed(
        &self,
        query: ScoreQuery,
        key: PassKey,
    ) -> Result<mpsc::Receiver<BatchResult>> {
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            if !st.open {
                bail!("service is shutting down");
            }
            if st.queue.len() >= self.queue_cap {
                obs::counter_add("batcher_rejects_total", 1);
                bail!("admission queue full ({} queries waiting)", self.queue_cap);
            }
            st.queue.push_back(Job { query, key, reply: tx });
            obs::gauge_set("batcher_queue_depth", st.queue.len() as i64);
        }
        self.shared.arrived.notify_all();
        Ok(rx)
    }

    /// The session's cumulative [`ServiceStats`], as of the end of the
    /// most recently scored batch (the worker owns the live session, so
    /// this is a snapshot, not a lock on the hot path).
    pub fn stats(&self) -> ServiceStats {
        self.view().stats
    }

    /// The full [`SessionView`] snapshot — stats plus the generation and
    /// live row total the worker last served.
    pub fn view(&self) -> SessionView {
        *self.snapshot.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Stop admissions, let the worker drain every queued query, and join
    /// it. Idempotent.
    pub fn close(&self) {
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.open = false;
        }
        self.shared.arrived.notify_all();
        if let Some(h) = self.worker.lock().unwrap_or_else(|e| e.into_inner()).take() {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.close();
    }
}

fn worker_loop(
    shared: Arc<Shared>,
    mut session: Session,
    opts: BatcherOpts,
    snapshot: Arc<Mutex<SessionView>>,
) {
    let max_batch = opts.max_batch.max(1);
    loop {
        let batch: Vec<Job> = {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            // wait for the first pending query (or shutdown + empty queue)
            loop {
                if !st.queue.is_empty() {
                    break;
                }
                if !st.open {
                    return;
                }
                st = shared.arrived.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            // hold the admission window open for stragglers
            let deadline = Instant::now() + opts.window;
            while st.open && st.queue.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _timeout) = shared
                    .arrived
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(|e| e.into_inner());
                st = guard;
            }
            // a batch is the longest front run sharing one fuse key, so
            // it maps onto exactly one fused pass; jobs with a different
            // key stay queued for the next iteration
            let want = st.queue.front().map(|j| j.key.clone()).expect("queue non-empty");
            let mut take = 0;
            while take < st.queue.len() && take < max_batch && st.queue[take].key == want {
                take += 1;
            }
            let batch: Vec<Job> = st.queue.drain(..take).collect();
            obs::gauge_set("batcher_queue_depth", st.queue.len() as i64);
            batch
        };
        // window occupancy: how many queries each fused pass amortizes —
        // the micro-batcher's whole reason to exist (mean occupancy =
        // batched_queries / batches)
        obs::counter_add("batcher_batches_total", 1);
        obs::counter_add("batcher_batched_queries_total", batch.len() as u64);
        let key = batch.first().map(|j| j.key.clone()).expect("batch non-empty");
        let (queries, repliers): (Vec<ScoreQuery>, Vec<mpsc::Sender<BatchResult>>) =
            batch.into_iter().map(|j| (j.query, j.reply)).unzip();
        // panic isolation: a scoring panic must not kill the only scoring
        // worker (queued + future queries would hang forever, wedging the
        // whole server) — it becomes an error broadcast to this batch's
        // riders, and the worker lives on
        let pass_span = obs::span("batcher.pass");
        let result = catch_unwind(AssertUnwindSafe(|| match &key {
            PassKey::Full => session.answer_batch(&queries),
            PassKey::Range { start, len } => session.answer_range(&queries, *start, *len),
            PassKey::Cascade { plan, top_k } => {
                session.answer_cascade(&queries, *plan, *top_k)
            }
            PassKey::Probe { start, len, bits } => {
                session.answer_range_at(&queries, *start, *len, *bits)
            }
            PassKey::Rerank { rows, bits } => {
                session.answer_rerank_rows(&queries, rows, *bits)
            }
            PassKey::Index { nprobe, top_k, clusters } => {
                session.answer_index(&queries, *nprobe, *top_k, *clusters)
            }
            PassKey::IndexCascade { plan, top_k, nprobe } => {
                session.answer_index_cascade(&queries, *plan, *top_k, *nprobe)
            }
        }));
        drop(pass_span);
        // publish stats before replying, so a client that just got its
        // answer reads a snapshot that already includes its batch (and
        // any generation reload the batch picked up)
        *snapshot.lock().unwrap_or_else(|e| e.into_inner()) = view_of(&session);
        match result {
            Ok(Ok(answers)) => {
                for (tx, ans) in repliers.iter().zip(answers) {
                    let _ = tx.send(Ok(ans)); // receiver may have hung up
                }
            }
            Ok(Err(e)) => {
                let msg = format!("{e:#}");
                for tx in &repliers {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
            Err(payload) => {
                let what = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic".into());
                let msg = format!("scoring worker panicked: {what}");
                for tx in &repliers {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Precision, Scheme};
    use crate::service::session::SessionOpts;
    use crate::util::prop::{normal_features as feats, seeded_datastore};
    use std::path::PathBuf;

    fn build_store(tag: &str, n: usize, k: usize) -> PathBuf {
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let path = std::env::temp_dir().join(format!(
            "qless_batcher_{tag}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ));
        seeded_datastore(&path, p, n, k, &[1.0], 0);
        path
    }

    fn query(k: usize, seed: u64) -> ScoreQuery {
        ScoreQuery { val: vec![feats(2, k, seed)] }
    }

    #[test]
    fn wide_window_coalesces_a_burst_into_one_pass() {
        let path = build_store("coalesce", 16, 64);
        let session = Session::open(&path, SessionOpts::default()).unwrap();
        let batcher = Batcher::new(
            session,
            BatcherOpts { window: Duration::from_millis(300), max_batch: 16, queue_cap: 64 },
        );
        // all three submitted well inside the 300ms window
        let rxs: Vec<_> =
            (0..3).map(|i| batcher.submit(query(64, 100 + i)).unwrap()).collect();
        let answers: Vec<Answer> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        for a in &answers {
            assert!(!a.cached);
            assert_eq!(a.batched, 3, "burst must fuse into one pass");
            assert_eq!(a.pass.tasks, 3);
            assert_eq!(a.pass, answers[0].pass, "all riders share the pass");
        }
        let stats = batcher.stats();
        assert_eq!(stats.fused_passes, 1);
        assert_eq!(stats.queries, 3);
        assert_eq!(stats.batches, 1);
        batcher.close();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn max_batch_caps_a_batch() {
        let path = build_store("cap", 12, 64);
        let session = Session::open(&path, SessionOpts::default()).unwrap();
        let batcher = Batcher::new(
            session,
            BatcherOpts { window: Duration::from_millis(300), max_batch: 2, queue_cap: 64 },
        );
        let rxs: Vec<_> =
            (0..4).map(|i| batcher.submit(query(64, 200 + i)).unwrap()).collect();
        for rx in rxs {
            let a = rx.recv().unwrap().unwrap();
            assert!(a.batched <= 2, "batched {} > max_batch", a.batched);
        }
        assert!(batcher.stats().batches >= 2);
        batcher.close();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn mixed_ranges_split_into_homogeneous_batches() {
        let path = build_store("ranges", 16, 64);
        let session = Session::open(&path, SessionOpts::default()).unwrap();
        let batcher = Batcher::new(
            session,
            BatcherOpts { window: Duration::from_millis(300), max_batch: 16, queue_cap: 64 },
        );
        // one logical task, submitted full + as two half-ranges inside one
        // admission window: ranges must not fuse across boundaries
        let full = batcher.submit(query(64, 500)).unwrap();
        let lo = batcher.submit_ranged(query(64, 500), Some((0, 8))).unwrap();
        let hi = batcher.submit_ranged(query(64, 500), Some((8, 8))).unwrap();
        let a_full = full.recv().unwrap().unwrap();
        let a_lo = lo.recv().unwrap().unwrap();
        let a_hi = hi.recv().unwrap().unwrap();
        assert_eq!(a_full.scores.len(), 16);
        assert_eq!(a_lo.scores.len(), 8);
        assert_eq!(a_hi.scores.len(), 8);
        // stitched ranged answers equal the full answer bit-exactly
        assert_eq!(a_lo.scores[..], a_full.scores[..8]);
        assert_eq!(a_hi.scores[..], a_full.scores[8..]);
        assert_eq!(batcher.stats().batches, 3, "three distinct ranges, three passes");
        batcher.close();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cascade_jobs_fuse_by_plan_and_answer_with_top() {
        let dir = std::env::temp_dir().join(format!(
            "qless_batcher_casc_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        let probe_path = crate::datastore::default_store_path(&dir, p1);
        seeded_datastore(&probe_path, p1, 16, 64, &[1.0], 0);
        seeded_datastore(&crate::datastore::default_store_path(&dir, p8), p8, 16, 64, &[1.0], 0);
        let session = Session::open(&probe_path, SessionOpts::default()).unwrap();
        let batcher = Batcher::new(
            session,
            BatcherOpts { window: Duration::from_millis(300), max_batch: 16, queue_cap: 64 },
        );
        let plan = CascadePlan { probe: 1, rerank: 8, mult: 2 };
        let rxs: Vec<_> = (0..3)
            .map(|i| batcher.submit_cascade(query(64, 700 + i), plan, 2).unwrap())
            .collect();
        let answers: Vec<Answer> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        for a in &answers {
            assert_eq!(a.batched, 3, "same-plan cascade burst must fuse");
            assert!(a.scores.is_empty());
            assert_eq!(a.top.as_ref().unwrap().len(), 2);
        }
        let stats = batcher.stats();
        assert_eq!(stats.batches, 1, "one fused cascade batch");
        assert_eq!(stats.fused_passes, 2, "probe pass + rerank pass");
        batcher.close();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn index_jobs_fuse_by_key_and_answer_with_top() {
        use crate::datastore::{index_path, reindex_store, IndexBuildOpts};
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let path = std::env::temp_dir().join(format!(
            "qless_batcher_idx_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ));
        seeded_datastore(&path, p1, 32, 64, &[1.0], 0);
        reindex_store(&path, &IndexBuildOpts { n_clusters: 4, max_iters: 3 }).unwrap();
        let session = Session::open(&path, SessionOpts::default()).unwrap();
        let batcher = Batcher::new(
            session,
            BatcherOpts { window: Duration::from_millis(300), max_batch: 16, queue_cap: 64 },
        );
        let rxs: Vec<_> = (0..3)
            .map(|i| batcher.submit_index(query(64, 900 + i), 2, 3, None).unwrap())
            .collect();
        let answers: Vec<Answer> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        for a in &answers {
            assert_eq!(a.batched, 3, "same-key indexed burst must fuse");
            assert!(a.scores.is_empty(), "indexed answers carry top lists only");
            assert_eq!(a.top.as_ref().unwrap().len(), 3);
        }
        let stats = batcher.stats();
        assert_eq!(stats.batches, 1, "one fused indexed batch");
        assert_eq!(stats.index_queries, 3);
        assert_eq!(stats.index_fallbacks, 0);
        assert_eq!(stats.index_clusters, 4);
        batcher.close();
        std::fs::remove_file(index_path(&path)).ok();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn closed_batcher_rejects_and_drains() {
        let path = build_store("close", 8, 64);
        let session = Session::open(&path, SessionOpts::default()).unwrap();
        let batcher = Batcher::new(
            session,
            BatcherOpts { window: Duration::from_millis(50), max_batch: 8, queue_cap: 8 },
        );
        let rx = batcher.submit(query(64, 300)).unwrap();
        batcher.close(); // drains the pending query before joining
        assert!(rx.recv().unwrap().is_ok(), "queued query answered during drain");
        assert!(batcher.submit(query(64, 301)).is_err(), "closed queue rejects");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn batch_errors_are_broadcast() {
        let path = build_store("err", 8, 64);
        let session = Session::open(&path, SessionOpts::default()).unwrap();
        let batcher = Batcher::new(
            session,
            BatcherOpts { window: Duration::from_millis(100), max_batch: 8, queue_cap: 8 },
        );
        // wrong checkpoint count (the server normally validates before
        // submit; the batcher must still fail cleanly, not panic)
        let bad = ScoreQuery { val: vec![feats(2, 64, 1), feats(2, 64, 2)] };
        let rx = batcher.submit(bad).unwrap();
        let res = rx.recv().unwrap();
        let msg = res.unwrap_err();
        assert!(msg.contains("checkpoints"), "{msg}");
        batcher.close();
        std::fs::remove_file(path).ok();
    }
}
