//! Caches of the resident query service: a generic weight-budgeted LRU
//! (used byte-budgeted for pinned datastore shards and entry-budgeted for
//! score vectors) plus the task digest that keys the score cache.
//!
//! Both caches only ever hold `Arc`ed values, so a hit is a pointer clone —
//! eviction can never invalidate a score another query is still holding.

use std::collections::BTreeMap;

use crate::grads::FeatureMatrix;

/// A least-recently-used cache with a total *weight* budget.
///
/// Each entry carries a caller-supplied weight (bytes for shards, `1` for
/// score-cache entries); inserting evicts least-recently-used entries until
/// the total fits the budget again. The entry just inserted is never
/// evicted by its own insertion — a single entry heavier than the whole
/// budget stays resident (and alone) rather than thrashing. A budget of
/// `0` disables the cache entirely (inserts are dropped, gets always miss).
#[derive(Debug)]
pub struct LruCache<K: Ord + Clone, V: Clone> {
    map: BTreeMap<K, Entry<V>>,
    /// Recency index: logical tick → key. Ticks are unique, so the first
    /// entry is always the least recently used.
    recency: BTreeMap<u64, K>,
    tick: u64,
    weight: usize,
    budget: usize,
}

#[derive(Debug)]
struct Entry<V> {
    value: V,
    weight: usize,
    tick: u64,
}

impl<K: Ord + Clone, V: Clone> LruCache<K, V> {
    /// Create a cache holding at most `budget` total weight.
    pub fn new(budget: usize) -> LruCache<K, V> {
        LruCache { map: BTreeMap::new(), recency: BTreeMap::new(), tick: 0, weight: 0, budget }
    }

    /// Look up `key`, marking it most recently used on a hit.
    pub fn get(&mut self, key: &K) -> Option<V> {
        let (old_tick, value) = {
            let e = self.map.get_mut(key)?;
            self.tick += 1;
            let old = e.tick;
            e.tick = self.tick;
            (old, e.value.clone())
        };
        self.recency.remove(&old_tick);
        self.recency.insert(self.tick, key.clone());
        Some(value)
    }

    /// Insert (or replace) `key` with the given weight, then evict
    /// least-recently-used entries until the budget holds. Returns the
    /// total weight evicted (replaced entries excluded) so callers can
    /// feed eviction-bytes metrics without a second bookkeeping pass.
    pub fn insert(&mut self, key: K, value: V, weight: usize) -> usize {
        if self.budget == 0 {
            return 0; // caching disabled
        }
        if let Some(old) = self.map.remove(&key) {
            self.weight -= old.weight;
            self.recency.remove(&old.tick);
        }
        self.tick += 1;
        let tick = self.tick;
        self.recency.insert(tick, key.clone());
        self.map.insert(key, Entry { value, weight, tick });
        self.weight += weight;
        let mut evicted = 0usize;
        while self.weight > self.budget && self.map.len() > 1 {
            let lru_tick = *self.recency.keys().next().expect("recency tracks map");
            if lru_tick == tick {
                break; // never evict the entry this insert added
            }
            let lru_key = self.recency.remove(&lru_tick).expect("tick present");
            if let Some(e) = self.map.remove(&lru_key) {
                self.weight -= e.weight;
                evicted += e.weight;
            }
        }
        evicted
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total resident weight (bytes for the shard cache).
    pub fn weight(&self) -> usize {
        self.weight
    }

    /// The configured budget.
    pub fn budget(&self) -> usize {
        self.budget
    }
}

/// 64-bit FNV-1a over a byte slice, continuing from `h` (seed the first
/// call with [`FNV_OFFSET`]).
pub(crate) fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// FNV-1a offset basis.
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Digest of one query's validation features: 64-bit FNV-1a over the
/// per-checkpoint geometry and the exact f32 bit patterns. Two queries
/// with the same digest are treated as identical by the score cache (and
/// deduplicated within a batch); the 64-bit space makes an accidental
/// collision vanishingly unlikely at service scale, and a collision's
/// blast radius is one wrong (but well-formed) score vector, not memory
/// unsafety.
pub fn task_digest(val: &[FeatureMatrix]) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, &(val.len() as u64).to_le_bytes());
    for m in val {
        h = fnv1a(h, &(m.n as u64).to_le_bytes());
        h = fnv1a(h, &(m.k as u64).to_le_bytes());
        for &x in &m.data {
            h = fnv1a(h, &x.to_bits().to_le_bytes());
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lru_hits_and_misses() {
        let mut c: LruCache<u64, Arc<Vec<f32>>> = LruCache::new(10);
        assert!(c.get(&1).is_none());
        c.insert(1, Arc::new(vec![1.0]), 3);
        c.insert(2, Arc::new(vec![2.0]), 3);
        assert_eq!(c.get(&1).unwrap()[0], 1.0);
        assert_eq!(c.len(), 2);
        assert_eq!(c.weight(), 6);
        assert!(!c.is_empty());
    }

    #[test]
    fn lru_evicts_least_recent_under_budget() {
        let mut c: LruCache<u64, u64> = LruCache::new(3);
        c.insert(1, 10, 1);
        c.insert(2, 20, 1);
        c.insert(3, 30, 1);
        // touch 1 so 2 becomes the LRU
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.insert(4, 40, 1), 1, "evicted weight reported");
        assert_eq!(c.get(&2), None, "LRU entry evicted");
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&3), Some(30));
        assert_eq!(c.get(&4), Some(40));
        assert_eq!(c.weight(), 3);
    }

    #[test]
    fn lru_keeps_oversized_newest_entry() {
        let mut c: LruCache<u64, u64> = LruCache::new(5);
        c.insert(1, 10, 2);
        // alone over budget: evicts 1 (2 weight back), stays resident
        assert_eq!(c.insert(2, 20, 100), 2);
        assert_eq!(c.get(&1), None);
        assert_eq!(c.get(&2), Some(20));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn lru_replace_updates_weight() {
        let mut c: LruCache<u64, u64> = LruCache::new(10);
        c.insert(1, 10, 4);
        c.insert(1, 11, 6);
        assert_eq!(c.weight(), 6);
        assert_eq!(c.get(&1), Some(11));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn zero_budget_disables() {
        let mut c: LruCache<u64, u64> = LruCache::new(0);
        c.insert(1, 10, 1);
        assert!(c.get(&1).is_none());
        assert!(c.is_empty());
        assert_eq!(c.budget(), 0);
    }

    #[test]
    fn digest_sensitive_to_data_and_shape() {
        let m = |n: usize, k: usize, seed: f32| FeatureMatrix {
            n,
            k,
            data: (0..n * k).map(|i| seed + i as f32).collect(),
        };
        let a = vec![m(2, 4, 0.0), m(2, 4, 1.0)];
        let b = vec![m(2, 4, 0.0), m(2, 4, 1.0)];
        assert_eq!(task_digest(&a), task_digest(&b), "same features, same digest");
        let mut c = vec![m(2, 4, 0.0), m(2, 4, 1.0)];
        c[1].data[3] += 1e-6;
        assert_ne!(task_digest(&a), task_digest(&c), "one-ulp-ish change flips digest");
        // same flat data, different geometry
        let d = vec![m(4, 2, 0.0), m(4, 2, 1.0)];
        assert_ne!(task_digest(&a), task_digest(&d));
        // 0.0 vs -0.0 have different bit patterns → different digests
        let z0 = vec![FeatureMatrix { n: 1, k: 1, data: vec![0.0] }];
        let z1 = vec![FeatureMatrix { n: 1, k: 1, data: vec![-0.0] }];
        assert_ne!(task_digest(&z0), task_digest(&z1));
    }
}
