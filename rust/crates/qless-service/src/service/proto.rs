//! Wire format of the influence query service: **JSON-lines over TCP**.
//!
//! The normative request/response grammar is `rust/crates/qless-service/PROTOCOL.md` —
//! included verbatim below, so its example exchange runs as a doctest
//! against this parser and the spec can never drift from the code. Edit
//! the markdown file, not this header.
#![doc = include_str!("../../PROTOCOL.md")]

use anyhow::{bail, Context as _, Result};

use crate::grads::FeatureMatrix;
use crate::influence::ScanStats;
use crate::util::json::Json;
use crate::util::obs::{HistoSnapshot, MetricsSnapshot, SpanRecord};

use super::session::ServiceStats;

/// A parsed client request (see the module docs for the wire shape).
#[derive(Debug, Clone)]
pub enum Request {
    /// Score the corpus against one validation task.
    Score(ScoreRequest),
    /// Fetch cumulative service statistics.
    Stats {
        /// Client token echoed in the response.
        id: u64,
        /// Ask a coordinator to include its per-worker breakdown
        /// (PROTOCOL.md §Metrics); single-node servers ignore it.
        per_worker: bool,
    },
    /// Scrape the process metrics registry (PROTOCOL.md §Metrics).
    Metrics {
        /// Client token echoed in the response.
        id: u64,
        /// Include the ring of recently finished spans.
        traces: bool,
        /// Include the Prometheus text rendering alongside the JSON.
        prometheus: bool,
    },
    /// Liveness probe.
    Ping {
        /// Client token echoed in the response.
        id: u64,
    },
    /// Ask the server to stop accepting and drain.
    Shutdown {
        /// Client token echoed in the response.
        id: u64,
    },
}

impl Request {
    /// The client token this request carries.
    pub fn id(&self) -> u64 {
        match self {
            Request::Score(r) => r.id,
            Request::Stats { id, .. } | Request::Metrics { id, .. } => *id,
            Request::Ping { id } | Request::Shutdown { id } => *id,
        }
    }
}

/// The `trace` field of a score request: the caller's trace identity,
/// propagated so every hop's reply `timing` stitches into one tree
/// (PROTOCOL.md §Trace propagation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceField {
    /// Trace id, nonzero (hex string on the wire, like generations).
    pub id: u64,
    /// Span id of the caller's enclosing span (0 = this hop is the root).
    pub parent: u64,
}

/// The `cascade` field of a score request: run the two-stage precision
/// cascade instead of one exhaustive scan (PROTOCOL.md §Cascade).
///
/// Precisions are named by **bits**; the serving side resolves them
/// against the run directory's sibling stores (scheme comes from what is
/// actually on disk — a request cannot pick between two schemes at the
/// same bitwidth, that is a server-side configuration error).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CascadeField {
    /// The client verb: probe every row at `probe` bits, keep
    /// `mult × top_k` candidates per task, re-score them at `rerank` bits.
    Full {
        /// Probe-stage storage bitwidth (the cheap full scan).
        probe: u8,
        /// Rerank-stage storage bitwidth (candidate re-scoring).
        rerank: u8,
        /// Candidate multiplier `c` (stage 1 keeps `c·top_k` per task).
        mult: usize,
    },
    /// Scatter-gather **worker** verb, wave 1: probe-precision ranged scan
    /// (pairs with the request's `rows` range; `top_k` carries `c·k`).
    Probe {
        /// Probe-stage storage bitwidth.
        probe: u8,
    },
    /// Scatter-gather **worker** verb, wave 2: re-score exactly the listed
    /// global rows at the rerank precision and return every (row, score).
    Rerank {
        /// Rerank-stage storage bitwidth.
        rerank: u8,
        /// Global row indices to re-score, strictly increasing.
        rows: Vec<usize>,
    },
}

/// The `score` op's payload: per-checkpoint raw validation features plus
/// response-shaping knobs.
#[derive(Debug, Clone)]
pub struct ScoreRequest {
    /// Client token echoed in the response.
    pub id: u64,
    /// Top-k entries to return (per-request k; 0 = none).
    pub top_k: usize,
    /// Return the full per-sample score vector too.
    pub want_scores: bool,
    /// Restrict the top list to rows **newer than this generation**
    /// (incremental selection after an ingest); `None` ranks every row.
    pub since_gen: Option<u64>,
    /// Restrict scoring to the global row range `[start, start + len)` —
    /// the scatter-gather **worker** verb (see `super::coordinator`).
    /// `top` indices stay global; a returned `scores` vector covers only
    /// the range. `None` scores every live row.
    pub rows: Option<(u64, u64)>,
    /// Two-stage precision cascade (PROTOCOL.md §Cascade); `None` runs
    /// the ordinary exhaustive scan at the served precision.
    pub cascade: Option<CascadeField>,
    /// IVF index probe width (PROTOCOL.md §Indexed scoring): scan only
    /// each task's top-`nprobe` clusters of the served store's `.qidx`
    /// sidecar. `None` (or a server without a sidecar) scans exhaustively.
    /// Excludes `scores`, `since_gen` and `rows` — the indexed path
    /// returns top lists, and a coordinator partitions the *cluster* list
    /// (`clusters`), never the row space.
    pub nprobe: Option<u32>,
    /// Scatter-gather **worker** verb for indexed scoring: window
    /// `[start, start + len)` of cluster-list *positions* in each task's
    /// deterministic probe ranking. Requires `nprobe` (which bounds the
    /// ranking's coverage).
    pub clusters: Option<(u64, u64)>,
    /// Propagated trace identity; when present the reply carries a
    /// `timing` span array (PROTOCOL.md §Trace propagation).
    pub trace: Option<TraceField>,
    /// One raw `n × k` feature matrix per warmup checkpoint, in order.
    pub val: Vec<FeatureMatrix>,
}

/// The `score` op's success payload.
#[derive(Debug, Clone)]
pub struct ScoreReply {
    /// Echoed client token.
    pub id: u64,
    /// Datastore generation the session is pinned to.
    pub generation: u64,
    /// True when answered from the score cache without a scan.
    pub cached: bool,
    /// Distinct tasks fused into the producing pass (0 on a cache hit).
    pub batched: usize,
    /// I/O accounting of the producing pass (zeroed on a cache hit).
    pub pass: ScanStats,
    /// Echo of the request's row range on a ranged (worker) answer; a
    /// `scores` payload, if present, is local to it.
    pub rows: Option<(u64, u64)>,
    /// The `top_k` highest-scoring `(sample index, score)` pairs
    /// (**global** indices, even on a ranged answer).
    pub top: Vec<(usize, f32)>,
    /// Full per-sample scores, present iff the request set `"scores":true`.
    pub scores: Option<Vec<f32>>,
    /// Per-stage timing spans, present iff the request carried `trace`:
    /// `start_us` is relative to this hop's request start, and parent
    /// links resolve within the array (or to the request's trace parent).
    pub timing: Option<Vec<SpanRecord>>,
}

/// The `stats` op's success payload: served-store geometry + cumulative
/// [`ServiceStats`].
#[derive(Debug, Clone)]
pub struct StatsReply {
    /// Echoed client token.
    pub id: u64,
    /// Datastore generation the session is pinned to.
    pub generation: u64,
    /// Sample rows per checkpoint block.
    pub n_samples: usize,
    /// Projection dimension of the served store.
    pub k: usize,
    /// Checkpoint blocks in the served store.
    pub checkpoints: usize,
    /// Storage bitwidth of the served store.
    pub bits: u8,
    /// Cumulative service accounting.
    pub stats: ServiceStats,
    /// Per-worker breakdown, present iff a coordinator answered a
    /// request with `"per_worker":true` — the fleet sums are lossy for
    /// debugging a straggler, this row set is not.
    pub per_worker: Option<Vec<WorkerStat>>,
}

/// One worker's row in a coordinator's `per_worker` stats breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStat {
    /// The worker's address, as configured at the coordinator.
    pub addr: String,
    /// Generation the worker is pinned to.
    pub generation: u64,
    /// Live rows the worker serves.
    pub n_samples: usize,
    /// The worker's cumulative service accounting.
    pub stats: ServiceStats,
}

/// The `metrics` op's success payload: the scraped (or fleet-merged)
/// registry, plus optional Prometheus text and recent spans.
#[derive(Debug, Clone)]
pub struct MetricsReply {
    /// Echoed client token.
    pub id: u64,
    /// Counters, gauges and histograms by name.
    pub snapshot: MetricsSnapshot,
    /// Prometheus text rendering, iff the request set `"prometheus":true`.
    pub prometheus: Option<String>,
    /// Recently finished spans, iff the request set `"traces":true`
    /// (empty when tracing is disabled on the server).
    pub traces: Option<Vec<SpanRecord>>,
}

/// A parsed server response (see the module docs for the wire shape).
#[derive(Debug, Clone)]
pub enum Response {
    /// Answer to a `score` request.
    Score(ScoreReply),
    /// Answer to a `stats` request.
    Stats(StatsReply),
    /// Answer to a `metrics` request.
    Metrics(MetricsReply),
    /// Answer to a `ping` request.
    Pong {
        /// Echoed client token.
        id: u64,
    },
    /// Acknowledgement that the server is shutting down.
    ShuttingDown {
        /// Echoed client token.
        id: u64,
    },
    /// Any failure: malformed line, unknown op, invalid query, scan error.
    Error {
        /// Echoed client token (0 when the request line was unparsable).
        id: u64,
        /// Human-readable cause.
        error: String,
    },
}

impl Response {
    /// The client token this response echoes.
    pub fn id(&self) -> u64 {
        match self {
            Response::Score(r) => r.id,
            Response::Stats(r) => r.id,
            Response::Metrics(r) => r.id,
            Response::Pong { id } | Response::ShuttingDown { id } => *id,
            Response::Error { id, .. } => *id,
        }
    }
}

// ---------------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------------

fn id_json(id: u64) -> Json {
    Json::Num(id as f64)
}

fn gen_json(generation: u64) -> Json {
    Json::Str(format!("{generation:#x}"))
}

fn f32s_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn rows_json(start: u64, len: u64) -> Json {
    Json::Arr(vec![Json::Num(start as f64), Json::Num(len as f64)])
}

fn cascade_json(c: &CascadeField) -> Json {
    let mut o = Json::obj();
    match c {
        CascadeField::Full { probe, rerank, mult } => {
            o.set("probe", *probe as usize)
                .set("rerank", *rerank as usize)
                .set("mult", *mult);
        }
        CascadeField::Probe { probe } => {
            o.set("stage", "probe").set("probe", *probe as usize);
        }
        CascadeField::Rerank { rerank, rows } => {
            o.set("stage", "rerank").set("rerank", *rerank as usize).set(
                "rows_list",
                Json::Arr(rows.iter().map(|&r| Json::Num(r as f64)).collect()),
            );
        }
    }
    o
}

fn matrix_json(m: &FeatureMatrix) -> Json {
    let mut o = Json::obj();
    o.set("n", m.n).set("k", m.k).set("data", f32s_json(&m.data));
    o
}

fn scan_stats_json(s: &ScanStats) -> Json {
    let mut o = Json::obj();
    o.set("checkpoints", s.checkpoints)
        .set("tasks", s.tasks)
        .set("shards_read", s.shards_read)
        .set("rows_read", s.rows_read as f64)
        .set("bytes_read", s.bytes_read as f64);
    o
}

fn trace_json(t: &TraceField) -> Json {
    let mut o = Json::obj();
    o.set("id", gen_json(t.id));
    if t.parent != 0 {
        o.set("parent", gen_json(t.parent));
    }
    o
}

fn spans_json(spans: &[SpanRecord]) -> Json {
    Json::Arr(
        spans
            .iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("name", s.name.as_str());
                // score-reply timing belongs to the request's trace and
                // omits the id; ring dumps (`metrics --traces`) mix many
                // traces, so there each span carries its own
                if s.trace != 0 {
                    o.set("trace", gen_json(s.trace));
                }
                o.set("id", gen_json(s.id))
                    .set("parent", gen_json(s.parent))
                    .set("start_us", s.start_us as f64)
                    .set("dur_us", s.dur_us as f64);
                o
            })
            .collect(),
    )
}

fn snapshot_json(s: &MetricsSnapshot) -> Json {
    let mut counters = Json::obj();
    for (k, v) in &s.counters {
        counters.set(k.as_str(), *v as f64);
    }
    let mut gauges = Json::obj();
    for (k, v) in &s.gauges {
        gauges.set(k.as_str(), *v as f64);
    }
    let mut histos = Json::obj();
    for (k, h) in &s.histos {
        let mut e = Json::obj();
        e.set(
            "counts",
            Json::Arr(h.counts.iter().map(|&c| Json::Num(c as f64)).collect()),
        )
        .set("sum", h.sum as f64)
        .set("count", h.count as f64);
        histos.set(k.as_str(), e);
    }
    let mut o = Json::obj();
    o.set("counters", counters).set("gauges", gauges).set("histograms", histos);
    o
}

fn service_stats_json(s: &ServiceStats) -> Json {
    let mut o = Json::obj();
    o.set("queries", s.queries as f64)
        .set("batches", s.batches as f64)
        .set("fused_passes", s.fused_passes as f64)
        .set("score_cache_hits", s.score_cache_hits as f64)
        .set("score_cache_extends", s.score_cache_extends as f64)
        .set("shard_cache_hits", s.shard_cache_hits as f64)
        .set("disk_shard_reads", s.disk_shard_reads as f64)
        .set("shard_cache_bytes", s.shard_cache_bytes as f64)
        .set("rows_scored", s.rows_scored as f64)
        .set("reloads", s.reloads as f64)
        .set("index_queries", s.index_queries as f64)
        .set("index_fallbacks", s.index_fallbacks as f64)
        .set("index_stale_rows", s.index_stale_rows as f64)
        .set("index_clusters", s.index_clusters as f64);
    o
}

/// Encode a request as one wire line (no trailing newline).
pub fn encode_request(req: &Request) -> String {
    let mut o = Json::obj();
    match req {
        Request::Score(r) => {
            o.set("op", "score").set("id", id_json(r.id)).set("top_k", r.top_k);
            if r.want_scores {
                o.set("scores", true);
            }
            if let Some(g) = r.since_gen {
                o.set("since_gen", g as f64);
            }
            if let Some((start, len)) = r.rows {
                o.set("rows", rows_json(start, len));
            }
            if let Some(c) = &r.cascade {
                o.set("cascade", cascade_json(c));
            }
            if let Some(p) = r.nprobe {
                o.set("nprobe", p as usize);
            }
            if let Some((start, len)) = r.clusters {
                o.set("clusters", rows_json(start, len));
            }
            if let Some(t) = &r.trace {
                o.set("trace", trace_json(t));
            }
            o.set("val", Json::Arr(r.val.iter().map(matrix_json).collect()));
        }
        Request::Stats { id, per_worker } => {
            o.set("op", "stats").set("id", id_json(*id));
            if *per_worker {
                o.set("per_worker", true);
            }
        }
        Request::Metrics { id, traces, prometheus } => {
            o.set("op", "metrics").set("id", id_json(*id));
            if *traces {
                o.set("traces", true);
            }
            if *prometheus {
                o.set("prometheus", true);
            }
        }
        Request::Ping { id } => {
            o.set("op", "ping").set("id", id_json(*id));
        }
        Request::Shutdown { id } => {
            o.set("op", "shutdown").set("id", id_json(*id));
        }
    }
    o.encode()
}

/// Encode a response as one wire line (no trailing newline).
pub fn encode_response(resp: &Response) -> String {
    let mut o = Json::obj();
    match resp {
        Response::Score(r) => {
            o.set("id", id_json(r.id))
                .set("ok", true)
                .set("re", "score")
                .set("generation", gen_json(r.generation))
                .set("cached", r.cached)
                .set("batched", r.batched)
                .set("pass", scan_stats_json(&r.pass));
            if let Some((start, len)) = r.rows {
                o.set("rows", rows_json(start, len));
            }
            let top: Vec<Json> = r
                .top
                .iter()
                .map(|&(i, s)| {
                    let mut e = Json::obj();
                    e.set("index", i).set("score", s as f64);
                    e
                })
                .collect();
            o.set("top", Json::Arr(top));
            if let Some(scores) = &r.scores {
                o.set("scores", f32s_json(scores));
            }
            if let Some(timing) = &r.timing {
                o.set("timing", spans_json(timing));
            }
        }
        Response::Stats(r) => {
            o.set("id", id_json(r.id))
                .set("ok", true)
                .set("re", "stats")
                .set("generation", gen_json(r.generation))
                .set("n_samples", r.n_samples)
                .set("k", r.k)
                .set("checkpoints", r.checkpoints)
                .set("bits", r.bits as usize)
                .set("stats", service_stats_json(&r.stats));
            if let Some(per_worker) = &r.per_worker {
                let rows: Vec<Json> = per_worker
                    .iter()
                    .map(|w| {
                        let mut e = Json::obj();
                        e.set("addr", w.addr.as_str())
                            .set("generation", gen_json(w.generation))
                            .set("n_samples", w.n_samples)
                            .set("stats", service_stats_json(&w.stats));
                        e
                    })
                    .collect();
                o.set("per_worker", Json::Arr(rows));
            }
        }
        Response::Metrics(r) => {
            o.set("id", id_json(r.id))
                .set("ok", true)
                .set("re", "metrics")
                .set("metrics", snapshot_json(&r.snapshot));
            if let Some(text) = &r.prometheus {
                o.set("prometheus", text.as_str());
            }
            if let Some(traces) = &r.traces {
                o.set("traces", spans_json(traces));
            }
        }
        Response::Pong { id } => {
            o.set("id", id_json(*id)).set("ok", true).set("re", "ping");
        }
        Response::ShuttingDown { id } => {
            o.set("id", id_json(*id)).set("ok", true).set("re", "shutdown");
        }
        Response::Error { id, error } => {
            o.set("id", id_json(*id)).set("ok", false).set("error", error.as_str());
        }
    }
    o.encode()
}

// ---------------------------------------------------------------------------
// parse
// ---------------------------------------------------------------------------

fn parse_id(j: &Json) -> u64 {
    j.get("id").and_then(|v| v.as_f64().ok()).map(|f| f as u64).unwrap_or(0)
}

fn parse_gen(j: &Json, key: &str) -> Result<u64> {
    let s = j.req(key)?.as_str()?;
    let hex = s.strip_prefix("0x").unwrap_or(s);
    Ok(u64::from_str_radix(hex, 16)?)
}

fn parse_f32s(j: &Json) -> Result<Vec<f32>> {
    j.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
}

fn parse_matrix(j: &Json) -> Result<FeatureMatrix> {
    let n = j.req("n")?.as_usize()?;
    let k = j.req("k")?.as_usize()?;
    let data = parse_f32s(j.req("data")?)?;
    Ok(FeatureMatrix { n, k, data })
}

fn parse_rows(j: &Json) -> Result<Option<(u64, u64)>> {
    match j.get("rows") {
        Some(v) => {
            let a = v.as_arr()?;
            if a.len() != 2 {
                bail!("'rows' must be [start, len], got {} entries", a.len());
            }
            Ok(Some((a[0].as_usize()? as u64, a[1].as_usize()? as u64)))
        }
        None => Ok(None),
    }
}

/// Strict parse of the `nprobe` field (PROTOCOL.md §Indexed scoring):
/// must be an integer ≥ 1 — a zero or fractional probe width must not
/// silently degrade to an exhaustive scan or an empty candidate set.
fn parse_nprobe(j: &Json) -> Result<Option<u32>> {
    let Some(v) = j.get("nprobe") else { return Ok(None) };
    let p = v
        .as_usize()
        .context("'nprobe' must be a non-negative integer (see PROTOCOL.md §Indexed scoring)")?;
    if p == 0 {
        bail!("'nprobe' must be >= 1 (omit the field for an exhaustive scan)");
    }
    if p > u32::MAX as usize {
        bail!("'nprobe' {p} out of range");
    }
    Ok(Some(p as u32))
}

/// Strict parse of the `clusters` worker window: `[start, len]` positions
/// into each task's probe ranking; only meaningful with `nprobe`.
fn parse_clusters(j: &Json) -> Result<Option<(u64, u64)>> {
    let Some(v) = j.get("clusters") else { return Ok(None) };
    let a = v.as_arr()?;
    if a.len() != 2 {
        bail!("'clusters' must be [start, len], got {} entries", a.len());
    }
    Ok(Some((a[0].as_usize()? as u64, a[1].as_usize()? as u64)))
}

/// Legal storage bitwidths a cascade stage may name.
const CASCADE_BITS: [u8; 5] = [1, 2, 4, 8, 16];

fn parse_cascade_bits(j: &Json, key: &str) -> Result<u8> {
    let b = j.req(key)?.as_usize()?;
    if b == 0 || b > u8::MAX as usize || !CASCADE_BITS.contains(&(b as u8)) {
        bail!("cascade '{key}' bits must be one of 1,2,4,8,16 (got {b})");
    }
    Ok(b as u8)
}

/// Strict parse of the `cascade` object: unknown keys are an error, never
/// ignored — a typoed field must not silently fall back to an exhaustive
/// scan or a truncated candidate list.
fn parse_cascade(j: &Json) -> Result<Option<CascadeField>> {
    let Some(c) = j.get("cascade") else { return Ok(None) };
    let obj = c.as_obj().map_err(|_| {
        anyhow::anyhow!("'cascade' must be an object (see PROTOCOL.md §Cascade)")
    })?;
    let check_keys = |allowed: &[&str]| -> Result<()> {
        for k in obj.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!(
                    "unknown key '{k}' in 'cascade' (allowed here: {})",
                    allowed.join(", ")
                );
            }
        }
        Ok(())
    };
    let field = match c.get("stage") {
        None => {
            check_keys(&["probe", "rerank", "mult"])?;
            let probe = parse_cascade_bits(c, "probe")?;
            let rerank = parse_cascade_bits(c, "rerank")?;
            if probe >= rerank {
                bail!("cascade probe bits must be below rerank bits (got {probe},{rerank})");
            }
            let mult = match c.get("mult") {
                Some(v) => v.as_usize()?,
                None => crate::influence::DEFAULT_CASCADE_MULT,
            };
            if mult == 0 {
                bail!("cascade 'mult' must be >= 1");
            }
            CascadeField::Full { probe, rerank, mult }
        }
        Some(stage) => match stage.as_str()? {
            "probe" => {
                check_keys(&["stage", "probe"])?;
                CascadeField::Probe { probe: parse_cascade_bits(c, "probe")? }
            }
            "rerank" => {
                check_keys(&["stage", "rerank", "rows_list"])?;
                let rows = c
                    .req("rows_list")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_usize())
                    .collect::<Result<Vec<_>>>()?;
                if rows.is_empty() {
                    bail!("cascade 'rows_list' must name at least one row");
                }
                if rows.windows(2).any(|w| w[0] >= w[1]) {
                    bail!("cascade 'rows_list' must be strictly increasing");
                }
                CascadeField::Rerank { rerank: parse_cascade_bits(c, "rerank")?, rows }
            }
            other => bail!("unknown cascade stage '{other}' (expected probe|rerank)"),
        },
    };
    Ok(Some(field))
}

/// Strict parse of the `trace` field: unknown keys are an error (a typoed
/// field must not silently drop tracing), ids are hex strings like
/// generations, and a zero trace id is rejected — 0 is the "untraced"
/// sentinel in span records.
fn parse_trace(j: &Json) -> Result<Option<TraceField>> {
    let Some(t) = j.get("trace") else { return Ok(None) };
    let obj = t.as_obj().map_err(|_| {
        anyhow::anyhow!("'trace' must be an object (see PROTOCOL.md §Trace propagation)")
    })?;
    for k in obj.keys() {
        if !["id", "parent"].contains(&k.as_str()) {
            bail!("unknown key '{k}' in 'trace' (allowed: id, parent)");
        }
    }
    let id = parse_gen(t, "id").context("malformed 'trace' id (want a hex string)")?;
    if id == 0 {
        bail!("'trace' id must be nonzero");
    }
    let parent = match t.get("parent") {
        Some(_) => {
            parse_gen(t, "parent").context("malformed 'trace' parent (want a hex string)")?
        }
        None => 0,
    };
    Ok(Some(TraceField { id, parent }))
}

fn parse_spans(j: &Json) -> Result<Vec<SpanRecord>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            Ok(SpanRecord {
                name: e.req("name")?.as_str()?.to_string(),
                // optional: score-reply timing spans may omit it (the
                // trace id travels in the request, and the receiver
                // re-homes absorbed spans into its own trace anyway)
                trace: match e.get("trace") {
                    Some(_) => parse_gen(e, "trace")?,
                    None => 0,
                },
                id: parse_gen(e, "id")?,
                parent: parse_gen(e, "parent")?,
                start_us: e.req("start_us")?.as_f64()? as u64,
                dur_us: e.req("dur_us")?.as_f64()? as u64,
            })
        })
        .collect()
}

fn parse_snapshot(j: &Json) -> Result<MetricsSnapshot> {
    let mut snap = MetricsSnapshot::default();
    for (k, v) in j.req("counters")?.as_obj()? {
        snap.counters.insert(k.clone(), v.as_f64()? as u64);
    }
    for (k, v) in j.req("gauges")?.as_obj()? {
        snap.gauges.insert(k.clone(), v.as_f64()? as i64);
    }
    for (k, v) in j.req("histograms")?.as_obj()? {
        let counts = v
            .req("counts")?
            .as_arr()?
            .iter()
            .map(|c| Ok(c.as_f64()? as u64))
            .collect::<Result<Vec<_>>>()?;
        snap.histos.insert(
            k.clone(),
            HistoSnapshot {
                counts,
                sum: v.req("sum")?.as_f64()? as u64,
                count: v.req("count")?.as_f64()? as u64,
            },
        );
    }
    Ok(snap)
}

fn parse_scan_stats(j: &Json) -> Result<ScanStats> {
    Ok(ScanStats {
        checkpoints: j.req("checkpoints")?.as_usize()?,
        tasks: j.req("tasks")?.as_usize()?,
        shards_read: j.req("shards_read")?.as_usize()?,
        rows_read: j.req("rows_read")?.as_f64()? as u64,
        bytes_read: j.req("bytes_read")?.as_f64()? as u64,
    })
}

fn parse_service_stats(j: &Json) -> Result<ServiceStats> {
    let u = |key: &str| -> Result<u64> { Ok(j.req(key)?.as_f64()? as u64) };
    Ok(ServiceStats {
        queries: u("queries")?,
        batches: u("batches")?,
        fused_passes: u("fused_passes")?,
        score_cache_hits: u("score_cache_hits")?,
        score_cache_extends: u("score_cache_extends")?,
        shard_cache_hits: u("shard_cache_hits")?,
        disk_shard_reads: u("disk_shard_reads")?,
        shard_cache_bytes: u("shard_cache_bytes")?,
        rows_scored: u("rows_scored")?,
        reloads: u("reloads")?,
        index_queries: u("index_queries")?,
        index_fallbacks: u("index_fallbacks")?,
        index_stale_rows: u("index_stale_rows")?,
        index_clusters: u("index_clusters")?,
    })
}

/// The `id` of a (possibly malformed) request line, for error responses —
/// 0 when the line is not even parsable JSON.
pub fn salvage_id(line: &str) -> u64 {
    Json::parse(line.trim()).map(|j| parse_id(&j)).unwrap_or(0)
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line.trim())?;
    let op = j.req("op")?.as_str()?.to_string();
    let id = parse_id(&j);
    match op.as_str() {
        "score" => {
            let top_k = match j.get("top_k") {
                Some(v) => v.as_usize()?,
                None => 0,
            };
            let want_scores = match j.get("scores") {
                Some(Json::Bool(b)) => *b,
                None => false,
                Some(other) => bail!("'scores' must be a bool, got {other:?}"),
            };
            let since_gen = match j.get("since_gen") {
                Some(v) => Some(v.as_usize()? as u64),
                None => None,
            };
            let rows = parse_rows(&j)?;
            let cascade = parse_cascade(&j)?;
            let nprobe = parse_nprobe(&j)?;
            let clusters = parse_clusters(&j)?;
            if nprobe.is_some() {
                if want_scores {
                    bail!("'nprobe' cannot be combined with 'scores' (indexed scans return top lists only)");
                }
                if since_gen.is_some() {
                    bail!("'nprobe' cannot be combined with 'since_gen'");
                }
                if rows.is_some() {
                    bail!("'nprobe' cannot be combined with 'rows' (partition the cluster list via 'clusters')");
                }
            } else if clusters.is_some() {
                bail!("'clusters' requires 'nprobe' (see PROTOCOL.md §Indexed scoring)");
            }
            let trace = parse_trace(&j)?;
            let val = j
                .req("val")?
                .as_arr()?
                .iter()
                .map(parse_matrix)
                .collect::<Result<Vec<_>>>()?;
            Ok(Request::Score(ScoreRequest {
                id,
                top_k,
                want_scores,
                since_gen,
                rows,
                cascade,
                nprobe,
                clusters,
                trace,
                val,
            }))
        }
        "stats" => {
            let per_worker = match j.get("per_worker") {
                Some(Json::Bool(b)) => *b,
                None => false,
                Some(other) => bail!("'per_worker' must be a bool, got {other:?}"),
            };
            Ok(Request::Stats { id, per_worker })
        }
        "metrics" => {
            for k in j.as_obj()?.keys() {
                if !["op", "id", "traces", "prometheus"].contains(&k.as_str()) {
                    bail!(
                        "unknown key '{k}' in 'metrics' request \
                         (allowed: op, id, traces, prometheus)"
                    );
                }
            }
            let flag = |key: &str| -> Result<bool> {
                match j.get(key) {
                    Some(Json::Bool(b)) => Ok(*b),
                    None => Ok(false),
                    Some(other) => bail!("'{key}' must be a bool, got {other:?}"),
                }
            };
            Ok(Request::Metrics { id, traces: flag("traces")?, prometheus: flag("prometheus")? })
        }
        "ping" => Ok(Request::Ping { id }),
        "shutdown" => Ok(Request::Shutdown { id }),
        other => bail!("unknown op '{other}' (expected score|stats|metrics|ping|shutdown)"),
    }
}

/// Parse one response line.
pub fn parse_response(line: &str) -> Result<Response> {
    let j = Json::parse(line.trim())?;
    let id = parse_id(&j);
    let ok = match j.req("ok")? {
        Json::Bool(b) => *b,
        other => bail!("'ok' must be a bool, got {other:?}"),
    };
    if !ok {
        let error = j.req("error")?.as_str()?.to_string();
        return Ok(Response::Error { id, error });
    }
    let re = j.req("re")?.as_str()?.to_string();
    match re.as_str() {
        "score" => {
            let cached = match j.req("cached")? {
                Json::Bool(b) => *b,
                other => bail!("'cached' must be a bool, got {other:?}"),
            };
            let top = j
                .req("top")?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok((e.req("index")?.as_usize()?, e.req("score")?.as_f64()? as f32))
                })
                .collect::<Result<Vec<_>>>()?;
            let scores = match j.get("scores") {
                Some(v) => Some(parse_f32s(v)?),
                None => None,
            };
            let timing = match j.get("timing") {
                Some(v) => Some(parse_spans(v)?),
                None => None,
            };
            Ok(Response::Score(ScoreReply {
                id,
                generation: parse_gen(&j, "generation")?,
                cached,
                batched: j.req("batched")?.as_usize()?,
                pass: parse_scan_stats(j.req("pass")?)?,
                rows: parse_rows(&j)?,
                top,
                scores,
                timing,
            }))
        }
        "stats" => {
            let per_worker = match j.get("per_worker") {
                Some(v) => Some(
                    v.as_arr()?
                        .iter()
                        .map(|e| {
                            Ok(WorkerStat {
                                addr: e.req("addr")?.as_str()?.to_string(),
                                generation: parse_gen(e, "generation")?,
                                n_samples: e.req("n_samples")?.as_usize()?,
                                stats: parse_service_stats(e.req("stats")?)?,
                            })
                        })
                        .collect::<Result<Vec<_>>>()?,
                ),
                None => None,
            };
            Ok(Response::Stats(StatsReply {
                id,
                generation: parse_gen(&j, "generation")?,
                n_samples: j.req("n_samples")?.as_usize()?,
                k: j.req("k")?.as_usize()?,
                checkpoints: j.req("checkpoints")?.as_usize()?,
                bits: j.req("bits")?.as_usize()? as u8,
                stats: parse_service_stats(j.req("stats")?)?,
                per_worker,
            }))
        }
        "metrics" => {
            let prometheus = match j.get("prometheus") {
                Some(v) => Some(v.as_str()?.to_string()),
                None => None,
            };
            let traces = match j.get("traces") {
                Some(v) => Some(parse_spans(v)?),
                None => None,
            };
            Ok(Response::Metrics(MetricsReply {
                id,
                snapshot: parse_snapshot(j.req("metrics")?)?,
                prometheus,
                traces,
            }))
        }
        "ping" => Ok(Response::Pong { id }),
        "shutdown" => Ok(Response::ShuttingDown { id }),
        other => bail!("unknown response kind '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mat(n: usize, k: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() }
    }

    #[test]
    fn score_request_roundtrips_exactly() {
        let req = Request::Score(ScoreRequest {
            id: 42,
            top_k: 7,
            want_scores: true,
            since_gen: Some(3),
            rows: Some((120, 64)),
            cascade: None,
            nprobe: None,
            clusters: None,
            trace: None,
            val: vec![mat(2, 8, 1), mat(3, 8, 2)],
        });
        let line = encode_request(&req);
        assert!(!line.contains('\n'), "one line");
        let back = parse_request(&line).unwrap();
        match back {
            Request::Score(r) => {
                assert_eq!(r.id, 42);
                assert_eq!(r.top_k, 7);
                assert!(r.want_scores);
                assert_eq!(r.since_gen, Some(3));
                assert_eq!(r.rows, Some((120, 64)));
                assert_eq!(r.val.len(), 2);
                match &req {
                    Request::Score(orig) => {
                        for (a, b) in orig.val.iter().zip(&r.val) {
                            assert_eq!(a.n, b.n);
                            assert_eq!(a.k, b.k);
                            // f32 → JSON → f32 must be bit-exact
                            for (x, y) in a.data.iter().zip(&b.data) {
                                assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
                            }
                        }
                    }
                    _ => unreachable!(),
                }
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn control_requests_roundtrip() {
        for (req, want_op) in [
            (Request::Stats { id: 1, per_worker: false }, "stats"),
            (Request::Ping { id: 2 }, "ping"),
            (Request::Shutdown { id: 3 }, "shutdown"),
            (Request::Metrics { id: 4, traces: false, prometheus: false }, "metrics"),
        ] {
            let line = encode_request(&req);
            assert!(line.contains(want_op));
            let back = parse_request(&line).unwrap();
            assert_eq!(back.id(), req.id());
        }
    }

    #[test]
    fn score_response_roundtrips_exactly() {
        let scores: Vec<f32> = (0..9).map(|i| (i as f32 - 4.2) / 3.7).collect();
        let resp = Response::Score(ScoreReply {
            id: 5,
            generation: 0xdead_beef_0042_1337,
            cached: false,
            batched: 3,
            pass: ScanStats {
                checkpoints: 2,
                tasks: 3,
                shards_read: 14,
                rows_read: 96,
                bytes_read: 12_480,
            },
            rows: Some((32, 9)),
            top: vec![(7, scores[7]), (0, scores[0])],
            scores: Some(scores.clone()),
            timing: None,
        });
        let line = encode_response(&resp);
        match parse_response(&line).unwrap() {
            Response::Score(r) => {
                assert_eq!(r.id, 5);
                assert_eq!(r.generation, 0xdead_beef_0042_1337);
                assert!(!r.cached);
                assert_eq!(r.batched, 3);
                assert_eq!(r.pass.shards_read, 14);
                assert_eq!(r.pass.rows_read, 96);
                assert_eq!(r.rows, Some((32, 9)), "ranged answers echo the range");
                assert_eq!(r.top, vec![(7, scores[7]), (0, scores[0])]);
                let got = r.scores.unwrap();
                for (x, y) in scores.iter().zip(&got) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn stats_pong_error_roundtrip() {
        let stats = ServiceStats {
            queries: 9,
            batches: 4,
            fused_passes: 2,
            score_cache_hits: 3,
            score_cache_extends: 1,
            shard_cache_hits: 14,
            disk_shard_reads: 14,
            shard_cache_bytes: 16_640,
            rows_scored: 192,
            reloads: 1,
            index_queries: 6,
            index_fallbacks: 1,
            index_stale_rows: 40,
            index_clusters: 16,
        };
        let resp = Response::Stats(StatsReply {
            id: 2,
            generation: 0x1,
            n_samples: 48,
            k: 512,
            checkpoints: 2,
            bits: 4,
            stats,
            per_worker: None,
        });
        match parse_response(&encode_response(&resp)).unwrap() {
            Response::Stats(r) => {
                assert_eq!(r.stats, stats);
                assert_eq!(r.bits, 4);
                assert_eq!(r.n_samples, 48);
            }
            other => panic!("wrong variant {other:?}"),
        }
        match parse_response(&encode_response(&Response::Pong { id: 3 })).unwrap() {
            Response::Pong { id } => assert_eq!(id, 3),
            other => panic!("wrong variant {other:?}"),
        }
        let err = Response::Error { id: 7, error: "bad \"query\"\nline".into() };
        match parse_response(&encode_response(&err)).unwrap() {
            Response::Error { id, error } => {
                assert_eq!(id, 7);
                assert_eq!(error, "bad \"query\"\nline");
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn malformed_lines_rejected_with_salvaged_id() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request("{\"op\":\"conquer\"}").is_err());
        assert!(parse_request("{\"id\":1}").is_err()); // no op
        assert!(parse_response("{\"id\":1}").is_err()); // no ok
        assert_eq!(salvage_id("garbage"), 0);
        assert_eq!(salvage_id("{\"id\":31,\"op\":\"?\"}"), 31);
        // rows must be a 2-element array
        let bad = "{\"op\":\"score\",\"rows\":[4],\"val\":[{\"n\":1,\"k\":1,\"data\":[1]}]}";
        assert!(parse_request(bad).is_err());
    }

    #[test]
    fn score_request_defaults() {
        let line = "{\"op\":\"score\",\"val\":[{\"n\":1,\"k\":2,\"data\":[0.5,1]}]}";
        match parse_request(line).unwrap() {
            Request::Score(r) => {
                assert_eq!(r.id, 0);
                assert_eq!(r.top_k, 0);
                assert!(!r.want_scores);
                assert_eq!(r.since_gen, None, "no filter by default");
                assert_eq!(r.rows, None, "full row space by default");
                assert_eq!(r.cascade, None, "exhaustive scan by default");
                assert_eq!(r.nprobe, None, "no index probing by default");
                assert_eq!(r.clusters, None);
                assert_eq!(r.val[0].data, vec![0.5, 1.0]);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    fn score_req(cascade: Option<CascadeField>) -> Request {
        Request::Score(ScoreRequest {
            id: 9,
            top_k: 4,
            want_scores: false,
            since_gen: None,
            rows: None,
            cascade,
            nprobe: None,
            clusters: None,
            trace: None,
            val: vec![mat(2, 8, 3)],
        })
    }

    #[test]
    fn cascade_fields_roundtrip() {
        for c in [
            CascadeField::Full { probe: 1, rerank: 8, mult: 4 },
            CascadeField::Probe { probe: 1 },
            CascadeField::Rerank { rerank: 8, rows: vec![3, 17, 640] },
        ] {
            let line = encode_request(&score_req(Some(c.clone())));
            match parse_request(&line).unwrap() {
                Request::Score(r) => assert_eq!(r.cascade, Some(c), "{line}"),
                other => panic!("wrong variant {other:?}"),
            }
        }
        // mult is optional on the wire and defaults to the library default
        let line = "{\"op\":\"score\",\"top_k\":2,\"cascade\":{\"probe\":1,\"rerank\":8},\
                    \"val\":[{\"n\":1,\"k\":2,\"data\":[0.5,1]}]}";
        match parse_request(line).unwrap() {
            Request::Score(r) => assert_eq!(
                r.cascade,
                Some(CascadeField::Full {
                    probe: 1,
                    rerank: 8,
                    mult: crate::influence::DEFAULT_CASCADE_MULT
                })
            ),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn malformed_cascade_fields_rejected() {
        let wrap = |cascade: &str| {
            format!(
                "{{\"op\":\"score\",\"top_k\":2,\"cascade\":{cascade},\
                 \"val\":[{{\"n\":1,\"k\":2,\"data\":[0.5,1]}}]}}"
            )
        };
        let cases: &[(&str, &str)] = &[
            ("3", "must be an object"),
            ("{\"probe\":1}", "missing key 'rerank'"),
            ("{\"rerank\":8}", "missing key 'probe'"),
            ("{\"probe\":3,\"rerank\":8}", "one of 1,2,4,8,16"),
            ("{\"probe\":1,\"rerank\":99}", "one of 1,2,4,8,16"),
            ("{\"probe\":8,\"rerank\":1}", "below rerank"),
            ("{\"probe\":8,\"rerank\":8}", "below rerank"),
            ("{\"probe\":1,\"rerank\":8,\"mult\":0}", "'mult' must be >= 1"),
            ("{\"probe\":1,\"rerank\":8,\"multt\":2}", "unknown key 'multt'"),
            ("{\"probe\":1,\"rerank\":8,\"rows_list\":[1]}", "unknown key 'rows_list'"),
            ("{\"stage\":\"launch\",\"probe\":1}", "unknown cascade stage"),
            ("{\"stage\":\"probe\"}", "missing key 'probe'"),
            ("{\"stage\":\"probe\",\"probe\":1,\"mult\":2}", "unknown key 'mult'"),
            ("{\"stage\":\"rerank\",\"rerank\":8,\"rows_list\":[]}", "at least one row"),
            (
                "{\"stage\":\"rerank\",\"rerank\":8,\"rows_list\":[5,5]}",
                "strictly increasing",
            ),
            (
                "{\"stage\":\"rerank\",\"rerank\":8,\"rows_list\":[9,2]}",
                "strictly increasing",
            ),
            ("{\"stage\":\"rerank\",\"rerank\":8}", "missing key 'rows_list'"),
        ];
        for (cascade, want) in cases {
            let err = match parse_request(&wrap(cascade)) {
                Err(e) => format!("{e:#}"),
                Ok(r) => panic!("cascade {cascade} must be rejected, parsed {r:?}"),
            };
            assert!(err.contains(want), "cascade {cascade}: got '{err}', want '{want}'");
        }
    }

    #[test]
    fn nprobe_fields_roundtrip() {
        for (nprobe, clusters) in [(Some(4u32), None), (Some(7), Some((2u64, 3u64)))] {
            let req = Request::Score(ScoreRequest {
                id: 11,
                top_k: 5,
                want_scores: false,
                since_gen: None,
                rows: None,
                cascade: None,
                nprobe,
                clusters,
                trace: None,
                val: vec![mat(2, 8, 4)],
            });
            let line = encode_request(&req);
            match parse_request(&line).unwrap() {
                Request::Score(r) => {
                    assert_eq!(r.nprobe, nprobe, "{line}");
                    assert_eq!(r.clusters, clusters, "{line}");
                }
                other => panic!("wrong variant {other:?}"),
            }
        }
        // nprobe composes with a full cascade (index-restricted probe stage)
        let line = "{\"op\":\"score\",\"top_k\":2,\"nprobe\":3,\
                    \"cascade\":{\"probe\":1,\"rerank\":8},\
                    \"val\":[{\"n\":1,\"k\":2,\"data\":[0.5,1]}]}";
        match parse_request(line).unwrap() {
            Request::Score(r) => {
                assert_eq!(r.nprobe, Some(3));
                assert!(r.cascade.is_some());
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn malformed_nprobe_fields_rejected() {
        let wrap = |extra: &str| {
            format!(
                "{{\"op\":\"score\",\"top_k\":2,{extra},\
                 \"val\":[{{\"n\":1,\"k\":2,\"data\":[0.5,1]}}]}}"
            )
        };
        let cases: &[(&str, &str)] = &[
            ("\"nprobe\":0", "must be >= 1"),
            ("\"nprobe\":1.5", "non-negative integer"),
            ("\"nprobe\":-2", "non-negative integer"),
            ("\"nprobe\":\"four\"", "'nprobe'"),
            ("\"nprobe\":2,\"scores\":true", "cannot be combined with 'scores'"),
            ("\"nprobe\":2,\"since_gen\":1", "cannot be combined with 'since_gen'"),
            ("\"nprobe\":2,\"rows\":[0,4]", "cannot be combined with 'rows'"),
            ("\"clusters\":[0,2]", "'clusters' requires 'nprobe'"),
            ("\"nprobe\":2,\"clusters\":[0]", "must be [start, len]"),
            ("\"nprobe\":2,\"clusters\":[0,1,2]", "must be [start, len]"),
        ];
        for (extra, want) in cases {
            let err = match parse_request(&wrap(extra)) {
                Err(e) => format!("{e:#}"),
                Ok(r) => panic!("{extra} must be rejected, parsed {r:?}"),
            };
            assert!(err.contains(want), "{extra}: got '{err}', want '{want}'");
        }
    }

    #[test]
    fn trace_field_roundtrips() {
        for t in [
            TraceField { id: 0x1f, parent: 0 },
            TraceField { id: 0xdead_beef, parent: 0x7 },
        ] {
            let req = Request::Score(ScoreRequest {
                id: 9,
                top_k: 4,
                want_scores: false,
                since_gen: None,
                rows: None,
                cascade: None,
                nprobe: None,
                clusters: None,
                trace: Some(t),
                val: vec![mat(2, 8, 3)],
            });
            let line = encode_request(&req);
            match parse_request(&line).unwrap() {
                Request::Score(r) => assert_eq!(r.trace, Some(t), "{line}"),
                other => panic!("wrong variant {other:?}"),
            }
        }
        // absent trace parses to None (and the reply carries no timing)
        match parse_request(&encode_request(&score_req(None))).unwrap() {
            Request::Score(r) => assert_eq!(r.trace, None),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn malformed_trace_fields_rejected() {
        let wrap = |trace: &str| {
            format!(
                "{{\"op\":\"score\",\"top_k\":2,\"trace\":{trace},\
                 \"val\":[{{\"n\":1,\"k\":2,\"data\":[0.5,1]}}]}}"
            )
        };
        let cases: &[(&str, &str)] = &[
            ("3", "must be an object"),
            ("[\"0x1\"]", "must be an object"),
            ("{\"parent\":\"0x2\"}", "missing key 'id'"),
            ("{\"id\":\"0x1\",\"parrent\":\"0x2\"}", "unknown key 'parrent'"),
            ("{\"id\":\"0xzz\"}", "malformed 'trace' id"),
            ("{\"id\":7}", "malformed 'trace' id"),
            ("{\"id\":\"0x0\"}", "must be nonzero"),
            ("{\"id\":\"0x1\",\"parent\":\"frogs\"}", "malformed 'trace' parent"),
        ];
        for (trace, want) in cases {
            let err = match parse_request(&wrap(trace)) {
                Err(e) => format!("{e:#}"),
                Ok(r) => panic!("trace {trace} must be rejected, parsed {r:?}"),
            };
            assert!(err.contains(want), "trace {trace}: got '{err}', want '{want}'");
        }
    }

    #[test]
    fn timing_spans_roundtrip_on_score_reply() {
        let spans = vec![
            SpanRecord {
                name: "server.score".into(),
                trace: 0xabc,
                id: 0x11,
                parent: 0x3,
                start_us: 0,
                dur_us: 1_850,
            },
            SpanRecord {
                name: "server.wait".into(),
                trace: 0,
                id: 0x12,
                parent: 0x11,
                start_us: 40,
                dur_us: 1_700,
            },
        ];
        let resp = Response::Score(ScoreReply {
            id: 5,
            generation: 0x2,
            cached: false,
            batched: 1,
            pass: ScanStats::default(),
            rows: None,
            top: vec![],
            scores: None,
            timing: Some(spans.clone()),
        });
        match parse_response(&encode_response(&resp)).unwrap() {
            Response::Score(r) => assert_eq!(r.timing, Some(spans)),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn metrics_request_roundtrips_and_rejects_unknown_keys() {
        for (traces, prometheus) in [(false, false), (true, false), (false, true), (true, true)] {
            let line = encode_request(&Request::Metrics { id: 8, traces, prometheus });
            match parse_request(&line).unwrap() {
                Request::Metrics { id, traces: t, prometheus: p } => {
                    assert_eq!((id, t, p), (8, traces, prometheus), "{line}");
                }
                other => panic!("wrong variant {other:?}"),
            }
        }
        let cases: &[(&str, &str)] = &[
            ("{\"op\":\"metrics\",\"id\":1,\"tracez\":true}", "unknown key 'tracez'"),
            ("{\"op\":\"metrics\",\"id\":1,\"traces\":1}", "must be a bool"),
            ("{\"op\":\"metrics\",\"id\":1,\"prometheus\":\"yes\"}", "must be a bool"),
        ];
        for (line, want) in cases {
            let err = match parse_request(line) {
                Err(e) => format!("{e:#}"),
                Ok(r) => panic!("{line} must be rejected, parsed {r:?}"),
            };
            assert!(err.contains(want), "{line}: got '{err}', want '{want}'");
        }
        // the op itself still parses strictly elsewhere: a typoed op names it
        let err = format!("{:#}", parse_request("{\"op\":\"metricz\",\"id\":1}").unwrap_err());
        assert!(err.contains("expected score|stats|metrics|ping|shutdown"), "{err}");
    }

    #[test]
    fn metrics_reply_roundtrips_exactly() {
        let mut snap = MetricsSnapshot::default();
        snap.counters.insert("scan_rows_total{bits=\"4\"}".into(), 4096);
        snap.counters.insert("score_cache_hits_total".into(), 3);
        snap.gauges.insert("session_generation".into(), 2);
        snap.gauges.insert("batcher_queue_depth".into(), 3);
        let mut h = HistoSnapshot::default();
        h.counts = vec![0; crate::util::obs::LATENCY_BOUNDS_US.len() + 1];
        h.counts[2] = 5;
        h.counts[9] = 1;
        h.sum = 61_400;
        h.count = 6;
        snap.histos.insert("score_us".into(), h);
        let resp = Response::Metrics(MetricsReply {
            id: 12,
            snapshot: snap.clone(),
            prometheus: Some("qless_score_cache_hits_total 3\n".into()),
            traces: Some(vec![SpanRecord {
                name: "session.answer_batch".into(),
                trace: 0x7,
                id: 0x9,
                parent: 0,
                start_us: 17,
                dur_us: 950,
            }]),
        });
        let line = encode_response(&resp);
        match parse_response(&line).unwrap() {
            Response::Metrics(r) => {
                assert_eq!(r.id, 12);
                assert_eq!(r.snapshot, snap, "{line}");
                assert_eq!(r.prometheus.as_deref(), Some("qless_score_cache_hits_total 3\n"));
                let ring = r.traces.unwrap();
                assert_eq!(ring.len(), 1);
                assert_eq!(ring[0].trace, 0x7, "ring spans keep their trace id on the wire");
            }
            other => panic!("wrong variant {other:?}"),
        }
        // minimal reply: no prometheus text, no traces
        let bare = Response::Metrics(MetricsReply {
            id: 13,
            snapshot: MetricsSnapshot::default(),
            prometheus: None,
            traces: None,
        });
        match parse_response(&encode_response(&bare)).unwrap() {
            Response::Metrics(r) => {
                assert_eq!(r.snapshot, MetricsSnapshot::default());
                assert!(r.prometheus.is_none() && r.traces.is_none());
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn per_worker_stats_roundtrip() {
        // request flag survives the wire both ways
        let line = encode_request(&Request::Stats { id: 4, per_worker: true });
        assert!(line.contains("per_worker"));
        match parse_request(&line).unwrap() {
            Request::Stats { id, per_worker } => assert!(id == 4 && per_worker),
            other => panic!("wrong variant {other:?}"),
        }
        let line = encode_request(&Request::Stats { id: 4, per_worker: false });
        assert!(!line.contains("per_worker"), "flag absent when false: {line}");

        let worker = |addr: &str, queries: u64| WorkerStat {
            addr: addr.to_string(),
            generation: 2,
            n_samples: 64,
            stats: ServiceStats { queries, ..ServiceStats::default() },
        };
        let per_worker = vec![worker("127.0.0.1:7501", 5), worker("127.0.0.1:7502", 7)];
        let resp = Response::Stats(StatsReply {
            id: 4,
            generation: 0x2,
            n_samples: 128,
            k: 16,
            checkpoints: 2,
            bits: 4,
            stats: ServiceStats { queries: 12, ..ServiceStats::default() },
            per_worker: Some(per_worker.clone()),
        });
        match parse_response(&encode_response(&resp)).unwrap() {
            Response::Stats(r) => {
                assert_eq!(r.per_worker, Some(per_worker));
                assert_eq!(r.stats.queries, 12);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }
}
