//! The std-only TCP front end (`qless serve`) and its line client.
//!
//! Request lifecycle: **accept → admit → coalesce → fused scan → top-k →
//! respond.** A blocking accept loop hands each connection to a
//! fixed-size handler pool (`util::pool::TaskPool`, bounded queue =
//! accept-loop backpressure); handlers parse JSON lines (`proto`),
//! validate score queries against the served store's geometry, and admit
//! them to the [`Batcher`], which coalesces concurrent queries into fused
//! [`crate::influence::MultiScan`] passes over the warm [`Session`].
//! Responses go back in request order per connection, so clients may
//! pipeline.
//!
//! Shutdown (a `shutdown` request or [`Server::stop`]) is cooperative and
//! bounded: the accept loop exits on its next wakeup, handlers poll the
//! shutdown flag between 200ms read timeouts, and the batcher drains
//! queued queries before joining — no request that got a queue slot is
//! dropped.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::datastore::Header;
use crate::grads::FeatureMatrix;
use crate::select::top_k_scored_since;
use crate::util::obs::{self, SpanRecord};
use crate::util::pool::TaskPool;
use crate::{info, warn_};

use super::batcher::{Batcher, BatcherOpts};
use super::proto::{
    self, CascadeField, MetricsReply, Request, Response, ScoreReply, ScoreRequest, StatsReply,
    TraceField,
};
use super::session::{CascadePlan, ScoreQuery, ServiceStats, Session, SessionOpts};

/// Tuning of `qless serve`. CLI flags map 1:1 onto these fields; the top
/// crate's `Config::serve_opts()` does the mapping.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Bind address, `host:port` (port 0 = kernel-assigned ephemeral).
    pub addr: String,
    /// Micro-batch admission window in milliseconds (see `batcher`).
    pub batch_window_ms: u64,
    /// Most validation tasks fused into one scan pass.
    pub max_batch_tasks: usize,
    /// Fixed rows per scan shard; 0 = derive from `mem_budget_mb`.
    pub shard_rows: usize,
    /// Shard-cache budget in MiB (also bounds the streaming shard size).
    pub mem_budget_mb: usize,
    /// Score-cache capacity in entries; 0 disables.
    pub score_cache_entries: usize,
    /// Connection-handler threads (= max concurrent connections served;
    /// further connections queue on the handler pool).
    pub workers: usize,
    /// Bound of the admission queue and the handler-pool queue.
    pub queue_cap: usize,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            addr: "127.0.0.1:7411".into(),
            batch_window_ms: 2,
            max_batch_tasks: 16,
            shard_rows: 0,
            mem_budget_mb: crate::DEFAULT_MEM_BUDGET_MB,
            score_cache_entries: 64,
            workers: 8,
            queue_cap: 256,
        }
    }
}

/// Everything a connection handler needs, shared behind one `Arc`. The
/// header's geometry fields (`k`, `n_checkpoints`, precision) are
/// ingest-invariant, so admission validation needs no lock; generation
/// and live row count come from the batcher's published view.
struct Ctx {
    batcher: Batcher,
    header: Header,
    shutdown: AtomicBool,
    addr: SocketAddr,
}

/// Set the shutdown flag and nudge the (blocking) accept loop awake with a
/// throwaway connection. Idempotent. An unspecified bind IP (0.0.0.0 / ::)
/// is not connectable, so the nudge aims at loopback on the same port;
/// should the connect fail anyway (fd exhaustion), the flag still ends the
/// loop on the next real connection.
fn trigger_shutdown(ctx: &Ctx) {
    ctx.shutdown.store(true, Ordering::SeqCst);
    let mut target = ctx.addr;
    if target.ip().is_unspecified() {
        let loopback: std::net::IpAddr = if target.is_ipv4() {
            std::net::Ipv4Addr::LOCALHOST.into()
        } else {
            std::net::Ipv6Addr::LOCALHOST.into()
        };
        target.set_ip(loopback);
    }
    let _ = TcpStream::connect(target);
}

/// A running `qless serve` instance. Dropping it (or calling
/// [`Server::stop`] then [`Server::join`]) shuts the whole stack down
/// deterministically.
pub struct Server {
    ctx: Arc<Ctx>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Open `datastore` into a warm [`Session`], bind the listener, and
    /// start the accept loop + handler pool + batcher worker.
    pub fn start(datastore: &Path, opts: ServeOpts) -> Result<Server> {
        let session = Session::open(
            datastore,
            SessionOpts {
                shard_rows: opts.shard_rows,
                mem_budget_mb: opts.mem_budget_mb,
                score_cache_entries: opts.score_cache_entries,
            },
        )?;
        let header = *session.header();
        let listener = TcpListener::bind(opts.addr.as_str())
            .with_context(|| format!("binding {}", opts.addr))?;
        let addr = listener.local_addr()?;
        let batcher = Batcher::new(
            session,
            BatcherOpts {
                window: Duration::from_millis(opts.batch_window_ms),
                max_batch: opts.max_batch_tasks,
                queue_cap: opts.queue_cap,
            },
        );
        let ctx = Arc::new(Ctx { batcher, header, shutdown: AtomicBool::new(false), addr });
        let pool = TaskPool::new("qless-conn", opts.workers, opts.queue_cap);
        info!(
            "serve: listening on {addr} ({} handler threads, window {}ms, max batch {})",
            pool.workers(),
            opts.batch_window_ms,
            opts.max_batch_tasks
        );
        let accept = std::thread::Builder::new()
            .name("qless-accept".into())
            .spawn({
                let ctx = Arc::clone(&ctx);
                move || {
                    for conn in listener.incoming() {
                        if ctx.shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        match conn {
                            Ok(stream) => {
                                let ctx = Arc::clone(&ctx);
                                if pool.execute(move || handle_conn(stream, ctx)).is_err() {
                                    break;
                                }
                            }
                            Err(e) => warn_!("accept error: {e}"),
                        }
                    }
                    // joins handlers (they exit ≤ one read-timeout after
                    // the flag), then drains + joins the batcher
                    drop(pool);
                    ctx.batcher.close();
                }
            })
            .expect("spawning accept thread");
        Ok(Server { ctx, accept: Some(accept) })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.ctx.addr
    }

    /// The served store's header (`n_samples` is the base store's row
    /// count at open; [`Server::n_rows`] is the live total).
    pub fn header(&self) -> &Header {
        &self.ctx.header
    }

    /// The manifest generation currently served, as of the most recently
    /// scored batch (an ingest is picked up by the scoring worker without
    /// a restart).
    pub fn generation(&self) -> u64 {
        self.ctx.batcher.view().generation
    }

    /// Total rows currently served (base + ingested segments), as of the
    /// most recently scored batch.
    pub fn n_rows(&self) -> usize {
        self.ctx.batcher.view().rows as usize
    }

    /// Cumulative service statistics (snapshot as of the last batch).
    pub fn stats(&self) -> ServiceStats {
        self.ctx.batcher.stats()
    }

    /// Begin shutdown without blocking (the wire `shutdown` op calls the
    /// same path). Use [`Server::join`] to wait for completion.
    pub fn stop(&self) {
        trigger_shutdown(&self.ctx);
    }

    /// Block until the server has fully shut down (accept loop exited,
    /// handlers joined, batcher drained).
    pub fn join(mut self) -> Result<()> {
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        trigger_shutdown(&self.ctx);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Most bytes one request line may hold — far above any sane query (a
/// paper-scale k = 8192 task with 32 val rows per checkpoint × 4
/// checkpoints is ~20 MB of JSON), but it bounds what one connection can
/// make the resident server buffer.
const MAX_LINE_BYTES: usize = 64 << 20;

/// Serve one connection: JSON-lines request/response until EOF, a fatal
/// I/O error, or shutdown — shared by the single-node server and the
/// scatter-gather coordinator (`super::coordinator`), which differ only
/// in how a line becomes a response. Read timeouts bound how long a quiet
/// keep-alive connection can delay shutdown; a partial line survives
/// timeouts intact; a line over [`MAX_LINE_BYTES`] gets an error response
/// and the connection is dropped (there is no way to resync mid-line).
/// `on_shutdown` fires once, after a `ShuttingDown` ack has been flushed.
pub(crate) fn serve_lines(
    stream: TcpStream,
    shutdown: &AtomicBool,
    dispatch: &dyn Fn(&str) -> Response,
    on_shutdown: &dyn Fn(),
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    let mut line = String::new();
    loop {
        // cap the total line length across timeout retries: the +1 lets an
        // oversized line be detected as > MAX rather than silently clipped
        let budget = (MAX_LINE_BYTES + 1).saturating_sub(line.len()) as u64;
        match (&mut reader).take(budget).read_line(&mut line) {
            Ok(0) => return, // EOF
            Ok(_) => {
                if line.len() > MAX_LINE_BYTES {
                    let resp = Response::Error {
                        id: 0,
                        error: format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                    };
                    let mut out = proto::encode_response(&resp);
                    out.push('\n');
                    let _ = writer.write_all(out.as_bytes());
                    let _ = writer.flush();
                    return;
                }
                // under the cap, a missing trailing newline means EOF —
                // serve this final request, then close
                let eof = !line.ends_with('\n');
                if !line.trim().is_empty() {
                    let resp = dispatch(&line);
                    let shutting_down = matches!(resp, Response::ShuttingDown { .. });
                    let mut out = proto::encode_response(&resp);
                    out.push('\n');
                    if writer.write_all(out.as_bytes()).is_err() || writer.flush().is_err() {
                        return;
                    }
                    if shutting_down {
                        on_shutdown();
                        return;
                    }
                }
                if eof {
                    return;
                }
                line.clear();
                // re-check after every served request too: a continuously
                // active connection must not stall shutdown past one request
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // idle poll: any bytes read before the timeout stay in
                // `line` and the next read continues the same request
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

/// Serve one single-node connection (see [`serve_lines`]).
fn handle_conn(stream: TcpStream, ctx: Arc<Ctx>) {
    serve_lines(
        stream,
        &ctx.shutdown,
        &|line| handle_line(line, &ctx),
        &|| trigger_shutdown(&ctx),
    );
}

/// Dispatch one request line to a response (never panics; every failure
/// becomes an error response).
fn handle_line(line: &str, ctx: &Ctx) -> Response {
    let req = match proto::parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            return Response::Error {
                id: proto::salvage_id(line),
                error: format!("bad request: {e:#}"),
            }
        }
    };
    match req {
        Request::Ping { id } => Response::Pong { id },
        Request::Shutdown { id } => Response::ShuttingDown { id },
        // a single-node server has no per-worker breakdown to offer, so the
        // flag is accepted (coordinator requests pass through verbatim) and
        // the reply simply omits the array
        Request::Stats { id, .. } => {
            let view = ctx.batcher.view();
            Response::Stats(StatsReply {
                id,
                generation: view.generation,
                n_samples: view.rows as usize,
                k: ctx.header.k as usize,
                checkpoints: ctx.header.n_checkpoints as usize,
                bits: ctx.header.precision.bits,
                stats: view.stats,
                per_worker: None,
            })
        }
        Request::Metrics { id, traces, prometheus } => {
            let reg = obs::reg();
            let snapshot = reg.snapshot();
            Response::Metrics(MetricsReply {
                id,
                prometheus: prometheus.then(|| snapshot.prometheus()),
                traces: traces.then(|| reg.recent_spans(obs::SPAN_RING_CAP)),
                snapshot,
            })
        }
        Request::Score(r) => handle_score(r, ctx),
    }
}

/// Build the reply `timing` for a traced score request: a root span for
/// the whole server-side handling and a child covering the batcher wait
/// (queue + coalescing window + fused scan). Both are measured directly —
/// attribution inside a fused batch is the batch's, not the request's, so
/// the server reports only what it can measure truthfully per request.
/// Offsets are relative to this hop's request start (`t0`).
fn score_timing(
    trace: TraceField,
    reg: &obs::Registry,
    t0: u64,
    wait0: u64,
    done: u64,
) -> Vec<SpanRecord> {
    let root = obs::next_id();
    let spans = vec![
        SpanRecord {
            name: "server.score".into(),
            trace: trace.id,
            id: root,
            parent: trace.parent,
            start_us: 0,
            dur_us: done.saturating_sub(t0),
        },
        SpanRecord {
            name: "server.wait".into(),
            trace: trace.id,
            id: obs::next_id(),
            parent: root,
            start_us: wait0.saturating_sub(t0),
            dur_us: done.saturating_sub(wait0),
        },
    ];
    if obs::tracing_enabled() {
        for s in &spans {
            reg.record_span(s.clone());
        }
    }
    spans
}

fn handle_score(req: ScoreRequest, ctx: &Ctx) -> Response {
    let ScoreRequest {
        id,
        top_k,
        want_scores,
        since_gen,
        rows: wire_rows,
        val,
        cascade,
        nprobe,
        clusters,
        trace,
    } = req;
    let reg = obs::reg();
    let t0 = reg.now_us();
    let query = ScoreQuery { val };
    if let Err(e) = query.validate(&ctx.header) {
        return Response::Error { id, error: format!("invalid query: {e:#}") };
    }
    let rows = wire_rows.map(|(s, l)| (s as usize, l as usize));
    // The `nprobe`/`cascade` fields pick the scan strategy; every variant
    // still funnels through the batcher so concurrent same-shape requests
    // fuse. (`nprobe` with `scores`/`since_gen`/`rows` was already
    // rejected at parse time — see proto.)
    let submitted = if let Some(p) = nprobe {
        let p = p as usize;
        let window = clusters.map(|(s, l)| (s as usize, l as usize));
        if top_k == 0 {
            let error = "indexed scoring needs top_k >= 1 final selections per task".into();
            return Response::Error { id, error };
        }
        match &cascade {
            None => ctx.batcher.submit_index(query, p, top_k, window),
            Some(CascadeField::Full { probe, rerank, mult }) => {
                if window.is_some() {
                    let error = "'clusters' cannot be combined with a cascade; \
                                 coordinators partition plain indexed scans only"
                        .into();
                    return Response::Error { id, error };
                }
                let plan = CascadePlan { probe: *probe, rerank: *rerank, mult: *mult };
                ctx.batcher.submit_index_cascade(query, plan, top_k, p)
            }
            Some(_) => {
                let error = "'nprobe' combines only with a full cascade \
                             (stage verbs carry rows, not clusters)"
                    .into();
                return Response::Error { id, error };
            }
        }
    } else {
        match &cascade {
            None => ctx.batcher.submit_ranged(query, rows),
            Some(CascadeField::Full { probe, rerank, mult }) => {
                if top_k == 0 {
                    let error = "cascade needs top_k >= 1 final selections per task".into();
                    return Response::Error { id, error };
                }
                if want_scores {
                    let error = "a cascade reply carries only the reranked top list; \
                                 drop 'want_scores' or score exhaustively"
                        .into();
                    return Response::Error { id, error };
                }
                if since_gen.is_some() {
                    let error = "cascade cannot be combined with 'since_gen'; \
                                 score the new rows exhaustively instead"
                        .into();
                    return Response::Error { id, error };
                }
                if rows.is_some() {
                    let error = "a full cascade request cannot carry a 'rows' range; \
                                 coordinators split cascades into probe/rerank stage verbs"
                        .into();
                    return Response::Error { id, error };
                }
                let plan = CascadePlan { probe: *probe, rerank: *rerank, mult: *mult };
                ctx.batcher.submit_cascade(query, plan, top_k)
            }
            Some(CascadeField::Probe { probe }) => match rows {
                None => {
                    let error = "a probe-stage request must carry a 'rows' range".into();
                    return Response::Error { id, error };
                }
                Some((start, len)) => ctx.batcher.submit_probe(query, start, len, *probe),
            },
            Some(CascadeField::Rerank { rerank, rows: row_list }) => {
                if rows.is_some() {
                    let error = "a rerank-stage request carries its rows in 'rows_list', \
                                 not a 'rows' range"
                        .into();
                    return Response::Error { id, error };
                }
                ctx.batcher.submit_rerank(query, Arc::new(row_list.clone()), *rerank)
            }
        }
    };
    let rx = match submitted {
        Ok(rx) => rx,
        Err(e) => return Response::Error { id, error: format!("{e:#}") },
    };
    let wait0 = reg.now_us();
    match rx.recv() {
        Ok(Ok(ans)) => {
            let done = reg.now_us();
            reg.observe_us("score_us", done.saturating_sub(t0));
            let timing = trace.map(|t| score_timing(t, &reg, t0, wait0, done));
            // indexed, full-cascade, and rerank-stage answers carry their
            // ranked / scored pairs in `ans.top`; nothing to rank
            // server-side
            if nprobe.is_some()
                || matches!(
                    cascade,
                    Some(CascadeField::Full { .. }) | Some(CascadeField::Rerank { .. })
                )
            {
                return Response::Score(ScoreReply {
                    id,
                    generation: ans.generation,
                    cached: ans.cached,
                    batched: ans.batched,
                    pass: ans.pass,
                    rows: None,
                    top: ans.top.unwrap_or_default(),
                    scores: None,
                    timing,
                });
            }
            let (top, scores) = match rows {
                None => {
                    // `since_gen` restricts the top list to rows newer
                    // than the named generation (resolved against the
                    // answer's own member map, so it cannot race a
                    // concurrent ingest)
                    let first_row = match since_gen {
                        None => 0,
                        Some(g) => ans.first_row_after(g),
                    };
                    let top = top_k_scored_since(&ans.scores, top_k, first_row);
                    (top, want_scores.then(|| ans.scores.as_ref().clone()))
                }
                Some((start, len)) => {
                    // ranged (worker) answer: `ans.scores[j]` is global
                    // row `start + j`; rank the local slice and lift the
                    // winners back to global indices so a coordinator can
                    // merge per-worker tops directly
                    let first_global = match since_gen {
                        None => start,
                        Some(g) => ans
                            .gen_rows
                            .iter()
                            .filter(|(g2, _)| *g2 > g)
                            .map(|(_, row)| *row)
                            .min()
                            .unwrap_or(start + len)
                            .max(start),
                    };
                    let from_local = (first_global - start).min(len);
                    let mut top = top_k_scored_since(&ans.scores, top_k, from_local);
                    for entry in &mut top {
                        entry.0 += start;
                    }
                    (top, want_scores.then(|| ans.scores.as_ref().clone()))
                }
            };
            Response::Score(ScoreReply {
                id,
                generation: ans.generation,
                cached: ans.cached,
                batched: ans.batched,
                pass: ans.pass,
                rows: wire_rows,
                top,
                scores,
                timing,
            })
        }
        Ok(Err(msg)) => Response::Error { id, error: msg },
        Err(_) => Response::Error { id, error: "scoring worker unavailable".into() },
    }
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// A blocking JSON-lines client for the service — used by the e2e tests,
/// the load-generator bench, and scriptable from anything that can speak
/// the wire format in `proto`.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    trace: Option<TraceField>,
}

impl Client {
    /// Connect to a running server.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to qless serve")?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: stream, next_id: 0, trace: None })
    }

    /// [`Client::connect`] with `deadline` bounding connection
    /// establishment **and** installed as the socket read/write timeout —
    /// the coordinator's worker-facing constructor, so one dead or
    /// wedged worker can stall a scatter by at most the deadline.
    pub fn connect_deadline<A: ToSocketAddrs>(addr: A, deadline: Duration) -> Result<Client> {
        let mut last: Option<std::io::Error> = None;
        for a in addr.to_socket_addrs().context("resolving server address")? {
            match TcpStream::connect_timeout(&a, deadline) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    stream.set_read_timeout(Some(deadline))?;
                    stream.set_write_timeout(Some(deadline))?;
                    let reader = BufReader::new(stream.try_clone()?);
                    return Ok(Client { reader, writer: stream, next_id: 0, trace: None });
                }
                Err(e) => last = Some(e),
            }
        }
        match last {
            Some(e) => Err(e).context("connecting to qless serve"),
            None => bail!("address resolved to nothing"),
        }
    }

    fn bump(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    /// Attach this trace identity to every subsequent score request
    /// (`None` clears it); traced replies carry per-stage `timing` spans
    /// (PROTOCOL.md §Trace propagation). The coordinator sets a fresh
    /// parent per sub-query so a fan-out stitches into one tree.
    pub fn set_trace(&mut self, trace: Option<TraceField>) {
        self.trace = trace;
    }

    /// Bound every subsequent socket read and write (`None` = block
    /// forever). The coordinator uses this as its per-request worker
    /// deadline. A timed-out roundtrip leaves the connection
    /// desynchronized — drop the client and reconnect.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        let mut line = proto::encode_request(req);
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        let mut resp = String::new();
        if self.reader.read_line(&mut resp)? == 0 {
            bail!("server closed the connection");
        }
        proto::parse_response(&resp)
    }

    /// Score one validation task (raw per-checkpoint features); ask for
    /// `top_k` ranked indices and optionally the full score vector.
    pub fn score(
        &mut self,
        val: &[FeatureMatrix],
        top_k: usize,
        want_scores: bool,
    ) -> Result<ScoreReply> {
        self.score_since(val, top_k, want_scores, None)
    }

    /// [`Client::score`] with an optional generation filter: with
    /// `since_gen = Some(g)`, the returned top list ranks **only rows
    /// newer than generation g** (incremental selection after an ingest).
    /// The full score vector, when requested, is always complete.
    pub fn score_since(
        &mut self,
        val: &[FeatureMatrix],
        top_k: usize,
        want_scores: bool,
        since_gen: Option<u64>,
    ) -> Result<ScoreReply> {
        self.score_rows(val, top_k, want_scores, since_gen, None)
    }

    /// The full-knob score call: [`Client::score_since`] plus an optional
    /// global row range — the verb a scatter-gather coordinator issues to
    /// its workers. With `rows = Some((start, len))` the server scores
    /// only rows `start .. start + len`; `top` indices are global, and a
    /// requested score vector covers only the range (`scores[j]` is row
    /// `start + j`).
    pub fn score_rows(
        &mut self,
        val: &[FeatureMatrix],
        top_k: usize,
        want_scores: bool,
        since_gen: Option<u64>,
        rows: Option<(u64, u64)>,
    ) -> Result<ScoreReply> {
        self.score_req(ScoreRequest {
            id: 0,
            top_k,
            want_scores,
            since_gen,
            rows,
            val: val.to_vec(),
            cascade: None,
            nprobe: None,
            clusters: None,
            trace: None,
        })
    }

    /// Indexed (IVF) score: the server probes its `.qidx` sidecar's
    /// centroids, scans only the `nprobe` closest clusters per task, and
    /// returns the top-`top_k` list from the scanned rows. `nprobe >=` the
    /// sidecar's cluster count makes the result byte-identical to an
    /// exhaustive scan; a server without a sidecar answers exhaustively
    /// (and says so in its `index_fallbacks` stat).
    pub fn score_index(
        &mut self,
        val: &[FeatureMatrix],
        top_k: usize,
        nprobe: u32,
    ) -> Result<ScoreReply> {
        self.score_req(ScoreRequest {
            id: 0,
            top_k,
            want_scores: false,
            since_gen: None,
            rows: None,
            val: val.to_vec(),
            cascade: None,
            nprobe: Some(nprobe),
            clusters: None,
            trace: None,
        })
    }

    /// Cluster-window worker verb: like [`Client::score_index`], but scan
    /// only positions `window.0 .. window.0 + window.1` of the per-task
    /// probed cluster list — the verb a coordinator issues after
    /// partitioning the cluster list (not the row space) across workers.
    /// Requires a sidecar on the server; there is no exhaustive fallback
    /// for a window.
    pub(crate) fn score_index_clusters(
        &mut self,
        val: &[FeatureMatrix],
        keep: usize,
        nprobe: u32,
        window: (u64, u64),
    ) -> Result<ScoreReply> {
        self.score_req(ScoreRequest {
            id: 0,
            top_k: keep,
            want_scores: false,
            since_gen: None,
            rows: None,
            val: val.to_vec(),
            cascade: None,
            nprobe: Some(nprobe),
            clusters: Some(window),
            trace: None,
        })
    }

    /// Index-restricted cascade: the 1-bit probe stage scans only the
    /// `nprobe` closest clusters (instead of every live row), the exact
    /// `rerank`-bit stage is unchanged. `nprobe >=` the cluster count
    /// degenerates to [`Client::score_cascade`] exactly.
    pub fn score_index_cascade(
        &mut self,
        val: &[FeatureMatrix],
        top_k: usize,
        probe: u8,
        rerank: u8,
        mult: usize,
        nprobe: u32,
    ) -> Result<ScoreReply> {
        self.score_req(ScoreRequest {
            id: 0,
            top_k,
            want_scores: false,
            since_gen: None,
            rows: None,
            val: val.to_vec(),
            cascade: Some(CascadeField::Full { probe, rerank, mult }),
            nprobe: Some(nprobe),
            clusters: None,
            trace: None,
        })
    }

    /// Two-stage cascade score: the server probes **every** live row at
    /// `probe` bits, keeps the `mult · top_k` best candidates per task,
    /// re-scores only those at `rerank` bits, and returns the reranked
    /// top-`top_k` list. Both precisions must exist as sibling stores in
    /// the served run directory (build the run with `--bits` listing
    /// them). `mult · top_k >=` the live row count makes the result
    /// byte-identical to an exhaustive `rerank`-bit scan.
    pub fn score_cascade(
        &mut self,
        val: &[FeatureMatrix],
        top_k: usize,
        probe: u8,
        rerank: u8,
        mult: usize,
    ) -> Result<ScoreReply> {
        self.score_req(ScoreRequest {
            id: 0,
            top_k,
            want_scores: false,
            since_gen: None,
            rows: None,
            val: val.to_vec(),
            cascade: Some(CascadeField::Full { probe, rerank, mult }),
            nprobe: None,
            clusters: None,
            trace: None,
        })
    }

    /// Probe-stage worker verb (coordinator wave 1): scan only rows
    /// `start .. start + len` at `probe` bits and return the range's
    /// top-`keep` candidates as global indices.
    pub(crate) fn score_probe(
        &mut self,
        val: &[FeatureMatrix],
        keep: usize,
        rows: (u64, u64),
        probe: u8,
    ) -> Result<ScoreReply> {
        self.score_req(ScoreRequest {
            id: 0,
            top_k: keep,
            want_scores: false,
            since_gen: None,
            rows: Some(rows),
            val: val.to_vec(),
            cascade: Some(CascadeField::Probe { probe }),
            nprobe: None,
            clusters: None,
            trace: None,
        })
    }

    /// Rerank-stage worker verb (coordinator wave 2): score exactly the
    /// listed global rows (strictly increasing) at `rerank` bits; the
    /// reply's `top` holds every listed row with its score, in list order.
    pub(crate) fn score_rerank(
        &mut self,
        val: &[FeatureMatrix],
        rows: Vec<usize>,
        rerank: u8,
    ) -> Result<ScoreReply> {
        self.score_req(ScoreRequest {
            id: 0,
            top_k: 0,
            want_scores: false,
            since_gen: None,
            rows: None,
            val: val.to_vec(),
            cascade: Some(CascadeField::Rerank { rerank, rows }),
            nprobe: None,
            clusters: None,
            trace: None,
        })
    }

    fn score_req(&mut self, mut req: ScoreRequest) -> Result<ScoreReply> {
        let id = self.bump();
        req.id = id;
        if req.trace.is_none() {
            req.trace = self.trace;
        }
        match self.roundtrip(&Request::Score(req))? {
            Response::Score(r) => {
                anyhow::ensure!(r.id == id, "response id {} for request {id}", r.id);
                Ok(r)
            }
            Response::Error { error, .. } => bail!("server error: {error}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Fetch the service's cumulative statistics.
    pub fn stats(&mut self) -> Result<StatsReply> {
        self.stats_detail(false)
    }

    /// [`Client::stats`] with `per_worker = true` asking a coordinator to
    /// include its per-worker breakdown (single-node servers ignore the
    /// flag and the reply's `per_worker` stays `None`).
    pub fn stats_detail(&mut self, per_worker: bool) -> Result<StatsReply> {
        let id = self.bump();
        match self.roundtrip(&Request::Stats { id, per_worker })? {
            Response::Stats(r) => Ok(r),
            Response::Error { error, .. } => bail!("server error: {error}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Scrape the server's metrics registry (PROTOCOL.md §Metrics):
    /// counters, gauges and latency histograms, plus the Prometheus text
    /// rendering and/or the recent-span ring on request. Against a
    /// coordinator this returns the fleet-merged registry.
    pub fn metrics(&mut self, traces: bool, prometheus: bool) -> Result<MetricsReply> {
        let id = self.bump();
        match self.roundtrip(&Request::Metrics { id, traces, prometheus })? {
            Response::Metrics(r) => Ok(r),
            Response::Error { error, .. } => bail!("server error: {error}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        let id = self.bump();
        match self.roundtrip(&Request::Ping { id })? {
            Response::Pong { .. } => Ok(()),
            Response::Error { error, .. } => bail!("server error: {error}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Ask the server to shut down (acknowledged before it begins).
    pub fn shutdown(&mut self) -> Result<()> {
        let id = self.bump();
        match self.roundtrip(&Request::Shutdown { id })? {
            Response::ShuttingDown { .. } => Ok(()),
            Response::Error { error, .. } => bail!("server error: {error}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Send one raw line (malformed-input testing); returns the raw
    /// response line.
    pub fn raw_roundtrip(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut resp = String::new();
        if self.reader.read_line(&mut resp)? == 0 {
            bail!("server closed the connection");
        }
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Precision, Scheme};
    use crate::util::prop::{normal_features as feats, seeded_datastore};
    use std::path::PathBuf;

    fn build_store(tag: &str, n: usize, k: usize, ckpts: usize) -> PathBuf {
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let path = std::env::temp_dir().join(format!(
            "qless_server_{tag}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ));
        seeded_datastore(&path, p, n, k, &vec![0.5f32; ckpts], 0);
        path
    }

    fn ephemeral_opts() -> ServeOpts {
        ServeOpts {
            addr: "127.0.0.1:0".into(),
            batch_window_ms: 0,
            workers: 4,
            ..Default::default()
        }
    }

    #[test]
    fn serve_score_stats_ping_shutdown() {
        let (n, k) = (16usize, 64usize);
        let path = build_store("basic", n, k, 1);
        let server = Server::start(&path, ephemeral_opts()).unwrap();
        let addr = server.addr();
        let mut c = Client::connect(addr).unwrap();
        c.ping().unwrap();
        let st = c.stats().unwrap();
        assert_eq!(st.n_samples, n);
        assert_eq!(st.k, k);
        assert_eq!(st.checkpoints, 1);
        assert_eq!(st.bits, 4);
        assert_eq!(st.generation, server.generation());
        let val = vec![feats(2, k, 9)];
        let r = c.score(&val, 3, true).unwrap();
        assert_eq!(r.top.len(), 3);
        let scores = r.scores.unwrap();
        assert_eq!(scores.len(), n);
        // the top list is consistent with the full vector
        assert_eq!(r.top, crate::select::top_k_scored(&scores, 3));
        // same task again: score-cache hit
        let r2 = c.score(&val, 3, false).unwrap();
        assert!(r2.cached);
        assert!(r2.scores.is_none());
        assert_eq!(r2.top, r.top);
        c.shutdown().unwrap();
        server.join().unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn serve_cascade_matches_exhaustive_rerank_scan() {
        let dir = std::env::temp_dir().join(format!(
            "qless_server_casc_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let (n, k) = (16usize, 64usize);
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        let probe_path = crate::datastore::default_store_path(&dir, p1);
        let rerank_path = crate::datastore::default_store_path(&dir, p8);
        seeded_datastore(&probe_path, p1, n, k, &[0.7, 0.3], 0);
        seeded_datastore(&rerank_path, p8, n, k, &[0.7, 0.3], 0);
        let server = Server::start(&probe_path, ephemeral_opts()).unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        let val = vec![feats(2, k, 9), feats(2, k, 10)];
        // mult 8 · top_k 4 = 32 candidates >= 16 rows → exact cascade
        let r = c.score_cascade(&val, 4, 1, 8, 8).unwrap();
        assert_eq!(r.top.len(), 4);
        assert!(r.scores.is_none() && r.rows.is_none());
        let server8 = Server::start(&rerank_path, ephemeral_opts()).unwrap();
        let mut c8 = Client::connect(server8.addr()).unwrap();
        let full = c8.score(&val, 4, true).unwrap();
        for (got, want) in r.top.iter().zip(full.top.iter()) {
            assert_eq!(got.0, want.0);
            assert_eq!(got.1.to_bits(), want.1.to_bits(), "cascade must be bit-exact");
        }
        // stage verbs over the wire: probe a range, rerank a row list
        let rp = c.score_probe(&val, 3, (2, 9), 1).unwrap();
        assert_eq!(rp.top.len(), 3);
        assert!(rp.top.iter().all(|(i, _)| (2..11).contains(i)), "{:?}", rp.top);
        let rr = c.score_rerank(&val, vec![1, 4, 9], 8).unwrap();
        let scores = full.scores.unwrap();
        assert_eq!(rr.top.iter().map(|(i, _)| *i).collect::<Vec<_>>(), vec![1, 4, 9]);
        for (i, s) in &rr.top {
            assert_eq!(s.to_bits(), scores[*i].to_bits());
        }
        // rerank precision absent from the run dir → clean error, not a
        // silent fallback
        let err = c.score_cascade(&val, 4, 1, 16, 8).unwrap_err();
        assert!(format!("{err:#}").contains("16-bit"), "{err:#}");
        c.shutdown().unwrap();
        server.join().unwrap();
        c8.shutdown().unwrap();
        server8.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_indexed_score_matches_exhaustive_and_partitions_clusters() {
        let (n, k) = (32usize, 64usize);
        let path = build_store("index", n, k, 2);
        crate::datastore::reindex_store(
            &path,
            crate::datastore::IndexBuildOpts { n_clusters: 4, max_iters: 4 },
        )
        .unwrap();
        let server = Server::start(&path, ephemeral_opts()).unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        let val = vec![feats(2, k, 9), feats(2, k, 10)];
        let full = c.score(&val, 5, false).unwrap();
        // nprobe = nclusters: full coverage must be byte-identical to the
        // exhaustive scan
        let r = c.score_index(&val, 5, 4).unwrap();
        assert!(r.scores.is_none() && r.rows.is_none());
        assert_eq!(r.top.len(), 5);
        for (got, want) in r.top.iter().zip(full.top.iter()) {
            assert_eq!(got.0, want.0);
            assert_eq!(got.1.to_bits(), want.1.to_bits(), "full coverage must be bit-exact");
        }
        // a sub-linear probe still answers a full-size top list
        let r1 = c.score_index(&val, 5, 1).unwrap();
        assert_eq!(r1.top.len(), 5);
        // worker windows over the cluster-list positions partition the
        // probed list; their merge equals the unpartitioned answer
        let w1 = c.score_index_clusters(&val, 5, 4, (0, 2)).unwrap();
        let w2 = c.score_index_clusters(&val, 5, 4, (2, 2)).unwrap();
        let merged = crate::select::merge_top_k(&[w1.top.clone(), w2.top.clone()], 5);
        assert_eq!(merged, r.top, "disjoint cluster windows must merge exactly");
        // the stats surface shows the sidecar and no fallbacks
        let st = c.stats().unwrap();
        assert_eq!(st.stats.index_clusters, 4);
        assert_eq!(st.stats.index_fallbacks, 0);
        assert!(st.stats.index_queries >= 4);
        // wire negatives (strict grammar): each rejected line leaves the
        // connection usable — no desync, no close
        let small_val = "\"val\":[{\"n\":1,\"k\":2,\"data\":[0.5,1]}]";
        for (bad, why) in [
            (format!("{{\"op\":\"score\",\"top_k\":2,\"nprobe\":0,{small_val}}}"), ">= 1"),
            (
                format!("{{\"op\":\"score\",\"top_k\":2,\"nprobe\":1.5,{small_val}}}"),
                "non-negative integer",
            ),
            (
                format!("{{\"op\":\"score\",\"top_k\":2,\"clusters\":[0,2],{small_val}}}"),
                "requires 'nprobe'",
            ),
            (
                format!(
                    "{{\"op\":\"score\",\"top_k\":2,\"nprobe\":2,\
                     \"cascade\":{{\"probe\":1,\"rerank\":8,\"nprobe\":3}},{small_val}}}"
                ),
                "unknown key 'nprobe'",
            ),
        ] {
            let raw = c.raw_roundtrip(&bad).unwrap();
            assert!(raw.contains("\"ok\":false"), "{raw}");
            assert!(raw.contains(why), "expected {why:?} in {raw}");
            c.ping().unwrap();
        }
        let again = c.score_index(&val, 5, 4).unwrap();
        assert_eq!(again.top, r.top, "connection stays usable after rejections");
        c.shutdown().unwrap();
        server.join().unwrap();
        std::fs::remove_file(crate::datastore::index_path(&path)).ok();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn serve_index_cascade_and_sidecar_free_fallback() {
        let dir = std::env::temp_dir().join(format!(
            "qless_server_idxcasc_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let (n, k) = (16usize, 64usize);
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        let probe_path = crate::datastore::default_store_path(&dir, p1);
        let rerank_path = crate::datastore::default_store_path(&dir, p8);
        seeded_datastore(&probe_path, p1, n, k, &[0.7, 0.3], 0);
        seeded_datastore(&rerank_path, p8, n, k, &[0.7, 0.3], 0);
        crate::datastore::reindex_store(
            &probe_path,
            crate::datastore::IndexBuildOpts { n_clusters: 4, max_iters: 4 },
        )
        .unwrap();
        let server = Server::start(&probe_path, ephemeral_opts()).unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        let val = vec![feats(2, k, 9), feats(2, k, 10)];
        // full coverage + exhaustive mult: the index-restricted cascade
        // degenerates to the plain cascade exactly
        let plain = c.score_cascade(&val, 4, 1, 8, 8).unwrap();
        let indexed = c.score_index_cascade(&val, 4, 1, 8, 8, 4).unwrap();
        for (got, want) in indexed.top.iter().zip(plain.top.iter()) {
            assert_eq!(got.0, want.0);
            assert_eq!(got.1.to_bits(), want.1.to_bits(), "index cascade must be bit-exact");
        }
        // nprobe composes with the full cascade only, never stage verbs
        let raw = c
            .raw_roundtrip(
                "{\"op\":\"score\",\"top_k\":2,\"nprobe\":2,\
                 \"cascade\":{\"stage\":\"rerank\",\"rerank\":8,\"rows_list\":[1]},\
                 \"val\":[{\"n\":1,\"k\":2,\"data\":[0.5,1]}]}",
            )
            .unwrap();
        assert!(raw.contains("\"ok\":false"), "{raw}");
        c.ping().unwrap();
        c.shutdown().unwrap();
        server.join().unwrap();
        // a server with no sidecar serves indexed requests exhaustively
        // (counted as fallbacks) and refuses only the windowed worker verb
        let server8 = Server::start(&rerank_path, ephemeral_opts()).unwrap();
        let mut c8 = Client::connect(server8.addr()).unwrap();
        let full = c8.score(&val, 4, false).unwrap();
        let fb = c8.score_index(&val, 4, 2).unwrap();
        assert_eq!(fb.top, full.top, "sidecar-free fallback is the exact exhaustive answer");
        let err = c8.score_index_clusters(&val, 4, 2, (0, 1)).unwrap_err();
        assert!(format!("{err:#}").contains("sidecar"), "{err:#}");
        c8.ping().unwrap();
        let st = c8.stats().unwrap();
        assert_eq!(st.stats.index_clusters, 0);
        assert!(st.stats.index_fallbacks >= 1);
        c8.shutdown().unwrap();
        server8.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_rejects_bad_lines_and_bad_queries() {
        let path = build_store("reject", 8, 64, 1);
        let server = Server::start(&path, ephemeral_opts()).unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        // malformed JSON → error response, connection stays usable
        let raw = c.raw_roundtrip("this is not json").unwrap();
        assert!(raw.contains("\"ok\":false"), "{raw}");
        // wrong feature dimension → validation error with the request id
        let bad = vec![feats(2, 32, 1)];
        let err = c.score(&bad, 0, false).unwrap_err();
        assert!(format!("{err:#}").contains("feature dim"), "{err:#}");
        // still alive
        c.ping().unwrap();
        server.stop();
        server.join().unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn serve_metrics_and_traced_score() {
        let (n, k) = (16usize, 64usize);
        let path = build_store("metrics", n, k, 1);
        let server = Server::start(&path, ephemeral_opts()).unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        c.set_trace(Some(TraceField { id: 0xabc, parent: 0 }));
        let val = vec![feats(2, k, 9)];
        let r = c.score(&val, 3, false).unwrap();
        let timing = r.timing.expect("traced request must carry timing");
        assert_eq!(timing.len(), 2);
        assert_eq!(timing[0].name, "server.score");
        assert_eq!(timing[1].name, "server.wait");
        assert_eq!(timing[1].parent, timing[0].id, "wait nests under the root");
        assert!(timing[0].dur_us >= timing[1].dur_us, "root covers the wait");
        c.set_trace(None);
        let r2 = c.score(&[feats(2, k, 10)], 3, false).unwrap();
        assert!(r2.timing.is_none(), "untraced requests carry no timing");
        // scrape: the in-process server shares this registry, so the two
        // scores above must be visible (>= because tests share the process)
        let m = c.metrics(false, true).unwrap();
        let h = m.snapshot.histos.get("score_us").expect("score_us histogram");
        assert!(h.count >= 2, "both scores observed, got {}", h.count);
        assert!(m.prometheus.unwrap().contains("qless_score_us_bucket"));
        assert!(m.traces.is_none(), "traces only on request");
        c.shutdown().unwrap();
        server.join().unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn drop_shuts_down_without_client_shutdown() {
        let path = build_store("drop", 8, 64, 1);
        let server = Server::start(&path, ephemeral_opts()).unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        c.ping().unwrap();
        drop(server); // must not hang despite the live keep-alive client
        std::fs::remove_file(path).ok();
    }
}
