//! Scatter-gather serving: a coordinator front end that speaks the same
//! JSON-lines protocol as `qless serve`, partitions the global row space
//! across N single-node workers, fans every score query out as ranged
//! sub-queries (the `rows` verb in `proto`), and merges the per-shard
//! answers back into one reply that is **bit-identical** to a single-node
//! scan of the whole store.
//!
//! Why this is exact and not approximate: influence scores are per-row
//! (each row's quantized dot products against the task, scaled by η and
//! summed over checkpoints), so scoring rows `[a, b)` on one worker and
//! `[b, c)` on another touches disjoint state — there is no cross-row
//! accumulation to re-order. Workers clip cached shards to their range
//! with a zero-copy `RowsView::slice`, so the fed bytes per row are the
//! bytes a single node would feed; the merged top-k uses the same
//! `(score desc, index asc)` comparator as `select::top_k_scored`
//! ([`crate::select::merge_top_k`]); and a stitched score vector is a
//! plain concatenation in range order.
//!
//! Generation consistency under live ingest rides the datastore's
//! append-only contract: rows never change once written and every
//! generation adds rows strictly at the end, so two workers that have
//! polled different generations of the **same** live store agree exactly
//! on every row they both serve. Per query the coordinator probes its
//! workers and serves `G = min(generation)`, `N = min(rows)` — the state
//! every reachable worker can answer for — and `since_gen` filters
//! resolve identically on every worker because the `(generation, row)`
//! boundaries are shared.
//!
//! Failure handling is **re-issue with retry-then-degrade**: a worker
//! that fails its probe or its sub-query is marked unhealthy and its row
//! range is re-issued to a surviving worker (any worker can serve any
//! range — they all hold the full store); after `retries` re-issue rounds
//! a still-unanswered range degrades the query to an error response — a
//! clean failure, never a silently truncated answer. A background health
//! loop pings every worker and restores ones that come back.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::influence::ScanStats;
use crate::select::{merge_top_k, top_k_scored_among};
use crate::util::obs::{self, SpanRecord};
use crate::util::pool::TaskPool;
use crate::{info, warn_};

use super::proto::{
    self, CascadeField, MetricsReply, Request, Response, ScoreReply, ScoreRequest, StatsReply,
    TraceField, WorkerStat,
};
use super::server::{serve_lines, Client, ServeOpts, Server};
use super::session::ServiceStats;

/// Tuning of the scatter-gather coordinator. CLI flags map 1:1 onto
/// these fields; the top crate's `Config::coordinator_opts()` does the
/// mapping.
#[derive(Debug, Clone)]
pub struct CoordinatorOpts {
    /// Bind address of the coordinator's own front end, `host:port`
    /// (port 0 = kernel-assigned ephemeral).
    pub addr: String,
    /// Worker addresses (`host:port` each). Every worker must serve the
    /// same live datastore (same geometry; generations may lag).
    pub workers: Vec<String>,
    /// Bound of the connection-handler pool's queue.
    pub queue_cap: usize,
    /// Per-request deadline for any one worker round trip (connect,
    /// send, receive); a worker that blows it is treated as failed.
    pub deadline: Duration,
    /// Re-issue rounds for a failed row range before the query degrades
    /// to an error response.
    pub retries: usize,
}

impl Default for CoordinatorOpts {
    fn default() -> CoordinatorOpts {
        CoordinatorOpts {
            addr: "127.0.0.1:7410".into(),
            workers: Vec::new(),
            queue_cap: 256,
            deadline: Duration::from_millis(2000),
            retries: 2,
        }
    }
}

/// One registered worker: its address plus the health flag the scatter
/// path and the background ping loop both maintain.
struct WorkerSlot {
    addr: String,
    healthy: AtomicBool,
}

/// Shared state of a running coordinator.
struct CoCtx {
    workers: Vec<WorkerSlot>,
    shutdown: AtomicBool,
    addr: SocketAddr,
    deadline: Duration,
    retries: usize,
    /// Geometry every worker agreed on at startup, for cheap local
    /// admission validation (`k`, checkpoints, bits).
    k: usize,
    checkpoints: usize,
    bits: u8,
}

/// Set the shutdown flag and nudge the blocking accept loop awake with a
/// throwaway connection (same trick as the single-node server).
fn trigger_shutdown(ctx: &CoCtx) {
    ctx.shutdown.store(true, Ordering::SeqCst);
    let mut target = ctx.addr;
    if target.ip().is_unspecified() {
        let loopback: std::net::IpAddr = if target.is_ipv4() {
            std::net::Ipv4Addr::LOCALHOST.into()
        } else {
            std::net::Ipv6Addr::LOCALHOST.into()
        };
        target.set_ip(loopback);
    }
    let _ = TcpStream::connect(target);
}

/// A running scatter-gather coordinator. In `--local-workers` mode it
/// also owns the worker [`Server`]s it spawned; dropping the coordinator
/// (or [`Coordinator::stop`] + [`Coordinator::join`]) shuts the whole
/// tree down deterministically.
pub struct Coordinator {
    ctx: Arc<CoCtx>,
    accept: Option<std::thread::JoinHandle<()>>,
    health: Option<std::thread::JoinHandle<()>>,
    local: Vec<Server>,
}

impl Coordinator {
    /// Start a coordinator over already-running workers listed in
    /// `opts.workers`. Every worker is probed once at startup; all must
    /// be reachable and agree on store geometry (`k`, checkpoint count,
    /// bitwidth) — refusing to start beats discovering a mismatched
    /// fleet one wrong answer at a time.
    pub fn start(opts: CoordinatorOpts) -> Result<Coordinator> {
        Coordinator::start_owning(opts, Vec::new())
    }

    /// Single-process scatter-gather: spawn `n_workers` full
    /// [`Server`]s on ephemeral loopback ports, all serving `datastore`,
    /// and a coordinator over them. This is the `qless serve
    /// --local-workers N` mode — the same code path as a distributed
    /// deployment (real sockets, real protocol), which is what lets the
    /// e2e suite property-test the merge against a single node.
    pub fn start_local(
        datastore: &Path,
        n_workers: usize,
        worker_opts: ServeOpts,
        mut opts: CoordinatorOpts,
    ) -> Result<Coordinator> {
        anyhow::ensure!(n_workers > 0, "--local-workers must be at least 1");
        let mut local = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            local.push(Server::start(
                datastore,
                ServeOpts { addr: "127.0.0.1:0".into(), ..worker_opts.clone() },
            )?);
        }
        opts.workers = local.iter().map(|w| w.addr().to_string()).collect();
        Coordinator::start_owning(opts, local)
    }

    fn start_owning(opts: CoordinatorOpts, local: Vec<Server>) -> Result<Coordinator> {
        anyhow::ensure!(!opts.workers.is_empty(), "coordinator needs at least one worker");
        let mut geom: Option<(usize, usize, u8)> = None;
        for addr in &opts.workers {
            let st = probe(addr, opts.deadline)
                .with_context(|| format!("probing worker {addr} at startup"))?;
            let g = (st.k, st.checkpoints, st.bits);
            match geom {
                None => geom = Some(g),
                Some(have) => anyhow::ensure!(
                    have == g,
                    "worker {addr} serves k={} / {} checkpoints / {}-bit, fleet serves \
                     k={} / {} checkpoints / {}-bit",
                    g.0,
                    g.1,
                    g.2,
                    have.0,
                    have.1,
                    have.2
                ),
            }
        }
        let (k, checkpoints, bits) = geom.expect("at least one worker probed");
        let listener = TcpListener::bind(opts.addr.as_str())
            .with_context(|| format!("binding coordinator {}", opts.addr))?;
        let addr = listener.local_addr()?;
        let ctx = Arc::new(CoCtx {
            workers: opts
                .workers
                .iter()
                .map(|a| WorkerSlot { addr: a.clone(), healthy: AtomicBool::new(true) })
                .collect(),
            shutdown: AtomicBool::new(false),
            addr,
            deadline: opts.deadline,
            retries: opts.retries,
            k,
            checkpoints,
            bits,
        });
        info!(
            "coordinator: listening on {addr} over {} worker(s) (k={k}, {checkpoints} \
             checkpoint(s), {bits}-bit, deadline {:?}, {} retries)",
            ctx.workers.len(),
            opts.deadline,
            opts.retries,
        );
        let health = std::thread::Builder::new()
            .name("qless-health".into())
            .spawn({
                let ctx = Arc::clone(&ctx);
                move || health_loop(&ctx)
            })
            .expect("spawning health thread");
        let pool = TaskPool::new("qless-coord", 8, opts.queue_cap);
        let accept = std::thread::Builder::new()
            .name("qless-coord-accept".into())
            .spawn({
                let ctx = Arc::clone(&ctx);
                move || {
                    for conn in listener.incoming() {
                        if ctx.shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        match conn {
                            Ok(stream) => {
                                let ctx = Arc::clone(&ctx);
                                let task = move || {
                                    serve_lines(
                                        stream,
                                        &ctx.shutdown,
                                        &|line| handle_line(line, &ctx),
                                        &|| trigger_shutdown(&ctx),
                                    )
                                };
                                if pool.execute(task).is_err() {
                                    break;
                                }
                            }
                            Err(e) => warn_!("coordinator accept error: {e}"),
                        }
                    }
                    drop(pool);
                }
            })
            .expect("spawning coordinator accept thread");
        Ok(Coordinator { ctx, accept: Some(accept), health: Some(health), local })
    }

    /// The coordinator's bound address (resolves port 0 to the actual
    /// ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.ctx.addr
    }

    /// The worker [`Server`]s owned in `--local-workers` mode (empty for
    /// a coordinator over remote workers). The failure e2e tests stop
    /// one mid-run to exercise re-issue.
    pub fn local_workers(&self) -> &[Server] {
        &self.local
    }

    /// Begin shutdown without blocking. Local workers (if any) are shut
    /// down by [`Coordinator::join`] / drop; remote workers are
    /// independent services and keep running.
    pub fn stop(&self) {
        trigger_shutdown(&self.ctx);
    }

    /// Block until the coordinator (accept loop, handlers, health loop)
    /// and any local workers have fully shut down.
    pub fn join(mut self) -> Result<()> {
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow::anyhow!("coordinator accept thread panicked"))?;
        }
        if let Some(h) = self.health.take() {
            h.join().map_err(|_| anyhow::anyhow!("health thread panicked"))?;
        }
        for w in self.local.drain(..) {
            w.stop();
            w.join()?;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        trigger_shutdown(&self.ctx);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.health.take() {
            let _ = h.join();
        }
        // local Servers shut themselves down on drop
    }
}

/// One stats round trip under the worker deadline.
fn probe(addr: &str, deadline: Duration) -> Result<StatsReply> {
    Client::connect_deadline(addr, deadline)?.stats()
}

/// Background worker liveness: ping every worker ~4×/second, flipping
/// health flags both ways — a dead worker stops receiving ranges within
/// one round, a revived one rejoins within one round.
fn health_loop(ctx: &CoCtx) {
    let ping_deadline = ctx.deadline.min(Duration::from_millis(500));
    while !ctx.shutdown.load(Ordering::SeqCst) {
        for slot in &ctx.workers {
            let ok = Client::connect_deadline(slot.addr.as_str(), ping_deadline)
                .and_then(|mut c| c.ping())
                .is_ok();
            let was = slot.healthy.swap(ok, Ordering::SeqCst);
            if was != ok {
                if ok {
                    info!("coordinator: worker {} is back", slot.addr);
                } else {
                    warn_!("coordinator: worker {} unreachable", slot.addr);
                }
            }
        }
        // nap in small slices so shutdown stays responsive
        for _ in 0..10 {
            if ctx.shutdown.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
    }
}

/// Dispatch one coordinator request line (never panics; every failure
/// becomes an error response).
fn handle_line(line: &str, ctx: &CoCtx) -> Response {
    let req = match proto::parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            return Response::Error {
                id: proto::salvage_id(line),
                error: format!("bad request: {e:#}"),
            }
        }
    };
    match req {
        Request::Ping { id } => Response::Pong { id },
        Request::Shutdown { id } => Response::ShuttingDown { id },
        Request::Stats { id, per_worker } => match scatter_stats(ctx, per_worker) {
            Ok(mut r) => {
                r.id = id;
                Response::Stats(r)
            }
            Err(e) => Response::Error { id, error: format!("{e:#}") },
        },
        Request::Metrics { id, traces, prometheus } => {
            let (snapshot, spans) = scatter_metrics(ctx, traces);
            Response::Metrics(MetricsReply {
                id,
                prometheus: prometheus.then(|| snapshot.prometheus()),
                traces: traces.then_some(spans),
                snapshot,
            })
        }
        Request::Score(r) => {
            let id = r.id;
            match scatter_score(&r, ctx) {
                Ok(reply) => Response::Score(reply),
                Err(e) => Response::Error { id, error: format!("{e:#}") },
            }
        }
    }
}

/// Aggregate `stats` across the fleet: generation and row count are the
/// **minimum** over reachable workers (the state every one of them can
/// answer for — the same pin the scatter path serves), counters are
/// summed, geometry comes from the startup agreement. With `per_worker`
/// the reply also carries one un-summed row per reachable worker — the
/// fleet sums are lossy for spotting a straggler, the row set is not.
fn scatter_stats(ctx: &CoCtx, per_worker: bool) -> Result<StatsReply> {
    let states = probe_fleet(ctx)?;
    let mut sum = ServiceStats::default();
    for (_, st) in &states {
        let s = &st.stats;
        sum.queries += s.queries;
        sum.batches += s.batches;
        sum.fused_passes += s.fused_passes;
        sum.score_cache_hits += s.score_cache_hits;
        sum.score_cache_extends += s.score_cache_extends;
        sum.shard_cache_hits += s.shard_cache_hits;
        sum.disk_shard_reads += s.disk_shard_reads;
        sum.shard_cache_bytes += s.shard_cache_bytes;
        sum.rows_scored += s.rows_scored;
        sum.reloads += s.reloads;
        sum.index_queries += s.index_queries;
        sum.index_fallbacks += s.index_fallbacks;
        // staleness is a per-worker property of the same shared sidecar —
        // report the worst lag, not a multiply-counted sum
        sum.index_stale_rows = sum.index_stale_rows.max(s.index_stale_rows);
    }
    // the cluster count every worker can serve a window against (0 = at
    // least one worker has no sidecar → indexed scatters fall back)
    let index_clusters =
        states.iter().map(|(_, s)| s.stats.index_clusters).min().expect("non-empty");
    sum.index_clusters = index_clusters;
    let generation = states.iter().map(|(_, s)| s.generation).min().expect("non-empty");
    record_generation_lag(&states, generation);
    Ok(StatsReply {
        id: 0, // caller stamps the request id
        generation,
        n_samples: states.iter().map(|(_, s)| s.n_samples).min().expect("non-empty"),
        k: ctx.k,
        checkpoints: ctx.checkpoints,
        bits: ctx.bits,
        stats: sum,
        per_worker: per_worker.then(|| {
            states
                .iter()
                .map(|(i, st)| WorkerStat {
                    addr: ctx.workers[*i].addr.clone(),
                    generation: st.generation,
                    n_samples: st.n_samples,
                    stats: st.stats,
                })
                .collect()
        }),
    })
}

/// Publish how far the slowest reachable worker's ingest generation lags
/// the fastest's — the fleet pin (`min`) drops freshly-ingested rows
/// whenever this is nonzero, so it is the first gauge to watch when a
/// `since_gen` query returns fewer rows than expected.
fn record_generation_lag(states: &[(usize, StatsReply)], min_gen: u64) {
    let max_gen = states.iter().map(|(_, s)| s.generation).max().unwrap_or(min_gen);
    obs::gauge_set("coord_generation_lag", max_gen.saturating_sub(min_gen) as i64);
}

/// Scrape-and-merge the fleet's metrics registries into the
/// coordinator's own. A worker that fails the scrape — including an
/// older worker that predates the `metrics` verb — is skipped (counted
/// in `coord_metrics_skipped_total`), never a hard error, and its health
/// flag is left alone: inability to answer `metrics` says nothing about
/// its ability to score. Span rings are concatenated after the
/// coordinator's own when `traces` is set.
fn scatter_metrics(ctx: &CoCtx, traces: bool) -> (obs::MetricsSnapshot, Vec<SpanRecord>) {
    let reg = obs::reg();
    let mut merged = obs::MetricsSnapshot::default();
    let mut spans = Vec::new();
    for slot in &ctx.workers {
        if !slot.healthy.load(Ordering::SeqCst) {
            continue;
        }
        let res = Client::connect_deadline(slot.addr.as_str(), ctx.deadline)
            .and_then(|mut c| c.metrics(traces, false));
        match res {
            Ok(r) => {
                merged.merge(&r.snapshot);
                if let Some(t) = r.traces {
                    spans.extend(t);
                }
            }
            Err(e) => {
                obs::counter_add("coord_metrics_skipped_total", 1);
                warn_!("coordinator: metrics scrape of {} skipped: {e:#}", slot.addr);
            }
        }
    }
    // the coordinator's own registry folds in LAST so a worker skipped by
    // THIS scrape is already counted in the reply that skipped it
    merged.merge(&reg.snapshot());
    if traces {
        let mut own = reg.recent_spans(obs::SPAN_RING_CAP);
        own.append(&mut spans);
        spans = own;
    }
    (merged, spans)
}

/// Probe the fleet in parallel: every currently-healthy worker (all of
/// them, as a second chance, when none is flagged healthy) gets one
/// deadline-bounded `stats` round trip. Failures flip the health flag;
/// at least one worker must answer. Returns `(worker index, reply)`.
fn probe_fleet(ctx: &CoCtx) -> Result<Vec<(usize, StatsReply)>> {
    let mut candidates: Vec<usize> = (0..ctx.workers.len())
        .filter(|&i| ctx.workers[i].healthy.load(Ordering::SeqCst))
        .collect();
    if candidates.is_empty() {
        candidates = (0..ctx.workers.len()).collect();
    }
    let probes: Vec<Result<StatsReply>> = std::thread::scope(|s| {
        let handles: Vec<_> = candidates
            .iter()
            .map(|&i| {
                let addr = ctx.workers[i].addr.as_str();
                s.spawn(move || probe(addr, ctx.deadline))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow::anyhow!("probe panicked"))))
            .collect()
    });
    let mut states = Vec::new();
    for (&i, res) in candidates.iter().zip(probes) {
        match res {
            Ok(st) => {
                ctx.workers[i].healthy.store(true, Ordering::SeqCst);
                states.push((i, st));
            }
            Err(e) => {
                ctx.workers[i].healthy.store(false, Ordering::SeqCst);
                warn_!("coordinator: worker {} failed probe: {e:#}", ctx.workers[i].addr);
            }
        }
    }
    if states.is_empty() {
        bail!("no reachable workers (of {})", ctx.workers.len());
    }
    Ok(states)
}

/// Split `[0, n)` into `ways` contiguous ranges differing in length by at
/// most one row (clamped so no range is empty).
fn partition(n: usize, ways: usize) -> Vec<(usize, usize)> {
    let ways = ways.clamp(1, n.max(1));
    let base = n / ways;
    let rem = n % ways;
    let mut parts = Vec::with_capacity(ways);
    let mut start = 0;
    for i in 0..ways {
        let len = base + usize::from(i < rem);
        parts.push((start, len));
        start += len;
    }
    debug_assert_eq!(start, n);
    parts
}

/// Span collector for one traced scatter query. The coordinator records
/// the spans it can measure directly (the whole query, each wave, each
/// worker rpc) and **absorbs** the `timing` arrays workers send back:
/// absorbed spans get fresh coordinator-side ids (worker ids are
/// per-process counters and would collide across workers), offsets
/// re-based onto the rpc's start, and any parent link that doesn't
/// resolve within the absorbed array re-homed onto the rpc span — so the
/// reply's `timing` is always one well-formed tree rooted at
/// `coordinator.score`.
struct TraceBuf {
    trace: TraceField,
    root: u64,
    t0: u64,
    spans: Mutex<Vec<SpanRecord>>,
}

impl TraceBuf {
    fn new(trace: TraceField, reg: &obs::Registry) -> TraceBuf {
        TraceBuf { trace, root: obs::next_id(), t0: reg.now_us(), spans: Mutex::new(Vec::new()) }
    }

    /// The trace identity sub-requests should carry: same trace id, the
    /// given wave span as parent (workers report it back verbatim; the
    /// absorb step re-homes their roots anyway).
    fn sub_trace(&self, wave: u64) -> TraceField {
        TraceField { id: self.trace.id, parent: wave }
    }

    /// Record a wave span (probe wave, rerank wave, or the single scatter
    /// wave) under the root. The id is allocated by the caller **before**
    /// the wave runs so concurrent rpc spans can name it as parent.
    fn push_wave(&self, name: &str, id: u64, start_us: u64, end_us: u64) {
        self.spans.lock().unwrap().push(SpanRecord {
            name: name.into(),
            trace: self.trace.id,
            id,
            parent: self.root,
            start_us: start_us.saturating_sub(self.t0),
            dur_us: end_us.saturating_sub(start_us),
        });
    }

    /// Record one completed worker rpc under `wave` and absorb the
    /// reply's timing spans beneath it.
    fn absorb(&self, name: &str, wave: u64, start_us: u64, end_us: u64, reply: &ScoreReply) {
        let rpc = obs::next_id();
        let mut spans = self.spans.lock().unwrap();
        spans.push(SpanRecord {
            name: name.into(),
            trace: self.trace.id,
            id: rpc,
            parent: wave,
            start_us: start_us.saturating_sub(self.t0),
            dur_us: end_us.saturating_sub(start_us),
        });
        if let Some(timing) = &reply.timing {
            let map: std::collections::BTreeMap<u64, u64> =
                timing.iter().map(|s| (s.id, obs::next_id())).collect();
            for s in timing {
                spans.push(SpanRecord {
                    name: s.name.clone(),
                    trace: self.trace.id,
                    id: map[&s.id],
                    parent: map.get(&s.parent).copied().unwrap_or(rpc),
                    start_us: start_us.saturating_sub(self.t0) + s.start_us,
                    dur_us: s.dur_us,
                });
            }
        }
    }

    /// Close the root span and hand the stitched tree back (root first).
    /// The tree also lands in the span ring when tracing is enabled, so
    /// `metrics --traces` can replay recent fan-outs.
    fn finish(self, reg: &obs::Registry) -> Vec<SpanRecord> {
        let done = reg.now_us();
        let mut spans = self.spans.into_inner().unwrap();
        spans.insert(
            0,
            SpanRecord {
                name: "coordinator.score".into(),
                trace: self.trace.id,
                id: self.root,
                parent: self.trace.parent,
                start_us: 0,
                dur_us: done.saturating_sub(self.t0),
            },
        );
        if obs::tracing_enabled() {
            for s in &spans {
                reg.record_span(s.clone());
            }
        }
        spans
    }
}

/// One ranged sub-query against one worker, under the deadline.
fn sub_score(
    addr: &str,
    req: &ScoreRequest,
    start: usize,
    len: usize,
    deadline: Duration,
    trace: Option<TraceField>,
) -> Result<ScoreReply> {
    let mut c = Client::connect_deadline(addr, deadline)?;
    c.set_trace(trace);
    let r = c.score_rows(
        &req.val,
        req.top_k,
        req.want_scores,
        req.since_gen,
        Some((start as u64, len as u64)),
    )?;
    anyhow::ensure!(
        r.rows == Some((start as u64, len as u64)),
        "worker answered range {:?} for request range {start}+{len}",
        r.rows
    );
    if req.want_scores {
        let got = r.scores.as_ref().map_or(0, Vec::len);
        anyhow::ensure!(got == len, "worker returned {got} scores for a {len}-row range");
    }
    Ok(r)
}

/// Fan one sub-request per part out to the fleet (part `i` goes to the
/// `i`-th reachable worker, all in parallel), then re-issue failed parts
/// to surviving workers round-robin for up to `ctx.retries` rounds.
/// `issue(addr, (start, len))` performs one deadline-bounded sub-request
/// — re-issues run the **same** closure, so a re-issued range carries the
/// exact cascade stage and precision of the first attempt. `what` names
/// the part unit in degrade errors ("rows" for row ranges, "candidates"
/// for rerank chunks); a part still unanswered after every round degrades
/// the query to an error — a clean failure, never a truncated answer.
fn fan_out(
    ctx: &CoCtx,
    states: &[(usize, StatsReply)],
    parts: &[(usize, usize)],
    what: &str,
    issue: &(dyn Fn(&str, (usize, usize)) -> Result<ScoreReply> + Sync),
) -> Result<Vec<ScoreReply>> {
    let mut results: Vec<Option<ScoreReply>> = std::thread::scope(|s| {
        let handles: Vec<_> = parts
            .iter()
            .enumerate()
            .map(|(i, &(start, len))| {
                let slot = &ctx.workers[states[i].0];
                s.spawn(move || {
                    let res = issue(slot.addr.as_str(), (start, len));
                    if let Err(e) = &res {
                        obs::counter_add("coord_subquery_failures_total", 1);
                        slot.healthy.store(false, Ordering::SeqCst);
                        warn_!(
                            "coordinator: worker {} failed {what} {start}+{len}: {e:#}",
                            slot.addr
                        );
                    }
                    res.ok()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(None)).collect()
    });
    let mut cursor = 0usize;
    for _round in 0..ctx.retries {
        let pending: Vec<usize> =
            (0..parts.len()).filter(|&i| results[i].is_none()).collect();
        if pending.is_empty() {
            break;
        }
        for pi in pending {
            let (start, len) = parts[pi];
            let healthy: Vec<&WorkerSlot> = ctx
                .workers
                .iter()
                .filter(|w| w.healthy.load(Ordering::SeqCst))
                .collect();
            if healthy.is_empty() {
                obs::counter_add("coord_degraded_total", 1);
                bail!("{what} {start}..{} unanswered and no workers left", start + len);
            }
            let slot = healthy[cursor % healthy.len()];
            cursor += 1;
            obs::counter_add("coord_reissues_total", 1);
            match issue(slot.addr.as_str(), (start, len)) {
                Ok(r) => results[pi] = Some(r),
                Err(e) => {
                    obs::counter_add("coord_subquery_failures_total", 1);
                    slot.healthy.store(false, Ordering::SeqCst);
                    warn_!(
                        "coordinator: re-issue of {what} {start}+{len} to {} failed: {e:#}",
                        slot.addr
                    );
                }
            }
        }
    }
    if let Some(pi) = results.iter().position(Option::is_none) {
        let (start, len) = parts[pi];
        obs::counter_add("coord_degraded_total", 1);
        bail!(
            "{what} {start}..{} unanswered after {} re-issue round(s)",
            start + len,
            ctx.retries
        );
    }
    Ok(results.into_iter().map(|r| r.expect("checked")).collect())
}

/// Sum I/O across sub-replies (max over the per-pass geometry counters,
/// which describe the same query on every worker).
fn merge_pass<'a>(replies: impl Iterator<Item = &'a ScoreReply>) -> ScanStats {
    let mut pass = ScanStats::default();
    for r in replies {
        pass.checkpoints = pass.checkpoints.max(r.pass.checkpoints);
        pass.tasks = pass.tasks.max(r.pass.tasks);
        pass.shards_read += r.pass.shards_read;
        pass.rows_read += r.pass.rows_read;
        pass.bytes_read += r.pass.bytes_read;
    }
    pass
}

/// The scatter-gather hot path: probe → pin `(G, N)` → partition → fan
/// out → re-issue failures → merge (see the module docs for why the
/// merge is bit-exact). A request carrying a full `cascade` field takes
/// the two-wave path in [`scatter_cascade`] instead.
fn scatter_score(req: &ScoreRequest, ctx: &CoCtx) -> Result<ScoreReply> {
    if req.rows.is_some() {
        bail!("coordinator does not accept ranged (worker) requests");
    }
    if req.clusters.is_some() {
        bail!("coordinator does not accept cluster-window (worker) requests");
    }
    if matches!(
        req.cascade,
        Some(CascadeField::Probe { .. }) | Some(CascadeField::Rerank { .. })
    ) {
        bail!("coordinator does not accept cascade stage (worker) verbs");
    }
    if req.nprobe.is_some() && req.cascade.is_some() {
        bail!(
            "the scatter front end does not compose 'nprobe' with a cascade; \
             send the index-restricted cascade to a single node, or drop 'nprobe'"
        );
    }
    // admission checks mirroring ScoreQuery::validate's geometry half, so
    // a malformed query dies here instead of fanning out N times
    anyhow::ensure!(
        req.val.len() == ctx.checkpoints,
        "query has {} checkpoint feature sets, workers serve {}",
        req.val.len(),
        ctx.checkpoints
    );
    for (ci, m) in req.val.iter().enumerate() {
        anyhow::ensure!(
            m.k == ctx.k,
            "checkpoint {ci}: feature dim {} != served k {}",
            m.k,
            ctx.k
        );
    }
    if let Some(CascadeField::Full { probe, rerank, mult }) = req.cascade {
        return scatter_cascade(req, ctx, probe, rerank, mult);
    }
    if let Some(nprobe) = req.nprobe {
        return scatter_index(req, ctx, nprobe);
    }
    let reg = obs::reg();
    let t0 = reg.now_us();
    let tb = req.trace.map(|t| TraceBuf::new(t, &reg));
    let states = probe_fleet(ctx)?;
    let generation = states.iter().map(|(_, s)| s.generation).min().expect("non-empty");
    record_generation_lag(&states, generation);
    let n = states.iter().map(|(_, s)| s.n_samples).min().expect("non-empty");
    anyhow::ensure!(n > 0, "workers serve an empty store");
    let parts = partition(n, states.len());
    let wave = obs::next_id();
    let wave0 = reg.now_us();
    let replies = fan_out(ctx, &states, &parts, "rows", &|addr, (start, len)| {
        let s0 = reg.now_us();
        let r = sub_score(
            addr,
            req,
            start,
            len,
            ctx.deadline,
            tb.as_ref().map(|b| b.sub_trace(wave)),
        )?;
        if let Some(b) = &tb {
            b.absorb("rpc.score", wave, s0, reg.now_us(), &r);
        }
        Ok(r)
    })?;
    if let Some(b) = &tb {
        b.push_wave("wave.scatter", wave, wave0, reg.now_us());
    }
    reg.observe_us("coord_score_us", reg.now_us().saturating_sub(t0));
    // merge: summed I/O, comparator-exact top-k, concatenated scores
    let pass = merge_pass(replies.iter());
    let tops: Vec<Vec<(usize, f32)>> = replies.iter().map(|r| r.top.clone()).collect();
    let scores = if req.want_scores {
        let mut full = vec![0f32; n];
        for (r, &(start, len)) in replies.iter().zip(&parts) {
            let s = r.scores.as_deref().expect("length checked in sub_score");
            full[start..start + len].copy_from_slice(s);
        }
        Some(full)
    } else {
        None
    };
    Ok(ScoreReply {
        id: req.id,
        generation,
        cached: false,
        batched: replies.iter().map(|r| r.batched).max().unwrap_or(0),
        pass,
        rows: None,
        top: merge_top_k(&tops, req.top_k),
        scores,
        timing: tb.map(|b| b.finish(&reg)),
    })
}

/// The two-wave cascade scatter. Wave 1: every worker probes its slice of
/// the pinned `[0, N)` row space at `probe` bits and returns the slice's
/// top-`mult · top_k` candidates; the coordinator merges them into one
/// global candidate pool of at most `mult · top_k` rows. Wave 2: the pool
/// (as a sorted row list) is cut into contiguous chunks and re-scored at
/// `rerank` bits via the `rows_list` worker verb; the final top-`top_k`
/// uses the same `(score desc, index asc)` comparator as a single node.
///
/// Exactness mirrors the single-node cascade: per-slice top-`c·k` pools
/// jointly cover the global top-`c·k` (each global winner is in some
/// slice, where at most `c·k - 1` rows can outrank it), and the
/// append-only contract means rows below the pinned `N` are immutable
/// between waves, so an ingest landing mid-cascade cannot skew the
/// rerank. Worker failures in either wave ride the same re-issue
/// machinery as plain scatters ([`fan_out`]) — a worker that lacks one of
/// the cascade's precision stores fails its sub-query cleanly and the
/// range is re-issued, so a degraded fleet yields an error, never a
/// silently exhaustive or truncated answer.
fn scatter_cascade(
    req: &ScoreRequest,
    ctx: &CoCtx,
    probe: u8,
    rerank: u8,
    mult: usize,
) -> Result<ScoreReply> {
    anyhow::ensure!(req.top_k >= 1, "cascade needs top_k >= 1 final selections per task");
    anyhow::ensure!(
        !req.want_scores,
        "a cascade reply carries only the reranked top list; drop 'want_scores' or score \
         exhaustively"
    );
    anyhow::ensure!(
        req.since_gen.is_none(),
        "cascade cannot be combined with 'since_gen'; score the new rows exhaustively instead"
    );
    let reg = obs::reg();
    let t0 = reg.now_us();
    let tb = req.trace.map(|t| TraceBuf::new(t, &reg));
    let states = probe_fleet(ctx)?;
    let generation = states.iter().map(|(_, s)| s.generation).min().expect("non-empty");
    record_generation_lag(&states, generation);
    let n = states.iter().map(|(_, s)| s.n_samples).min().expect("non-empty");
    anyhow::ensure!(n > 0, "workers serve an empty store");
    let ck = req.top_k.saturating_mul(mult).min(n);
    let parts = partition(n, states.len());
    let probe_wave = obs::next_id();
    let probe0 = reg.now_us();
    let probes = fan_out(ctx, &states, &parts, "rows", &|addr, (start, len)| {
        let s0 = reg.now_us();
        let mut c = Client::connect_deadline(addr, ctx.deadline)?;
        c.set_trace(tb.as_ref().map(|b| b.sub_trace(probe_wave)));
        let r = c.score_probe(&req.val, ck, (start as u64, len as u64), probe)?;
        anyhow::ensure!(
            r.rows == Some((start as u64, len as u64)),
            "worker answered range {:?} for request range {start}+{len}",
            r.rows
        );
        if let Some(b) = &tb {
            b.absorb("rpc.probe", probe_wave, s0, reg.now_us(), &r);
        }
        Ok(r)
    })?;
    if let Some(b) = &tb {
        b.push_wave("wave.probe", probe_wave, probe0, reg.now_us());
    }
    // merged candidate pool as a sorted, deduplicated global row list —
    // sorted so wave-2 chunks are contiguous row runs (sequential reads)
    let tops: Vec<Vec<(usize, f32)>> = probes.iter().map(|r| r.top.clone()).collect();
    let mut rows: Vec<usize> = merge_top_k(&tops, ck).into_iter().map(|(i, _)| i).collect();
    rows.sort_unstable();
    rows.dedup();
    anyhow::ensure!(!rows.is_empty(), "probe wave surfaced no candidates");
    let chunks = partition(rows.len(), states.len());
    let rerank_wave = obs::next_id();
    let rerank0 = reg.now_us();
    let reranks = fan_out(ctx, &states, &chunks, "candidates", &|addr, (start, len)| {
        let s0 = reg.now_us();
        let mut c = Client::connect_deadline(addr, ctx.deadline)?;
        c.set_trace(tb.as_ref().map(|b| b.sub_trace(rerank_wave)));
        let r = c.score_rerank(&req.val, rows[start..start + len].to_vec(), rerank)?;
        anyhow::ensure!(
            r.top.len() == len,
            "worker returned {} reranked rows for a {len}-candidate chunk",
            r.top.len()
        );
        if let Some(b) = &tb {
            b.absorb("rpc.rerank", rerank_wave, s0, reg.now_us(), &r);
        }
        Ok(r)
    })?;
    if let Some(b) = &tb {
        b.push_wave("wave.rerank", rerank_wave, rerank0, reg.now_us());
    }
    reg.observe_us("coord_score_us", reg.now_us().saturating_sub(t0));
    let pass = merge_pass(probes.iter().chain(reranks.iter()));
    let pairs: Vec<(usize, f32)> = reranks.iter().flat_map(|r| r.top.iter().copied()).collect();
    Ok(ScoreReply {
        id: req.id,
        generation,
        cached: false,
        batched: probes.iter().chain(reranks.iter()).map(|r| r.batched).max().unwrap_or(0),
        pass,
        rows: None,
        top: top_k_scored_among(&pairs, req.top_k),
        scores: None,
        timing: tb.map(|b| b.finish(&reg)),
    })
}

/// The indexed scatter: partition the **cluster list, not the row
/// space**. Every worker holds the full store and the same `.qidx`
/// sidecar, so each runs the identical deterministic centroid probe and
/// arrives at the same per-task cluster ranking; worker `i` then scans
/// only cluster-list *positions* `parts[i]` of that ranking. Clusters
/// partition the rows, the windows partition the probed clusters, so the
/// per-window top lists cover disjoint row sets and [`merge_top_k`]
/// (score desc, index asc — the single-node comparator) reassembles the
/// exact unpartitioned answer; at `nprobe >= nclusters` that answer is
/// the exhaustive one. A fleet where any reachable worker lacks a
/// sidecar (`index_clusters == 0` in its stats) degrades the whole query
/// to the plain row-partitioned scatter — exact, never approximate, and
/// counted in `coord_index_fallbacks_total`. Failed windows ride the
/// same re-issue machinery as row ranges ([`fan_out`]): any worker can
/// serve any window.
fn scatter_index(req: &ScoreRequest, ctx: &CoCtx, nprobe: u32) -> Result<ScoreReply> {
    anyhow::ensure!(req.top_k >= 1, "indexed scoring needs top_k >= 1 final selections per task");
    let reg = obs::reg();
    let t0 = reg.now_us();
    let states = probe_fleet(ctx)?;
    let c_min =
        states.iter().map(|(_, s)| s.stats.index_clusters).min().expect("non-empty") as usize;
    if c_min == 0 {
        obs::counter_add("coord_index_fallbacks_total", 1);
        warn_!(
            "coordinator: indexed query but a reachable worker serves no sidecar — \
             degrading to the exact row-partitioned scatter (run `qless reindex`)"
        );
        let mut plain = req.clone();
        plain.nprobe = None;
        return scatter_score(&plain, ctx);
    }
    let eff = (nprobe as usize).min(c_min);
    let tb = req.trace.map(|t| TraceBuf::new(t, &reg));
    let generation = states.iter().map(|(_, s)| s.generation).min().expect("non-empty");
    record_generation_lag(&states, generation);
    let n = states.iter().map(|(_, s)| s.n_samples).min().expect("non-empty");
    anyhow::ensure!(n > 0, "workers serve an empty store");
    let parts = partition(eff, states.len());
    let wave = obs::next_id();
    let wave0 = reg.now_us();
    let replies = fan_out(ctx, &states, &parts, "clusters", &|addr, (start, len)| {
        let s0 = reg.now_us();
        let mut c = Client::connect_deadline(addr, ctx.deadline)?;
        c.set_trace(tb.as_ref().map(|b| b.sub_trace(wave)));
        let r = c.score_index_clusters(
            &req.val,
            req.top_k,
            eff as u32,
            (start as u64, len as u64),
        )?;
        if let Some(b) = &tb {
            b.absorb("rpc.index", wave, s0, reg.now_us(), &r);
        }
        Ok(r)
    })?;
    if let Some(b) = &tb {
        b.push_wave("wave.index", wave, wave0, reg.now_us());
    }
    reg.observe_us("coord_score_us", reg.now_us().saturating_sub(t0));
    let pass = merge_pass(replies.iter());
    let tops: Vec<Vec<(usize, f32)>> = replies.iter().map(|r| r.top.clone()).collect();
    Ok(ScoreReply {
        id: req.id,
        generation,
        cached: false,
        batched: replies.iter().map(|r| r.batched).max().unwrap_or(0),
        pass,
        rows: None,
        top: merge_top_k(&tops, req.top_k),
        scores: None,
        timing: tb.map(|b| b.finish(&reg)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Precision, Scheme};
    use crate::util::prop::{normal_features as feats, seeded_datastore};
    use std::path::PathBuf;

    #[test]
    fn partition_covers_the_row_space_contiguously() {
        for n in [1usize, 2, 5, 23, 64, 100] {
            for ways in [1usize, 2, 3, 7, 200] {
                let parts = partition(n, ways);
                assert!(!parts.is_empty());
                assert!(parts.len() <= ways.min(n));
                let mut next = 0;
                for &(start, len) in &parts {
                    assert_eq!(start, next, "contiguous");
                    assert!(len > 0, "no empty ranges");
                    next = start + len;
                }
                assert_eq!(next, n, "covers [0, {n})");
                let (lo, hi) = parts
                    .iter()
                    .fold((usize::MAX, 0), |(lo, hi), &(_, l)| (lo.min(l), hi.max(l)));
                assert!(hi - lo <= 1, "balanced within one row");
            }
        }
    }

    fn build_store(tag: &str, n: usize, k: usize) -> PathBuf {
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let path = std::env::temp_dir().join(format!(
            "qless_coord_{tag}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ));
        seeded_datastore(&path, p, n, k, &[0.7, 0.3], 0);
        path
    }

    #[test]
    fn local_coordinator_merges_to_the_single_node_answer() {
        let (n, k) = (29usize, 64usize);
        let path = build_store("merge", n, k);
        let worker_opts = ServeOpts {
            addr: "127.0.0.1:0".into(),
            batch_window_ms: 0,
            workers: 2,
            shard_rows: 5,
            ..Default::default()
        };
        // single node reference
        let single = Server::start(&path, worker_opts.clone()).unwrap();
        let val = vec![feats(2, k, 11), feats(2, k, 12)];
        let mut sc = Client::connect(single.addr()).unwrap();
        let want = sc.score(&val, 7, true).unwrap();
        // 3 local workers behind a coordinator
        let co = Coordinator::start_local(
            &path,
            3,
            worker_opts,
            CoordinatorOpts { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        assert_eq!(co.local_workers().len(), 3);
        let mut c = Client::connect(co.addr()).unwrap();
        c.ping().unwrap();
        let got = c.score(&val, 7, true).unwrap();
        assert_eq!(got.top, want.top, "merged top-k vs single node");
        let (a, b) = (got.scores.unwrap(), want.scores.unwrap());
        assert_eq!(a.len(), n);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "merged scores bit-identical");
        }
        // the scatter read every row exactly once per checkpoint
        assert_eq!(got.pass.rows_read, (2 * n) as u64);
        // fleet stats aggregate
        let st = c.stats().unwrap();
        assert_eq!(st.n_samples, n);
        assert_eq!(st.k, k);
        assert_eq!(st.checkpoints, 2);
        c.shutdown().unwrap();
        co.join().unwrap();
        single.stop();
        single.join().unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn local_coordinator_partitions_the_cluster_list_and_falls_back() {
        let (n, k) = (29usize, 64usize);
        let path = build_store("index", n, k);
        let worker_opts = ServeOpts {
            addr: "127.0.0.1:0".into(),
            batch_window_ms: 0,
            workers: 2,
            shard_rows: 5,
            ..Default::default()
        };
        // single-node exhaustive reference
        let single = Server::start(&path, worker_opts.clone()).unwrap();
        let val = vec![feats(2, k, 11), feats(2, k, 12)];
        let mut sc = Client::connect(single.addr()).unwrap();
        let want = sc.score(&val, 7, false).unwrap();
        // phase 1: no sidecar anywhere → the indexed scatter degrades to
        // the exact row-partitioned scatter
        let co = Coordinator::start_local(
            &path,
            3,
            worker_opts.clone(),
            CoordinatorOpts { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let mut c = Client::connect(co.addr()).unwrap();
        let fb = c.score_index(&val, 7, 3).unwrap();
        assert_eq!(fb.top, want.top, "sidecar-free fleet degrades to the exact scatter");
        c.shutdown().unwrap();
        co.join().unwrap();
        // phase 2: sidecar built before the workers open the store
        crate::datastore::reindex_store(
            &path,
            crate::datastore::IndexBuildOpts { n_clusters: 5, max_iters: 4 },
        )
        .unwrap();
        let co = Coordinator::start_local(
            &path,
            3,
            worker_opts,
            CoordinatorOpts { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let mut c = Client::connect(co.addr()).unwrap();
        // full coverage: the cluster-partitioned scatter is bit-identical
        // to the single-node exhaustive answer
        let got = c.score_index(&val, 7, 5).unwrap();
        assert!(got.scores.is_none() && got.rows.is_none());
        for (g, w) in got.top.iter().zip(want.top.iter()) {
            assert_eq!(g.0, w.0, "cluster-partitioned scatter vs single node");
            assert_eq!(g.1.to_bits(), w.1.to_bits(), "bit-exact scores");
        }
        // sub-linear probing still answers a full-size top list
        assert_eq!(c.score_index(&val, 7, 2).unwrap().top.len(), 7);
        // worker verbs and unsupported compositions are rejected up front
        let err = c.score_index_clusters(&val, 7, 5, (0, 1)).unwrap_err();
        assert!(format!("{err:#}").contains("cluster-window"), "{err:#}");
        let err = c.score_index_cascade(&val, 7, 1, 8, 8, 5).unwrap_err();
        assert!(format!("{err:#}").contains("cascade"), "{err:#}");
        // fleet stats carry the index fields: min clusters, summed queries
        let st = c.stats().unwrap();
        assert_eq!(st.stats.index_clusters, 5);
        assert!(st.stats.index_queries >= 1, "{:?}", st.stats);
        assert_eq!(st.stats.index_fallbacks, 0);
        c.shutdown().unwrap();
        co.join().unwrap();
        single.stop();
        single.join().unwrap();
        std::fs::remove_file(crate::datastore::index_path(&path)).ok();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn local_coordinator_cascade_matches_single_node_exhaustive() {
        let dir = std::env::temp_dir().join(format!(
            "qless_coord_casc_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let (n, k) = (29usize, 64usize);
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        let probe_path = crate::datastore::default_store_path(&dir, p1);
        let rerank_path = crate::datastore::default_store_path(&dir, p8);
        seeded_datastore(&probe_path, p1, n, k, &[0.7, 0.3], 0);
        seeded_datastore(&rerank_path, p8, n, k, &[0.7, 0.3], 0);
        let worker_opts = ServeOpts {
            addr: "127.0.0.1:0".into(),
            batch_window_ms: 0,
            workers: 2,
            shard_rows: 5,
            ..Default::default()
        };
        // single-node 8-bit exhaustive reference
        let single = Server::start(&rerank_path, worker_opts.clone()).unwrap();
        let val = vec![feats(2, k, 11), feats(2, k, 12)];
        let mut sc = Client::connect(single.addr()).unwrap();
        let want = sc.score(&val, 5, false).unwrap();
        // 3 local workers (serving the 1-bit store, siblings on demand)
        let co = Coordinator::start_local(
            &probe_path,
            3,
            worker_opts,
            CoordinatorOpts { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let mut c = Client::connect(co.addr()).unwrap();
        // mult 8 · top_k 5 = 40 candidates >= 29 rows → exact cascade
        let got = c.score_cascade(&val, 5, 1, 8, 8).unwrap();
        assert_eq!(got.top.len(), 5);
        for (g, w) in got.top.iter().zip(want.top.iter()) {
            assert_eq!(g.0, w.0, "scattered cascade vs single-node exhaustive");
            assert_eq!(g.1.to_bits(), w.1.to_bits(), "bit-exact rerank scores");
        }
        // both waves covered every row once per checkpoint (exact regime)
        assert_eq!(got.pass.rows_read, (4 * n) as u64);
        // stage verbs are worker-facing; the coordinator front rejects them
        let err = c.score_probe(&val, 5, (0, 10), 1).unwrap_err();
        assert!(format!("{err:#}").contains("stage"), "{err:#}");
        let err = c.score_rerank(&val, vec![0, 3], 8).unwrap_err();
        assert!(format!("{err:#}").contains("stage"), "{err:#}");
        c.shutdown().unwrap();
        co.join().unwrap();
        single.stop();
        single.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
