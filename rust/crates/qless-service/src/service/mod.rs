//! The resident influence query service (`qless serve`).
//!
//! The batch pipeline makes valuation *possible*; this layer makes it
//! *cheap to ask again*. QLESS's economics (paper §3) are that once
//! gradients are quantized into the datastore, scoring a new validation
//! task is a scan, not a training run — but a scan that pays process
//! startup, header validation and a cold streaming read per query still
//! has the wrong marginal cost for a serving system (cf.
//! compute-constrained selection, arXiv:2410.16208). This subsystem keeps
//! everything warm and amortizes everything shareable:
//!
//! * [`session`] — the **live** datastore opened once (base + ingested
//!   segments via the generation manifest); recently-scanned shards
//!   pinned in a byte-budgeted LRU (`--mem-budget-mb`) so repeat scans
//!   hit RAM; a score cache keyed by task digest so identical queries
//!   never rescan at all. An ingest mid-serve is picked up **without
//!   restart**: new segment members attach in place, warm shards below
//!   the old row count stay pinned, and cached answers are *extended* by
//!   a tail scan over only the new rows.
//! * [`batcher`] — concurrent queries admitted to a bounded queue and
//!   coalesced within `--batch-window-ms` (cap `--max-batch-tasks`) into
//!   **one** fused multi-task pass — the PR-2 `score_datastore_tasks`
//!   compute primitive, reached through the re-entrant
//!   [`crate::influence::MultiScan`] so cached shards can feed it.
//! * [`cache`] — the LRU + task-digest machinery both caches share.
//! * [`proto`] — the JSON-lines wire format (normative spec:
//!   `rust/crates/qless-service/PROTOCOL.md`, included as its rustdoc).
//! * [`server`] — the std-only TCP front end (blocking accept loop +
//!   `util::pool::TaskPool` handlers) and the [`Client`] the tests and the
//!   load bench drive.
//! * [`coordinator`] — scatter-gather serving over N workers: the
//!   coordinator speaks the same wire protocol, partitions the row space,
//!   fans queries out as ranged sub-queries, re-issues failed ranges, and
//!   merges per-shard answers bit-exactly (`qless serve --local-workers N`
//!   runs the whole topology in one process).
//!
//! Served scores are **bit-identical** to the one-shot `--multi-scan`
//! pipeline: same kernels, same `RowsView` bytes (cached or streamed),
//! same per-row accumulation order — `tests/service_e2e.rs` asserts it
//! end-to-end over real sockets, and `tests/serve_scatter.rs` extends the
//! assertion across worker counts, worker kills, and mid-query ingests.

pub mod batcher;
pub mod cache;
pub mod coordinator;
pub mod proto;
pub mod server;
pub mod session;

pub use batcher::{Batcher, BatcherOpts, SessionView};
pub use cache::{task_digest, LruCache};
pub use coordinator::{Coordinator, CoordinatorOpts};
pub use proto::{
    CascadeField, MetricsReply, Request, Response, ScoreReply, ScoreRequest, StatsReply,
    TraceField, WorkerStat,
};
pub use server::{Client, ServeOpts, Server};
pub use session::{Answer, CascadePlan, ScoreQuery, ServiceStats, Session, SessionOpts};
