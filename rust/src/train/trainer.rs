//! The training driver: runs `train_step` (Adam inside the graph) over
//! shuffled epochs, records the loss curve, and snapshots a [`Checkpoint`]
//! (LoRA + Adam state + η_i) at every epoch boundary — the warmup protocol
//! of LESS/QLESS step 1.
//!
//! The frozen base is uploaded to the device once per run; LoRA/m/v round-
//! trip host↔device each step because Rust owns optimizer state across
//! checkpoint boundaries (they are small: d_lora ≪ d_base).

use anyhow::Result;

use crate::data::{Batcher, Dataset};
use crate::model::Checkpoint;
use crate::runtime::{Exec, ModelInfo, Runtime};
use crate::train::Schedule;
use crate::util::Rng;
use crate::{debug, info};

#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Mean loss per epoch.
    pub epoch_losses: Vec<f64>,
    /// Loss at every step (the e2e example logs this curve).
    pub step_losses: Vec<f32>,
    pub steps: usize,
}

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    info: ModelInfo,
    exec: std::sync::Arc<Exec>,
    base_buf: crate::runtime::DeviceBuf,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, info: &ModelInfo, base: &[f32]) -> Result<Trainer<'rt>> {
        let exec = rt.exec(info, "train_step")?;
        let base_buf = rt.upload_f32(base, &[info.d_base])?;
        Ok(Trainer { rt, info: info.clone(), exec, base_buf })
    }

    /// Train `epochs` over `data`, mutating `ckpt` in place. Returns the
    /// loss curve; pushes an epoch-end snapshot into `snapshots` if given.
    pub fn train(
        &self,
        data: &Dataset,
        ckpt: &mut Checkpoint,
        epochs: usize,
        schedule: &Schedule,
        seed: u64,
        mut snapshots: Option<&mut Vec<Checkpoint>>,
    ) -> Result<TrainReport> {
        let b = self.info.batch_train;
        let s = self.info.seq;
        let mut rng = Rng::new(seed).fork(0x7124);
        let mut report = TrainReport { epoch_losses: Vec::new(), step_losses: Vec::new(), steps: 0 };
        let mut t = ckpt.step; // resume-aware global step
        for epoch in 0..epochs {
            let mut epoch_loss = 0.0f64;
            let mut nb = 0usize;
            let mut last_lr = 0.0f64;
            for batch in Batcher::shuffled(data, b, &mut rng) {
                let lr = schedule.lr(t as usize);
                last_lr = lr;
                t += 1;
                let loss = self.step(ckpt, &batch.tokens, &batch.masks, t, lr, b, s)?;
                report.step_losses.push(loss);
                epoch_loss += loss as f64;
                nb += 1;
                debug!("epoch {epoch} step {t} lr {lr:.2e} loss {loss:.4}");
            }
            ckpt.step = t;
            ckpt.eta = last_lr as f32;
            let mean = epoch_loss / nb.max(1) as f64;
            report.epoch_losses.push(mean);
            report.steps = t as usize;
            info!("epoch {epoch}: mean loss {mean:.4} (lr {last_lr:.2e})");
            if let Some(snaps) = snapshots.as_deref_mut() {
                snaps.push(ckpt.clone());
            }
        }
        Ok(report)
    }

    /// One optimizer step through the AOT graph. Exposed for tests.
    pub fn step(
        &self,
        ckpt: &mut Checkpoint,
        tokens: &[i32],
        masks: &[f32],
        t: u64,
        lr: f64,
        b: usize,
        s: usize,
    ) -> Result<f32> {
        let dl = self.info.d_lora;
        let tok_buf = self.rt.upload_i32(tokens, &[b, s])?;
        let mask_buf = self.rt.upload_f32(masks, &[b, s])?;
        let lora_buf = self.rt.upload_f32(&ckpt.lora, &[dl])?;
        let m_buf = self.rt.upload_f32(&ckpt.m, &[dl])?;
        let v_buf = self.rt.upload_f32(&ckpt.v, &[dl])?;
        let t_buf = self.rt.upload_f32(&[t as f32], &[])?;
        let lr_buf = self.rt.upload_f32(&[lr as f32], &[])?;
        let out = self.exec.run_b(&[
            &self.base_buf,
            &lora_buf,
            &m_buf,
            &v_buf,
            &t_buf,
            &tok_buf,
            &mask_buf,
            &lr_buf,
        ])?;
        let [lora2, m2, v2, loss]: [Vec<f32>; 4] =
            out.try_into().map_err(|_| anyhow::anyhow!("train_step returned wrong arity"))?;
        ckpt.lora = lora2;
        ckpt.m = m2;
        ckpt.v = v2;
        Ok(loss[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, Tokenizer};
    use std::path::PathBuf;

    fn rt() -> Option<Runtime> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Runtime::new(&p).unwrap())
    }

    #[test]
    fn training_reduces_loss_on_tiny() {
        let Some(rt) = rt() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let info = rt.model("tiny").unwrap();
        let tok = Tokenizer::default();
        let data = crate::data::Dataset::encode(
            generate_corpus(64, 5, &tok, info.seq),
            &tok,
            info.seq,
        );
        let base = crate::model::init_base(&info, 1);
        let mut ckpt = Checkpoint::fresh(info.d_lora, crate::model::init_lora(&info, 1));
        let trainer = Trainer::new(&rt, &info, &base).unwrap();
        let sched = Schedule::new(5e-3, 3 * data.len().div_ceil(info.batch_train), 0.1);
        let report = trainer.train(&data, &mut ckpt, 3, &sched, 7, None).unwrap();
        assert_eq!(report.epoch_losses.len(), 3);
        assert!(
            report.epoch_losses[2] < report.epoch_losses[0] * 0.95,
            "{:?}",
            report.epoch_losses
        );
        assert!(ckpt.step > 0);
        assert!(ckpt.eta > 0.0);
    }
}
