//! Training loops (paper Appendix A): LR schedule and the warmup /
//! fine-tune drivers over the `train_step` AOT graph (Adam runs inside the
//! graph; Rust owns the optimizer *state* across steps and checkpoints).

pub mod schedule;
pub mod trainer;

pub use schedule::Schedule;
pub use trainer::{TrainReport, Trainer};
