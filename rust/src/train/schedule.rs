//! Learning-rate schedule: linear warmup → cosine decay to zero, the
//! paper's Appendix A setting. The per-epoch η_i recorded into checkpoints
//! (and from there into influence aggregation, Eq. 7) is the schedule value
//! at the step the checkpoint was taken.

#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub peak_lr: f64,
    pub total_steps: usize,
    pub warmup_steps: usize,
}

impl Schedule {
    pub fn new(peak_lr: f64, total_steps: usize, warmup_frac: f64) -> Schedule {
        let total_steps = total_steps.max(1);
        let warmup_steps = ((total_steps as f64) * warmup_frac).round() as usize;
        Schedule { peak_lr, total_steps, warmup_steps }
    }

    /// LR at 0-based step `t`.
    pub fn lr(&self, t: usize) -> f64 {
        if self.warmup_steps > 0 && t < self.warmup_steps {
            return self.peak_lr * (t + 1) as f64 / self.warmup_steps as f64;
        }
        let span = (self.total_steps - self.warmup_steps).max(1);
        let progress = ((t - self.warmup_steps) as f64 / span as f64).min(1.0);
        self.peak_lr * 0.5 * (1.0 + (std::f64::consts::PI * progress).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    #[test]
    fn warmup_ramps_to_peak() {
        let s = Schedule::new(1e-3, 100, 0.1);
        assert_eq!(s.warmup_steps, 10);
        assert!(s.lr(0) > 0.0);
        assert!(s.lr(0) < s.lr(5));
        assert!((s.lr(9) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = Schedule::new(1e-3, 100, 0.1);
        assert!(s.lr(50) < 1e-3);
        assert!(s.lr(99) < s.lr(50));
        assert!(s.lr(99) < 2e-5);
        assert!(s.lr(1000) >= 0.0); // past the end stays clamped
    }

    #[test]
    fn no_warmup_starts_at_peak() {
        let s = Schedule::new(2e-3, 50, 0.0);
        assert!((s.lr(0) - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn prop_lr_positive_and_bounded() {
        run_prop("lr-bounded", 100, |g| {
            let total = 1 + g.usize_in(1, 500);
            let s = Schedule::new(1e-3, total, 0.03);
            for t in 0..total {
                let lr = s.lr(t);
                prop_assert!(lr >= 0.0 && lr <= 1e-3 + 1e-15, "lr {lr} at {t}/{total}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_monotone_decay_after_warmup() {
        run_prop("lr-monotone", 50, |g| {
            let total = 20 + g.usize_up_to(200);
            let s = Schedule::new(1e-3, total, 0.1);
            for t in s.warmup_steps..total - 1 {
                prop_assert!(s.lr(t) >= s.lr(t + 1) - 1e-15, "not decaying at {t}");
            }
            Ok(())
        });
    }
}
