//! On-disk header + primitive (de)serialization for the gradient datastore.

use anyhow::{bail, Result};

use crate::quant::{Precision, Scheme};

pub const MAGIC: [u8; 4] = *b"QLDS";
pub const VERSION: u32 = 1;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub precision: Precision,
    pub n_samples: u64,
    pub k: u64,
    pub n_checkpoints: u32,
    pub row_stride: u32,
}

impl Header {
    pub fn new(precision: Precision, n_samples: usize, k: usize, n_checkpoints: usize) -> Header {
        let row_stride = match precision.bits {
            16 => (k * 2) as u32,
            b => ((k * b as usize).div_ceil(8)) as u32,
        };
        Header {
            precision,
            n_samples: n_samples as u64,
            k: k as u64,
            n_checkpoints: n_checkpoints as u32,
            row_stride,
        }
    }

    pub const BYTES: usize = 4 + 4 + 1 + 1 + 2 + 8 + 8 + 4 + 4;

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(Self::BYTES);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.precision.bits);
        out.push(scheme_tag(self.precision.scheme));
        out.extend_from_slice(&[0u8; 2]);
        out.extend_from_slice(&self.n_samples.to_le_bytes());
        out.extend_from_slice(&self.k.to_le_bytes());
        out.extend_from_slice(&self.n_checkpoints.to_le_bytes());
        out.extend_from_slice(&self.row_stride.to_le_bytes());
        debug_assert_eq!(out.len(), Self::BYTES);
        out
    }

    pub fn decode(b: &[u8]) -> Result<Header> {
        if b.len() < Self::BYTES {
            bail!("datastore header truncated ({} bytes)", b.len());
        }
        if b[0..4] != MAGIC {
            bail!("bad datastore magic {:?}", &b[0..4]);
        }
        let version = u32::from_le_bytes(b[4..8].try_into()?);
        if version != VERSION {
            bail!("datastore version {version} != {VERSION}");
        }
        let bits = b[8];
        let scheme = scheme_from_tag(b[9])?;
        let precision = Precision::new(bits, scheme)?;
        let n_samples = u64::from_le_bytes(b[12..20].try_into()?);
        let k = u64::from_le_bytes(b[20..28].try_into()?);
        let n_checkpoints = u32::from_le_bytes(b[28..32].try_into()?);
        let row_stride = u32::from_le_bytes(b[32..36].try_into()?);
        let expect = Header::new(precision, n_samples as usize, k as usize, n_checkpoints as usize);
        if expect.row_stride != row_stride {
            bail!("row_stride {row_stride} inconsistent with bits/k (expect {})", expect.row_stride);
        }
        Ok(expect)
    }

    /// Bytes of one checkpoint block (η + scales + rows). 16-bit blocks
    /// carry no scales section (bf16 rows are self-describing).
    pub fn block_bytes(&self) -> u64 {
        4 + self.scales_bytes() + self.row_stride as u64 * self.n_samples
    }

    /// Bytes of the per-row scale section (absent at 16-bit).
    pub fn scales_bytes(&self) -> u64 {
        if self.precision.bits == 16 {
            0
        } else {
            4 * self.n_samples
        }
    }

    /// Total file size this header implies.
    pub fn file_bytes(&self) -> u64 {
        Self::BYTES as u64 + self.block_bytes() * self.n_checkpoints as u64
    }
}

fn scheme_tag(s: Scheme) -> u8 {
    match s {
        Scheme::Absmax => 0,
        Scheme::Absmean => 1,
        Scheme::Sign => 2,
    }
}

fn scheme_from_tag(t: u8) -> Result<Scheme> {
    Ok(match t {
        0 => Scheme::Absmax,
        1 => Scheme::Absmean,
        2 => Scheme::Sign,
        _ => bail!("bad scheme tag {t}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hdr(bits: u8) -> Header {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        Header::new(Precision::new(bits, scheme).unwrap(), 1000, 512, 4)
    }

    #[test]
    fn encode_decode_roundtrip() {
        for bits in [1u8, 2, 4, 8, 16] {
            let h = hdr(bits);
            let d = Header::decode(&h.encode()).unwrap();
            assert_eq!(h, d, "{bits}-bit");
        }
    }

    #[test]
    fn row_strides() {
        assert_eq!(hdr(16).row_stride, 1024);
        assert_eq!(hdr(8).row_stride, 512);
        assert_eq!(hdr(4).row_stride, 256);
        assert_eq!(hdr(2).row_stride, 128);
        assert_eq!(hdr(1).row_stride, 64);
    }

    #[test]
    fn rejects_corruption() {
        let mut b = hdr(8).encode();
        b[0] = b'X';
        assert!(Header::decode(&b).is_err());
        let mut b2 = hdr(8).encode();
        b2[4] = 99; // version
        assert!(Header::decode(&b2).is_err());
        let mut b3 = hdr(8).encode();
        b3[9] = 7; // scheme tag
        assert!(Header::decode(&b3).is_err());
        assert!(Header::decode(&[0u8; 4]).is_err());
    }

    #[test]
    fn file_size_matches_quant_accounting() {
        // The header's implied file size must track quant::datastore_bytes
        // up to the per-block η and header overhead.
        let h = hdr(1);
        let payload = crate::quant::datastore_bytes(h.precision, 1000, 512, 4);
        let overhead = Header::BYTES as u64 + 4 * 4; // header + 4 η
        // datastore_bytes counts 4-byte scales per row; so does the file.
        assert_eq!(h.file_bytes(), payload + overhead);
    }
}
