//! Data selection — QLESS step 4: rank the corpus by cumulative influence
//! and keep the top p% (paper: 5%), plus the analyses built on top of it
//! (subset composition for Fig. 5, budget sweeps for Fig. 4).

pub mod distribution;
pub mod topk;

pub use distribution::SourceDistribution;
pub use topk::{select_top_frac, top_k_indices, top_k_scored, top_k_scored_since};
