//! Data selection — QLESS step 4: rank the corpus by cumulative influence
//! and keep the top p% (paper: 5%), plus the analyses built on top of it
//! (subset composition for Fig. 5, budget sweeps for Fig. 4).
//!
//! The ranking primitives themselves (top-k with deterministic
//! tie-breaking, the scatter-gather merge) live in `qless_core::select`
//! and are re-exported here; only the corpus-aware
//! [`SourceDistribution`] analysis needs this crate.

pub mod distribution;

pub use distribution::SourceDistribution;
pub use qless_core::select::topk;
pub use qless_core::select::{
    merge_top_k, select_top_frac, top_k_indices, top_k_scored, top_k_scored_among,
    top_k_scored_since,
};
