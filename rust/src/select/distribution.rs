//! Subset composition analysis — paper Figure 5: which corpus sources the
//! top-p% selection draws from, per benchmark and per quantization level.

use crate::corpus::{Sample, Source};

/// Composition of a selected subset by corpus source (one Fig. 5 bar).
#[derive(Debug, Clone)]
pub struct SourceDistribution {
    /// (source, selected count, fraction of selection).
    pub rows: Vec<(Source, usize, f64)>,
    /// Total selected samples the fractions are over.
    pub total: usize,
}

impl SourceDistribution {
    /// Tally the sources of `selected` indices into `samples`.
    pub fn of(samples: &[Sample], selected: &[usize]) -> SourceDistribution {
        let mut counts = [(Source::SynFlan, 0usize), (Source::SynCot, 0), (Source::SynDolly, 0), (Source::SynOasst, 0)];
        for &i in selected {
            let src = samples[i].source;
            for c in counts.iter_mut() {
                if c.0 == src {
                    c.1 += 1;
                }
            }
        }
        let total = selected.len();
        SourceDistribution {
            rows: counts
                .into_iter()
                .map(|(s, c)| (s, c, c as f64 / total.max(1) as f64))
                .collect(),
            total,
        }
    }

    /// Fraction of the selection drawn from `source`.
    pub fn frac(&self, source: Source) -> f64 {
        self.rows.iter().find(|r| r.0 == source).map(|r| r.2).unwrap_or(0.0)
    }

    /// L1 distance between two compositions (Fig. 5's "how much did the
    /// subset shift at this bit width" summary).
    pub fn l1_distance(&self, other: &SourceDistribution) -> f64 {
        self.rows
            .iter()
            .zip(&other.rows)
            .map(|(a, b)| (a.2 - b.2).abs())
            .sum()
    }

    /// One-line console rendering (`source: count (pct)` per source).
    pub fn render(&self) -> String {
        self.rows
            .iter()
            .map(|(s, c, f)| format!("{s}: {c} ({:.1}%)", f * 100.0))
            .collect::<Vec<_>>()
            .join("  ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Sample;

    fn samples() -> Vec<Sample> {
        let mut v = Vec::new();
        for (src, n) in [
            (Source::SynFlan, 4),
            (Source::SynCot, 3),
            (Source::SynDolly, 2),
            (Source::SynOasst, 1),
        ] {
            for _ in 0..n {
                v.push(Sample::new(src, "p", "a"));
            }
        }
        v
    }

    #[test]
    fn counts_by_source() {
        let s = samples();
        let d = SourceDistribution::of(&s, &[0, 1, 4, 9]);
        assert_eq!(d.total, 4);
        assert_eq!(d.frac(Source::SynFlan), 0.5);
        assert_eq!(d.frac(Source::SynCot), 0.25);
        assert_eq!(d.frac(Source::SynDolly), 0.0);
        assert_eq!(d.frac(Source::SynOasst), 0.25);
    }

    #[test]
    fn fractions_sum_to_one() {
        let s = samples();
        let d = SourceDistribution::of(&s, &[0, 4, 7, 8, 9]);
        let sum: f64 = d.rows.iter().map(|r| r.2).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn l1_distance_zero_for_identical() {
        let s = samples();
        let a = SourceDistribution::of(&s, &[0, 4]);
        let b = SourceDistribution::of(&s, &[1, 5]);
        assert_eq!(a.l1_distance(&b), 0.0);
        let c = SourceDistribution::of(&s, &[7, 8]);
        assert!(a.l1_distance(&c) > 0.9);
    }

    #[test]
    fn empty_selection_safe() {
        let s = samples();
        let d = SourceDistribution::of(&s, &[]);
        assert_eq!(d.total, 0);
        assert_eq!(d.frac(Source::SynFlan), 0.0);
    }

    #[test]
    fn render_contains_all_sources() {
        let s = samples();
        let r = SourceDistribution::of(&s, &[0, 4, 7, 9]).render();
        for name in ["synflan", "syncot", "syndolly", "synoasst"] {
            assert!(r.contains(name), "{r}");
        }
    }
}
