//! Batched greedy decoding over the `decode_step` AOT graph.
//!
//! No KV cache: `decode_step` recomputes the full forward and gathers the
//! logits at each row's current position. At S=96 / B=32 / SimLM scale the
//! recompute is cheaper than shipping a cache across the PJRT boundary
//! every step; DESIGN.md §7 records the trade-off.

use anyhow::Result;

use crate::corpus::tokenizer::{Tokenizer, EOT};
use crate::corpus::{EncodedSample, Sample};
use crate::runtime::{ModelInfo, Runtime};

/// Greedily decode answers for a batch of prompts. Returns the decoded
/// text (chars until `<eot>`) per sample.
pub fn greedy_decode(
    rt: &Runtime,
    info: &ModelInfo,
    base_buf: &crate::runtime::DeviceBuf,
    lora: &[f32],
    prompts: &[Sample],
    tok: &Tokenizer,
    max_new: usize,
) -> Result<Vec<String>> {
    let exec = rt.exec(info, "decode_step")?;
    let (b, s, v) = (info.batch_eval, info.seq, info.vocab);
    let lora_buf = rt.upload_f32(lora, &[info.d_lora])?;

    let mut outputs = vec![String::new(); prompts.len()];
    for chunk_start in (0..prompts.len()).step_by(b) {
        let chunk = &prompts[chunk_start..(chunk_start + b).min(prompts.len())];
        let enc: Vec<EncodedSample> = chunk
            .iter()
            .map(|p| p.encode_prompt(tok, s))
            .collect::<Result<_>>()?;
        let mut tokens: Vec<i32> = Vec::with_capacity(b * s);
        for e in &enc {
            tokens.extend_from_slice(&e.tokens);
        }
        // pad rows replicate row 0 (results discarded)
        for _ in chunk.len()..b {
            tokens.extend_from_slice(&enc[0].tokens);
        }
        let mut pos: Vec<i32> = enc.iter().map(|e| e.prompt_end as i32).collect();
        pos.resize(b, pos[0]);
        let mut done = vec![false; chunk.len()];
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); chunk.len()];

        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let tok_buf = rt.upload_i32(&tokens, &[b, s])?;
            let pos_buf = rt.upload_i32(&pos, &[b])?;
            let out = exec.run_b(&[base_buf, &lora_buf, &tok_buf, &pos_buf])?;
            let logits = &out[0]; // [b, v]
            for (row, d) in done.iter_mut().enumerate() {
                if *d {
                    continue;
                }
                let next = argmax(&logits[row * v..(row + 1) * v]);
                let p = pos[row] as usize;
                if p + 1 >= s {
                    *d = true;
                    continue;
                }
                tokens[row * s + p + 1] = next;
                pos[row] += 1;
                if next == EOT {
                    *d = true;
                } else {
                    generated[row].push(next);
                }
            }
        }
        for (row, gen) in generated.iter().enumerate() {
            outputs[chunk_start + row] = tok.decode_until_eot(gen);
        }
    }
    Ok(outputs)
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
