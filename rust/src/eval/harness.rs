//! The benchmark harness: scores a (base, lora) model on the three
//! benchmarks the way the paper scores MMLU / BBH / TyDiQA.
//!
//! * SynMC    — option ranking by per-option masked NLL (`loss_eval`
//!   graph), like 5-shot MMLU letter scoring; reports accuracy.
//! * SynArith — greedy CoT decode; exact match on the final value.
//! * SynQA    — greedy decode; token F1.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::corpus::tasks::arith_final;
use crate::corpus::{Sample, Tokenizer, World};
use crate::eval::benchmarks::{test_tasks, Benchmark, EvalTask};
use crate::eval::decoder::greedy_decode;
use crate::eval::metrics::{mean, token_f1};
use crate::info;
use crate::runtime::{ModelInfo, Runtime};

/// Scores per benchmark (fractions in [0,1]) + their average.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchScores {
    pub scores: BTreeMap<&'static str, f64>,
}

impl BenchScores {
    pub fn get(&self, b: Benchmark) -> f64 {
        self.scores[b.name()]
    }

    pub fn average(&self) -> f64 {
        mean(&self.scores.values().copied().collect::<Vec<_>>())
    }
}

/// Evaluate a model on all three benchmarks with `n_per_task` held-out
/// tasks each.
pub fn evaluate(
    rt: &Runtime,
    info: &ModelInfo,
    base: &[f32],
    lora: &[f32],
    world: &World,
    n_per_task: usize,
    seed: u64,
) -> Result<BenchScores> {
    let tok = Tokenizer::default();
    let base_buf = rt.upload_f32(base, &[info.d_base])?;
    let mut scores = BTreeMap::new();
    for bench in Benchmark::ALL {
        let tasks = test_tasks(bench, world, n_per_task, seed);
        let t0 = std::time::Instant::now();
        let score = match bench {
            Benchmark::SynMC => eval_mc(rt, info, &base_buf, lora, &tasks, &tok)?,
            Benchmark::SynArith => {
                let prompts: Vec<Sample> = tasks.iter().map(|t| t.sample.clone()).collect();
                let outs = greedy_decode(rt, info, &base_buf, lora, &prompts, &tok, 28)?;
                mean(
                    &tasks
                        .iter()
                        .zip(&outs)
                        .map(|(t, o)| {
                            let gold = arith_final(&t.sample.answer).expect("gold value");
                            f64::from(arith_final(o) == Some(gold))
                        })
                        .collect::<Vec<_>>(),
                )
            }
            Benchmark::SynQA => {
                let prompts: Vec<Sample> = tasks.iter().map(|t| t.sample.clone()).collect();
                let outs = greedy_decode(rt, info, &base_buf, lora, &prompts, &tok, 10)?;
                mean(
                    &tasks
                        .iter()
                        .zip(&outs)
                        .map(|(t, o)| token_f1(o, &t.sample.answer))
                        .collect::<Vec<_>>(),
                )
            }
        };
        info!(
            "eval {bench}: {:.2}% over {n_per_task} tasks in {:.1}s",
            score * 100.0,
            t0.elapsed().as_secs_f64()
        );
        scores.insert(bench.name(), score);
    }
    Ok(BenchScores { scores })
}

/// Multiple choice via per-option NLL ranking: build the four candidate
/// (prompt, letter) completions and take the lowest masked loss.
fn eval_mc(
    rt: &Runtime,
    info: &ModelInfo,
    base_buf: &crate::runtime::DeviceBuf,
    lora: &[f32],
    tasks: &[EvalTask],
    tok: &Tokenizer,
) -> Result<f64> {
    let exec = rt.exec(info, "loss_eval")?;
    let (b, s) = (info.batch_eval, info.seq);
    let lora_buf = rt.upload_f32(lora, &[info.d_lora])?;

    // Flatten (task × option) candidates.
    let mut cands: Vec<Sample> = Vec::with_capacity(tasks.len() * 4);
    for t in tasks {
        for opt in &t.options {
            cands.push(Sample::new(t.sample.source, t.sample.prompt.clone(), opt.clone()));
        }
    }
    let mut nlls = Vec::with_capacity(cands.len());
    for chunk in cands.chunks(b) {
        let mut tokens = Vec::with_capacity(b * s);
        let mut masks = Vec::with_capacity(b * s);
        for c in chunk {
            let e = c.try_encode(tok, s)?;
            tokens.extend_from_slice(&e.tokens);
            masks.extend_from_slice(&e.loss_mask);
        }
        for _ in chunk.len()..b {
            tokens.extend(std::iter::repeat_n(0i32, s));
            masks.extend(std::iter::repeat_n(0f32, s));
        }
        let tok_buf = rt.upload_i32(&tokens, &[b, s])?;
        let mask_buf = rt.upload_f32(&masks, &[b, s])?;
        let out = exec.run_b(&[base_buf, &lora_buf, &tok_buf, &mask_buf])?;
        nlls.extend_from_slice(&out[0][..chunk.len()]);
    }

    let correct = tasks
        .iter()
        .enumerate()
        .filter(|(i, t)| {
            let row = &nlls[i * 4..i * 4 + 4];
            let pick = row
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            pick == t.correct
        })
        .count();
    Ok(correct as f64 / tasks.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn rt() -> Option<Runtime> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Runtime::new(&p).unwrap())
    }

    #[test]
    fn untrained_model_scores_near_chance() {
        let Some(rt) = rt() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let info = rt.model("tiny").unwrap();
        let world = World::generate(5);
        let base = crate::model::init_base(&info, 1);
        let lora = crate::model::init_lora(&info, 1);
        let s = evaluate(&rt, &info, &base, &lora, &world, 16, 3).unwrap();
        // MC chance is 25%; untrained should be within broad chance bounds
        let mc = s.get(Benchmark::SynMC);
        assert!((0.0..=0.8).contains(&mc), "mc {mc}");
        // decode metrics near zero for an untrained model
        assert!(s.get(Benchmark::SynArith) <= 0.5);
        assert!(s.average() <= 0.7);
    }
}
