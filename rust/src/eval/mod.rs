//! Benchmark evaluation — the stand-ins for MMLU / BBH / TyDiQA (§4.1).
//!
//! * [`benchmarks`] — task builders over the held-out fact world: SynMC
//!   (option ranking → accuracy), SynArith (CoT decode → exact match),
//!   SynQA (extractive decode → token F1); plus the validation-split
//!   builders whose gradients drive selection.
//! * [`metrics`]   — accuracy / EM / F1.
//! * [`decoder`]   — batched greedy decoding over the `decode_step` graph.
//! * [`harness`]   — ties it together into per-benchmark scores.

pub mod benchmarks;
pub mod decoder;
pub mod harness;
pub mod metrics;

pub use benchmarks::{Benchmark, EvalTask};
pub use harness::{evaluate, BenchScores};
