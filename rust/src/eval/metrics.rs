//! Evaluation metrics: accuracy, exact match, and SQuAD-style token F1
//! (the TyDiQA gold-passage metric the paper reports).

/// Token-level F1 between prediction and gold (whitespace tokens,
/// lowercase, punctuation stripped) — the standard extractive-QA metric.
pub fn token_f1(pred: &str, gold: &str) -> f64 {
    let p = tokens(pred);
    let g = tokens(gold);
    if p.is_empty() || g.is_empty() {
        return f64::from(u8::from(p.is_empty() && g.is_empty()));
    }
    // multiset intersection
    let mut g_counts = std::collections::HashMap::new();
    for t in &g {
        *g_counts.entry(t.clone()).or_insert(0usize) += 1;
    }
    let mut overlap = 0usize;
    for t in &p {
        if let Some(c) = g_counts.get_mut(t) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / p.len() as f64;
    let recall = overlap as f64 / g.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

fn tokens(s: &str) -> Vec<String> {
    s.to_lowercase()
        .split_whitespace()
        .map(|t| t.trim_matches(|c: char| !c.is_alphanumeric()).to_string())
        .filter(|t| !t.is_empty())
        .collect()
}

/// Exact match after trimming.
pub fn exact_match(pred: &str, gold: &str) -> bool {
    pred.trim() == gold.trim()
}

/// Mean of a set of per-task 0/1 or fractional scores.
pub fn mean(scores: &[f64]) -> f64 {
    if scores.is_empty() {
        return 0.0;
    }
    scores.iter().sum::<f64>() / scores.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_exact_is_one() {
        assert_eq!(token_f1("red", "red"), 1.0);
        assert_eq!(token_f1("the red fox", "the red fox"), 1.0);
    }

    #[test]
    fn f1_disjoint_is_zero() {
        assert_eq!(token_f1("blue", "red"), 0.0);
    }

    #[test]
    fn f1_partial_overlap() {
        // pred {red, fox}, gold {red} → p=0.5, r=1.0, f1=2/3
        let f = token_f1("red fox", "red");
        assert!((f - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn f1_handles_case_and_punct() {
        assert_eq!(token_f1("Red.", "red"), 1.0);
        assert_eq!(token_f1("  red  ", "red"), 1.0);
    }

    #[test]
    fn f1_empty_cases() {
        assert_eq!(token_f1("", ""), 1.0);
        assert_eq!(token_f1("", "red"), 0.0);
        assert_eq!(token_f1("red", ""), 0.0);
    }

    #[test]
    fn f1_multiset_semantics() {
        // pred says "red red", gold "red": overlap must count once.
        let f = token_f1("red red", "red");
        assert!((f - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn em_trims() {
        assert!(exact_match(" 11 ", "11"));
        assert!(!exact_match("11", "12"));
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[1.0, 0.0]), 0.5);
    }
}
