//! Benchmark task builders (SynMC / SynArith / SynQA) and the validation
//! splits used for influence-based selection.
//!
//! Formats are shared byte-for-byte with the corpus generators
//! (`corpus::tasks`), so each benchmark has exactly one "right" training
//! source to discover — the mechanism behind the paper's Fig. 5.
//! Determinism: tasks come from tagged RNG forks; the validation split
//! (drives selection) and the eval split (scores models) use disjoint tags.

use crate::corpus::tasks::{arith_task, mc_prompt, qa_prompt, OPTION_LETTERS};
use crate::corpus::{Sample, Source, World};
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    /// MMLU analogue: 4-way multiple choice, option log-likelihood ranking.
    SynMC,
    /// BBH analogue: chain-of-thought arithmetic, exact match on the result.
    SynArith,
    /// TyDiQA analogue: extractive QA, token-F1 on the decoded answer.
    SynQA,
}

impl Benchmark {
    pub const ALL: [Benchmark; 3] = [Benchmark::SynQA, Benchmark::SynMC, Benchmark::SynArith];

    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::SynMC => "SynMC",
            Benchmark::SynArith => "SynArith",
            Benchmark::SynQA => "SynQA",
        }
    }

    /// The paper benchmark this one stands in for.
    pub fn paper_name(&self) -> &'static str {
        match self {
            Benchmark::SynMC => "MMLU",
            Benchmark::SynArith => "BBH",
            Benchmark::SynQA => "TyDiQA",
        }
    }

    /// The corpus source whose skill this benchmark needs (Fig. 5's
    /// expected selection alignment).
    pub fn aligned_source(&self) -> Source {
        match self {
            Benchmark::SynMC => Source::SynFlan,
            Benchmark::SynArith => Source::SynCot,
            Benchmark::SynQA => Source::SynDolly,
        }
    }
}

impl std::fmt::Display for Benchmark {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One evaluation instance: the prompt/gold pair plus MC options when
/// applicable.
#[derive(Debug, Clone)]
pub struct EvalTask {
    pub benchmark: Benchmark,
    pub sample: Sample,
    /// MC option strings (the candidate *answers*, i.e. letters).
    pub options: Vec<String>,
    /// Index of the correct option (MC only).
    pub correct: usize,
}

/// Build `n` tasks for a benchmark. `split_tag` separates validation
/// (selection-driving) from test (model-scoring) task streams.
pub fn build_tasks(bench: Benchmark, world: &World, n: usize, seed: u64, split_tag: u64) -> Vec<EvalTask> {
    let mut rng = Rng::new(seed).fork(0xE7A1 ^ split_tag ^ (bench as u64) << 8);
    (0..n).map(|_| build_task(bench, world, &mut rng)).collect()
}

fn build_task(bench: Benchmark, world: &World, rng: &mut Rng) -> EvalTask {
    match bench {
        Benchmark::SynMC => {
            let fact = world.eval_fact(rng);
            let mut opts = world.distractors(&fact, 4, rng);
            let correct = rng.below(4);
            opts.insert(correct, fact.value_name());
            let sample = Sample::new(
                Source::SynFlan,
                mc_prompt(&fact, &opts),
                OPTION_LETTERS[correct].to_string(),
            );
            EvalTask {
                benchmark: bench,
                sample,
                options: OPTION_LETTERS.iter().map(|s| s.to_string()).collect(),
                correct,
            }
        }
        Benchmark::SynArith => {
            let (prompt, answer, _) = arith_task(rng);
            EvalTask {
                benchmark: bench,
                sample: Sample::new(Source::SynCot, prompt, answer),
                options: vec![],
                correct: 0,
            }
        }
        Benchmark::SynQA => {
            let n_facts = 2 + rng.below(2);
            let mut facts: Vec<_> = (0..n_facts).map(|_| world.eval_fact(rng)).collect();
            facts.dedup_by(|a, b| a.entity == b.entity && a.attr == b.attr);
            let ask = facts[rng.below(facts.len())].clone();
            EvalTask {
                benchmark: bench,
                sample: Sample::new(
                    Source::SynDolly,
                    qa_prompt(&facts, &ask),
                    ask.value_name().to_string(),
                ),
                options: vec![],
                correct: 0,
            }
        }
    }
}

/// The validation split for selection: full prompt+gold samples whose SGD
/// gradients are the q̂_{z'} of Eq. 7 (the paper's few-shot D_val).
pub fn validation_samples(bench: Benchmark, world: &World, n: usize, seed: u64) -> Vec<Sample> {
    build_tasks(bench, world, n, seed, 0x7A11D)
        .into_iter()
        .map(|t| t.sample)
        .collect()
}

/// The held-out test split (scores fine-tuned models).
pub fn test_tasks(bench: Benchmark, world: &World, n: usize, seed: u64) -> Vec<EvalTask> {
    build_tasks(bench, world, n, seed, 0x7E57)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Tokenizer;

    fn world() -> World {
        World::generate(5)
    }

    #[test]
    fn tasks_fit_sequence_budget() {
        let w = world();
        let tok = Tokenizer::default();
        for bench in Benchmark::ALL {
            for t in build_tasks(bench, &w, 50, 1, 0) {
                assert!(
                    t.sample.encoded_len() <= 96,
                    "{bench}: {} chars: {:?}",
                    t.sample.encoded_len(),
                    t.sample.prompt
                );
                t.sample.try_encode(&tok, 96).unwrap();
            }
        }
    }

    #[test]
    fn val_and_test_splits_differ() {
        let w = world();
        for bench in Benchmark::ALL {
            let val = validation_samples(bench, &w, 10, 1);
            let test = test_tasks(bench, &w, 10, 1);
            let overlap = val
                .iter()
                .filter(|v| test.iter().any(|t| t.sample.prompt == v.prompt))
                .count();
            assert!(overlap <= 2, "{bench}: {overlap} overlapping prompts");
        }
    }

    #[test]
    fn mc_correct_option_is_gold() {
        let w = world();
        for t in build_tasks(Benchmark::SynMC, &w, 30, 2, 0) {
            assert_eq!(t.sample.answer, OPTION_LETTERS[t.correct]);
            assert_eq!(t.options.len(), 4);
            // the prompt lists the correct value after its letter
            assert!(t.sample.prompt.contains(&format!(" {} ", OPTION_LETTERS[t.correct])));
        }
    }

    #[test]
    fn arith_gold_has_final_value() {
        let w = world();
        for t in build_tasks(Benchmark::SynArith, &w, 30, 3, 0) {
            assert!(crate::corpus::tasks::arith_final(&t.sample.answer).is_some());
        }
    }

    #[test]
    fn qa_answers_are_extractable() {
        let w = world();
        for t in build_tasks(Benchmark::SynQA, &w, 30, 4, 0) {
            assert!(t.sample.prompt.contains(&t.sample.answer));
        }
    }

    #[test]
    fn tasks_use_heldout_entities() {
        let w = world();
        let train_entities = &w.entities[..w.train_split];
        for t in build_tasks(Benchmark::SynQA, &w, 20, 5, 0) {
            // the asked entity must be from the eval split
            let asked = t.sample.prompt.rsplit(" is ").next().unwrap().trim_end_matches('?');
            assert!(
                !train_entities.contains(&asked.to_string()),
                "train entity {asked} leaked into eval"
            );
        }
    }

    #[test]
    fn deterministic_builders() {
        let w = world();
        let a = build_tasks(Benchmark::SynMC, &w, 5, 7, 0);
        let b = build_tasks(Benchmark::SynMC, &w, 5, 7, 0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sample.prompt, y.sample.prompt);
        }
    }
}
