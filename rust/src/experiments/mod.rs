//! Paper table/figure reproduction harnesses (`qless xp <id>`).
//!
//! Each harness runs the pipeline grid behind one table or figure of the
//! paper's evaluation and emits a paper-shaped report to `reports/<id>.*`.
//! DESIGN.md §4 maps every id to its paper counterpart; EXPERIMENTS.md
//! records paper-vs-measured. `--fast` shrinks the grid for smoke runs.

pub mod cascade;
pub mod figures;
pub mod tables;

use anyhow::{bail, Result};

use crate::config::Config;

/// Workload scale knobs shared by all harnesses.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub fast: bool,
}

impl Scale {
    /// Tune a config for experiment grids. Fast mode shrinks everything to
    /// smoke-test size; full mode is the EXPERIMENTS.md configuration.
    pub fn apply(&self, cfg: &mut Config, model: &str) {
        cfg.model = model.to_string();
        cfg.lr = 2e-3; // SimLM-scale peak LR (paper's 2e-5 is 7B-scale)
        if self.fast {
            cfg.corpus_size = 2000;
            cfg.warmup_epochs = 4;
            cfg.finetune_epochs = 5;
            cfg.val_per_task = 24;
            cfg.eval_per_task = 96;
        } else {
            cfg.corpus_size = 4000;
            cfg.warmup_epochs = 4;
            cfg.finetune_epochs = 6;
            cfg.val_per_task = 32;
            cfg.eval_per_task = 128;
        }
    }

    /// The model families a multi-model table covers.
    pub fn table_models(&self) -> Vec<&'static str> {
        if self.fast {
            vec!["tiny"]
        } else {
            vec!["tiny", "small"]
        }
    }
}

pub fn run(id: &str, base_cfg: &Config, fast: bool) -> Result<()> {
    let scale = Scale { fast };
    match id {
        "table1" => tables::table1(base_cfg, scale),
        "table2" => tables::table2(base_cfg, scale),
        "table3" => tables::table3(base_cfg, scale),
        "fig1" => figures::fig1(base_cfg),
        "fig3" => figures::fig3(base_cfg, scale),
        "fig4" => figures::fig4(base_cfg, scale),
        "fig5" => figures::fig5(base_cfg, scale),
        "cascade" => cascade::cascade(base_cfg, scale),
        "all" => {
            for id in ["table1", "table2", "table3", "fig3", "fig4", "fig5", "cascade", "fig1"] {
                run(id, base_cfg, fast)?;
            }
            Ok(())
        }
        _ => bail!(
            "unknown experiment '{id}' (table1|table2|table3|fig1|fig3|fig4|fig5|cascade|all)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_knobs() {
        let mut c = Config::default();
        Scale { fast: true }.apply(&mut c, "tiny");
        assert_eq!(c.model, "tiny");
        assert_eq!(c.corpus_size, 2000);
        Scale { fast: false }.apply(&mut c, "small");
        assert_eq!(c.warmup_epochs, 4);
        assert!(c.corpus_size > 2000);
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run("table99", &Config::default(), true).is_err());
    }
}
