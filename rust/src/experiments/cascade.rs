//! Cascade tradeoff harness (`qless xp cascade`): recall@k and I/O cost
//! of the two-stage precision cascade against the exhaustive
//! high-precision scan, swept over the candidate multiplier.

use anyhow::Result;

use crate::config::Config;
use crate::eval::Benchmark;
use crate::influence::cascade::exhaustive_scan_bytes;
use crate::pipeline::{Pipeline, Report};
use crate::quant::{Precision, Scheme};
use crate::select::top_k_scored;
use crate::util::json::Json;
use crate::util::table::{human_bytes, Table};

use super::Scale;

/// `xp cascade`: 1-bit probe → 8-bit rerank over one run's sibling
/// stores, sweeping `--cascade-mult` ∈ {1, 2, 4, 8, 16}. Selection-only
/// (no fine-tunes) — cheap. For each multiplier the harness reports
/// recall@k_sel per benchmark against the exhaustive 8-bit top list,
/// bytes read (probe + rerank), the I/O reduction factor vs the
/// exhaustive 8-bit scan, and wall time. This is the harness behind
/// EXPERIMENTS.md §Perf's cascade entry; the acceptance targets are
/// recall ≥ 0.95 and ≥ 2× I/O reduction at the default multiplier 8.
pub fn cascade(base_cfg: &Config, scale: Scale) -> Result<()> {
    let model = if scale.fast { "tiny" } else { "small" };
    let mut cfg = base_cfg.clone();
    scale.apply(&mut cfg, model);
    cfg.run_dir = format!("runs/cascade_{model}_s{}", cfg.seed);
    let mut pipe = Pipeline::new(cfg.clone())?;
    let p1 = Precision::new(1, Scheme::Sign)?;
    let p8 = Precision::new(8, Scheme::Absmax)?;
    // one extraction pass emits both stores; the 8-bit one doubles as the
    // exhaustive reference
    let stores = pipe.build_datastores(&[p1, p8])?;
    let ds8 = &stores[1].0;
    let n = ds8.n_samples();
    let k_sel = (((n as f64) * cfg.select_frac).ceil() as usize).clamp(1, n);
    let exhaustive = exhaustive_scan_bytes(&ds8.header, n);
    let t0 = std::time::Instant::now();
    let all = pipe.influence_scores_all(ds8)?;
    let exhaustive_secs = t0.elapsed().as_secs_f64();
    let want: Vec<Vec<usize>> = Benchmark::ALL
        .iter()
        .map(|b| top_k_scored(&all[b.name()], k_sel).into_iter().map(|(i, _)| i).collect())
        .collect();

    let mut report = Report::new(
        "cascade",
        "Compute-constrained precision cascade: recall@k vs I/O (1-bit probe → 8-bit rerank)",
    );
    let mut t = Table::new(
        &format!("SimLM-{model}, n={n}, k_sel={k_sel}"),
        &["Mult", "SynQA", "SynMC", "SynArith", "Avg recall", "Bytes read", "I/O ×", "Wall (s)"],
    );
    let mut j = Json::obj();
    for mult in [1usize, 2, 4, 8, 16] {
        let t1 = std::time::Instant::now();
        let (tops, pass) = pipe.cascade_scores_all(p1, p8, mult, k_sel)?;
        let secs = t1.elapsed().as_secs_f64();
        let mut recalls = Vec::new();
        let mut j_m = Json::obj();
        for (bench, want_idx) in Benchmark::ALL.iter().zip(&want) {
            let got: std::collections::BTreeSet<usize> =
                tops[bench.name()].iter().map(|(i, _)| *i).collect();
            let hit = want_idx.iter().filter(|i| got.contains(i)).count();
            let recall = hit as f64 / want_idx.len().max(1) as f64;
            recalls.push(recall);
            j_m.set(bench.name(), recall);
        }
        let avg = recalls.iter().sum::<f64>() / recalls.len().max(1) as f64;
        let reduction = exhaustive as f64 / pass.bytes_read.max(1) as f64;
        t.row(vec![
            mult.to_string(),
            format!("{:.3}", recalls[0]),
            format!("{:.3}", recalls[1]),
            format!("{:.3}", recalls[2]),
            format!("{avg:.3}"),
            human_bytes(pass.bytes_read),
            format!("{reduction:.2}×"),
            format!("{secs:.2}"),
        ]);
        j_m.set("avg_recall", avg);
        j_m.set("bytes_read", pass.bytes_read as f64);
        j_m.set("io_reduction", reduction);
        j_m.set("wall_secs", secs);
        j.set(&format!("mult_{mult}"), j_m);
    }
    j.set("exhaustive_bytes", exhaustive as f64);
    j.set("exhaustive_wall_secs", exhaustive_secs);
    j.set("k_sel", k_sel as f64);
    report.add_table(t);
    report.note(format!(
        "Exhaustive 8-bit scan reads {} ({exhaustive_secs:.2}s measured). Targets: \
         recall@k >= 0.95 and >= 2x I/O reduction at the default multiplier 8; \
         mult · k_sel >= n makes the cascade exact (recall 1.000).",
        human_bytes(exhaustive)
    ));
    report.json = j;
    // after report.json so the stage-cost mirror lands in the artifact
    report.add_stage_costs(&pipe.stages);
    report.emit(std::path::Path::new("reports"))?;
    Ok(())
}
