//! Table harnesses: Table 1/4 (methods × models), Table 2/5 (model-Q ×
//! grad-Q grid), Table 3 (absmax vs absmean vs sign).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::Config;
use crate::eval::Benchmark;
use crate::pipeline::{Method, MethodResult, Pipeline, Report};
use crate::quant::{Precision, Scheme};
use crate::util::json::Json;
use crate::util::table::{human_bytes, pct, Table};

use super::Scale;

pub const BENCH_COLS: [&str; 3] = ["SynQA", "SynMC", "SynArith"];

fn method_row(label: &str, storage: Option<u64>, r: &MethodResult) -> Vec<String> {
    let mut row = vec![
        label.to_string(),
        storage.map(human_bytes).unwrap_or_else(|| "-".into()),
    ];
    for b in BENCH_COLS {
        row.push(pct(r.scores[b]));
    }
    row.push(pct(r.average));
    row
}

fn result_json(r: &MethodResult) -> Json {
    let mut j = Json::obj();
    j.set("label", r.label.clone());
    j.set("average", r.average);
    j.set("storage_bytes", r.storage_bytes as usize);
    let mut scores = Json::obj();
    for (k, v) in &r.scores {
        scores.set(k, *v);
    }
    j.set("scores", scores);
    let mut dists = Json::obj();
    for (bench, d) in &r.distributions {
        let mut o = Json::obj();
        for (src, _, frac) in &d.rows {
            o.set(src.name(), *frac);
        }
        dists.set(bench, o);
    }
    j.set("distributions", dists);
    j
}

/// The method list of Table 1 (and Table 4).
pub fn table1_methods() -> Vec<Method> {
    let p = |b: u8| Method::Qless(Precision::new(b, Scheme::Absmax).unwrap());
    vec![
        Method::Random100,
        Method::RandomFrac,
        p(16), // LESS
        p(8),
        p(4),
        p(2),
        p(1),
    ]
}

/// Table 1 / Table 4: selection methods × storage × benchmarks, per model.
pub fn table1(base_cfg: &Config, scale: Scale) -> Result<()> {
    let mut report = Report::new("table1", "Data selection methods vs storage (paper Tables 1 & 4)");
    let mut all_json = Json::obj();
    for model in scale.table_models() {
        let mut cfg = base_cfg.clone();
        scale.apply(&mut cfg, model);
        cfg.run_dir = format!("runs/table1_{model}_s{}", cfg.seed);
        let mut pipe = Pipeline::new(cfg.clone())?;
        // ONE streamed extraction pass pre-builds every method's datastore
        // (the Table-1 sweep); run_method then reuses them from cache
        let sweep: Vec<Precision> = table1_methods()
            .iter()
            .filter_map(|m| match m {
                Method::Qless(p) => Some(*p),
                _ => None,
            })
            .collect();
        pipe.build_datastores(&sweep)?;
        let mut t = Table::new(
            &format!("SimLM-{model} ({} params)", pipe.info.d_base + pipe.info.d_lora),
            &["Data Selection", "Storage", "SynQA", "SynMC", "SynArith", "Avg"],
        );
        let mut model_json = Json::obj();
        for method in table1_methods() {
            let r = pipe.run_method(method)?;
            let storage = matches!(method, Method::Qless(_)).then_some(r.storage_bytes);
            t.row(method_row(&r.label, storage, &r));
            model_json.set(&r.label, result_json(&r));
        }
        for col in 2..6 {
            t.mark_best(col, true);
        }
        report.add_table(t);
        // per-stage wall-clock for this model's whole method grid, with a
        // JSON mirror that survives the report.json assignment below
        report.add_table(pipe.stage_table());
        model_json.set("stage_costs", pipe.stages.to_json());
        all_json.set(model, model_json);
    }
    report.json = all_json;
    report.note("Benchmarks: SynQA→TyDiQA, SynMC→MMLU, SynArith→BBH (DESIGN.md §2).");
    report.note("Storage is the measured datastore file size (codes+scales+η).");
    report.emit(std::path::Path::new("reports"))?;
    Ok(())
}

/// Table 2 / Table 5: model quantization (16/8/4-bit weights, QLoRA
/// ablation) × gradient quantization grid on one model.
pub fn table2(base_cfg: &Config, scale: Scale) -> Result<()> {
    let model = if scale.fast { "tiny" } else { "small" };
    let mut report = Report::new(
        "table2",
        "Model quantization × gradient quantization (paper Tables 2 & 5)",
    );
    let mut t = Table::new(
        &format!("SimLM-{model}"),
        &["Model Q", "Grad Q", "SynQA", "SynMC", "SynArith", "Avg"],
    );
    let mut j = Json::obj();
    let grad_bits: &[u8] = if scale.fast { &[16, 4, 1] } else { &[16, 8, 4, 2, 1] };
    for model_bits in [16u8, 8, 4] {
        let mut cfg = base_cfg.clone();
        scale.apply(&mut cfg, model);
        cfg.model_bits = model_bits;
        cfg.run_dir = format!("runs/table2_{model}_m{model_bits}_s{}", cfg.seed);
        let mut pipe = Pipeline::new(cfg)?;
        // one extraction pass per model-bits cell covers its grad-Q row
        let sweep: Vec<Precision> =
            grad_bits.iter().map(|&b| Precision::new(b, Scheme::Absmax).unwrap()).collect();
        pipe.build_datastores(&sweep)?;
        let mut mb_json = Json::obj();
        for &bits in grad_bits {
            let p = Precision::new(bits, Scheme::Absmax).unwrap();
            let r = pipe.run_method(Method::Qless(p))?;
            let mut row = vec![format!("{model_bits}-bit"), p.label()];
            for b in BENCH_COLS {
                row.push(pct(r.scores[b]));
            }
            row.push(pct(r.average));
            t.row(row);
            mb_json.set(&p.label(), result_json(&r));
        }
        j.set(&format!("model_{model_bits}bit"), mb_json);
    }
    for col in 2..6 {
        t.mark_best(col, true);
    }
    report.add_table(t);
    report.json = j;
    report.note("Weight quantization: blockwise int8 (LLM.int8 analogue) / NF4 (QLoRA), applied during gradient extraction.");
    report.emit(std::path::Path::new("reports"))?;
    Ok(())
}

/// Table 3: absmax vs absmean vs sign across bit widths.
pub fn table3(base_cfg: &Config, scale: Scale) -> Result<()> {
    let model = if scale.fast { "tiny" } else { "small" };
    let mut cfg = base_cfg.clone();
    scale.apply(&mut cfg, model);
    cfg.run_dir = format!("runs/table3_{model}_s{}", cfg.seed);
    let mut pipe = Pipeline::new(cfg.clone())?;

    let mut report = Report::new("table3", "Quantization scheme ablation (paper Table 3)");
    let mut t = Table::new(
        &format!("SimLM-{model}"),
        &["Q Scheme", "Grad Q", "SynQA", "SynMC", "SynArith", "Avg"],
    );
    let mut j = Json::obj();

    let mut runs: Vec<(String, Precision)> =
        vec![("-".into(), Precision::new(16, Scheme::Absmax).unwrap())];
    let bit_list: &[u8] = if scale.fast { &[4, 2] } else { &[8, 4, 2] };
    for &b in bit_list {
        runs.push(("Absmax".into(), Precision::new(b, Scheme::Absmax).unwrap()));
    }
    for &b in bit_list {
        runs.push(("Absmean".into(), Precision::new(b, Scheme::Absmean).unwrap()));
    }
    runs.push(("Sign".into(), Precision::new(1, Scheme::Sign).unwrap()));

    // one extraction pass emits the whole scheme × bitwidth grid
    let sweep: Vec<Precision> = runs.iter().map(|(_, p)| *p).collect();
    pipe.build_datastores(&sweep)?;

    for (scheme_label, p) in runs {
        let r = pipe.run_method(Method::Qless(p))?;
        let mut row = vec![scheme_label, format!("{}-bit", p.bits)];
        for b in BENCH_COLS {
            row.push(pct(r.scores[b]));
        }
        row.push(pct(r.average));
        t.row(row);
        j.set(&format!("{}_{}", p.scheme, p.bits), result_json(&r));
    }
    for col in 2..6 {
        t.mark_best(col, true);
    }
    report.add_table(t);
    report.json = j;
    // after the json assignment so the stage_costs key survives
    report.add_stage_costs(&pipe.stages);
    report.note("Paper finding to check: absmean ≥ absmax at coarse bit widths (zero-bin effect), absmax better at 8/16-bit.");
    report.emit(std::path::Path::new("reports"))?;
    Ok(())
}

/// Benchmark-aligned source check used by integration tests: the Fig. 5
/// expectation that each benchmark's selection over-represents its aligned
/// source relative to the corpus mix.
pub fn alignment_score(r: &MethodResult) -> BTreeMap<&'static str, f64> {
    let mut out = BTreeMap::new();
    for bench in Benchmark::ALL {
        let d = &r.distributions[bench.name()];
        out.insert(bench.name(), d.frac(bench.aligned_source()));
    }
    out
}
