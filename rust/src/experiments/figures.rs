//! Figure harnesses: Fig. 1 (method comparison), Fig. 3 (bin occupancy),
//! Fig. 4 (selection-budget sweep), Fig. 5 (subset composition).

use anyhow::{Context, Result};

use crate::config::Config;
use crate::eval::Benchmark;
use crate::pipeline::{Pipeline, Report};
use crate::quant::{BinHistogram, Precision, Scheme};
use crate::select::{select_top_frac, SourceDistribution};
use crate::util::json::Json;
use crate::util::table::{pct, Table};

use super::Scale;

/// Fig. 1: average performance per selection method, aggregated across the
/// models of table1 — reads `reports/table1.json` (run `xp table1` first).
pub fn fig1(_cfg: &Config) -> Result<()> {
    let text = std::fs::read_to_string("reports/table1.json")
        .context("reports/table1.json missing — run `qless xp table1` first")?;
    let j = Json::parse(&text)?;
    let models = j.as_obj()?;
    let mut by_method: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
    for (_model, methods) in models {
        for (label, r) in methods.as_obj()? {
            by_method
                .entry(label.clone())
                .or_default()
                .push(r.req("average")?.as_f64()?);
        }
    }
    let mut report = Report::new("fig1", "Method comparison, averaged across models (paper Fig. 1)");
    let mut t = Table::new("", &["Method", "Avg performance", "Bar"]);
    let mut j_out = Json::obj();
    let mut rows: Vec<(String, f64)> = by_method
        .into_iter()
        .map(|(k, v)| (k, v.iter().sum::<f64>() / v.len() as f64))
        .collect();
    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (label, avg) in rows {
        let bar = "█".repeat((avg * 60.0).round() as usize);
        t.row(vec![label.clone(), pct(avg), bar]);
        j_out.set(&label, avg);
    }
    t.mark_best(1, true);
    report.add_table(t);
    report.json = j_out;
    report.emit(std::path::Path::new("reports"))?;
    Ok(())
}

/// Fig. 3: quantization-bin occupancy, absmax vs absmean, on *real*
/// extracted gradient features (checkpoint 0 of the warmup).
pub fn fig3(base_cfg: &Config, scale: Scale) -> Result<()> {
    let model = if scale.fast { "tiny" } else { "small" };
    let mut cfg = base_cfg.clone();
    scale.apply(&mut cfg, model);
    // fig3 only needs features, not fine-tunes — shrink further
    cfg.corpus_size = cfg.corpus_size.min(1200);
    cfg.run_dir = format!("runs/fig3_{model}_s{}", cfg.seed);
    let mut pipe = Pipeline::new(cfg)?;
    // dense features are the explicit small-run opt-in (fig3 shrinks the
    // corpus above); the datastore build path streams instead
    let feats = pipe.train_features_dense()?;
    let block0 = &feats[0];

    let mut report = Report::new("fig3", "Quantization bin occupancy (paper Fig. 3)");
    let mut t = Table::new(
        "zero-bin occupancy (fraction of codes = 0)",
        &["Bits", "absmax zero-bin", "absmean zero-bin"],
    );
    let mut j = Json::obj();
    for bits in [8u8, 4, 2] {
        let mut hmax = BinHistogram::new(bits, Scheme::Absmax);
        let mut hmean = BinHistogram::new(bits, Scheme::Absmean);
        for i in 0..block0.n {
            hmax.add_row(block0.row(i));
            hmean.add_row(block0.row(i));
        }
        t.row(vec![
            format!("{bits}"),
            format!("{:.3}", hmax.zero_bin_frac()),
            format!("{:.3}", hmean.zero_bin_frac()),
        ]);
        let mut o = Json::obj();
        o.set("absmax_zero", hmax.zero_bin_frac());
        o.set("absmean_zero", hmean.zero_bin_frac());
        j.set(&format!("bits_{bits}"), o);
        if bits == 2 {
            report.note(format!("absmax 2-bit histogram:\n{}", hmax.ascii()));
            report.note(format!("absmean 2-bit histogram:\n{}", hmean.ascii()));
        }
    }
    // 1-bit: no zero bin by construction
    let mut h1 = BinHistogram::new(1, Scheme::Sign);
    for i in 0..block0.n {
        h1.add_row(block0.row(i));
    }
    t.row(vec!["1 (sign)".into(), "0.000".into(), "0.000".into()]);
    j.set("bits_1_density", h1.density());
    report.add_table(t);
    report.json = j;
    report.note("Paper claim: absmax collapses most values into the zero bin at 2/4-bit; absmean yields denser codes; 1-bit has no zero bin.");
    report.emit(std::path::Path::new("reports"))?;
    Ok(())
}

/// Fig. 4: performance vs selected-data percentage at 1-bit gradients.
pub fn fig4(base_cfg: &Config, scale: Scale) -> Result<()> {
    let model = if scale.fast { "tiny" } else { "small" };
    let mut cfg = base_cfg.clone();
    scale.apply(&mut cfg, model);
    cfg.run_dir = format!("runs/fig4_{model}_s{}", cfg.seed);
    let fracs: &[f64] = if scale.fast {
        &[0.001, 0.01, 0.05, 0.10]
    } else {
        &[0.001, 0.005, 0.01, 0.02, 0.05, 0.10]
    };

    let mut report = Report::new("fig4", "Performance vs selected percentage, 1-bit store (paper Fig. 4)");
    let mut t = Table::new(
        &format!("SimLM-{model}, QLESS 1-bit"),
        &["Selected %", "SynQA", "SynMC", "SynArith", "Avg"],
    );
    let mut j = Json::obj();
    let mut pipe = Pipeline::new(cfg.clone())?;
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let (ds, _) = pipe.build_datastore(p1)?;
    for &frac in fracs {
        let mut scores_row = Vec::new();
        let mut j_b = Json::obj();
        for bench in Benchmark::ALL {
            let scores = pipe.influence_scores(&ds, bench)?;
            let sel = select_top_frac(&scores, frac);
            let (lora, _) = pipe.finetune(&sel, cfg.seed)?;
            let s = pipe.evaluate_lora(&lora)?;
            scores_row.push(s.get(bench));
            j_b.set(bench.name(), s.get(bench));
        }
        let avg = scores_row.iter().sum::<f64>() / scores_row.len() as f64;
        t.row(vec![
            format!("{:.1}%", frac * 100.0),
            pct(scores_row[0]),
            pct(scores_row[1]),
            pct(scores_row[2]),
            pct(avg),
        ]);
        j_b.set("avg", avg);
        j.set(&format!("frac_{frac}"), j_b);
    }
    report.add_table(t);
    report.json = j;
    report.note("Paper finding to check: performance plateaus from ~0.5% and 0.1% is not enough.");
    report.emit(std::path::Path::new("reports"))?;
    Ok(())
}

/// Fig. 5: source composition of the top-5% selection per quantization
/// level and benchmark. Selection-only (no fine-tunes) — cheap.
pub fn fig5(base_cfg: &Config, scale: Scale) -> Result<()> {
    let model = if scale.fast { "tiny" } else { "small" };
    let mut cfg = base_cfg.clone();
    scale.apply(&mut cfg, model);
    cfg.run_dir = format!("runs/fig5_{model}_s{}", cfg.seed);
    let mut pipe = Pipeline::new(cfg.clone())?;

    let mut report = Report::new("fig5", "Top-5% subset composition per quantization level (paper Fig. 5)");
    let mut j = Json::obj();
    // one extraction pass emits all five precision datastores
    let precisions: Vec<Precision> = [16u8, 8, 4, 2, 1]
        .iter()
        .map(|&b| Precision::new(b, if b == 1 { Scheme::Sign } else { Scheme::Absmax }).unwrap())
        .collect();
    let stores = pipe.build_datastores(&precisions)?;
    for bench in Benchmark::ALL {
        let mut t = Table::new(
            &format!("{bench} (aligned source: {})", bench.aligned_source()),
            &["Precision", "synflan", "syncot", "syndolly", "synoasst", "L1 vs 16-bit"],
        );
        let mut dist16: Option<SourceDistribution> = None;
        let mut j_b = Json::obj();
        for (p, (ds, _)) in precisions.iter().zip(&stores) {
            let (bits, p) = (p.bits, *p);
            let scores = pipe.influence_scores(ds, bench)?;
            let sel = select_top_frac(&scores, cfg.select_frac);
            let dist = SourceDistribution::of(&pipe.corpus.samples, &sel);
            let l1 = dist16.as_ref().map(|d| format!("{:.3}", d.l1_distance(&dist))).unwrap_or("-".into());
            t.row(vec![
                p.label(),
                format!("{:.1}%", dist.rows[0].2 * 100.0),
                format!("{:.1}%", dist.rows[1].2 * 100.0),
                format!("{:.1}%", dist.rows[2].2 * 100.0),
                format!("{:.1}%", dist.rows[3].2 * 100.0),
                l1,
            ]);
            let mut j_p = Json::obj();
            for (src, _, frac) in &dist.rows {
                j_p.set(src.name(), *frac);
            }
            j_b.set(&p.label(), j_p);
            if bits == 16 {
                dist16 = Some(dist);
            }
        }
        report.add_table(t);
        j.set(bench.name(), j_b);
    }
    report.json = j;
    report.note("Corpus mix is 37/37/6/20% (synflan/syncot/syndolly/synoasst).");
    report.note("Paper claim: composition stable at 16/8/4/1-bit, shifts most at 2-bit.");
    report.emit(std::path::Path::new("reports"))?;
    Ok(())
}
