//! Native influence paths: generic f32 cosine and the packed 1-bit
//! XNOR+popcount kernel.
//!
//! The popcount path is the performance centerpiece: for ±1 codes, cosine
//! similarity reduces to bit agreement,
//! `cos = (2·agree − k)/k`, computable at 64 dims per instruction over the
//! datastore's packed words with no dequantization, no normalization and
//! 1/32 the memory traffic of f32 — see EXPERIMENTS.md §Perf.
//!
//! Both kernels score a [`RowsView`] — a whole checkpoint block or one
//! streamed shard — so the block and streaming scan paths share one
//! per-row implementation and are bit-identical by construction. Row
//! parallelism runs on the persistent scan pool (`util::pool`): no
//! per-call thread spawns, no thread-count cap.

use crate::datastore::{CheckpointBlock, RowsView};
use crate::grads::FeatureMatrix;
use crate::quant::pack::{as_sign_words, pack_codes};
use crate::quant::scheme::{normalize_row, quantize_row};
use crate::quant::Precision;

/// Validation-side features prepared for scoring at a given precision:
/// quantized-normalized f32 rows, plus packed sign words at 1-bit.
#[derive(Debug, Clone)]
pub struct ValFeatures {
    pub k: usize,
    /// `[n_val][k]` quantized → normalized rows.
    pub rows: Vec<Vec<f32>>,
    /// Packed sign words per row (populated only at 1-bit).
    pub sign_words: Vec<Vec<u64>>,
}

impl ValFeatures {
    /// Fallible [`ValFeatures::prepare`]: rejects non-finite validation
    /// gradients with a recoverable error instead of aborting — the form
    /// `score_datastore` uses, so one NaN val gradient fails the scan, not
    /// the process.
    pub fn try_prepare(feats: &FeatureMatrix, precision: Precision) -> anyhow::Result<ValFeatures> {
        let mut rows = Vec::with_capacity(feats.n);
        let mut sign_words = Vec::new();
        for i in 0..feats.n {
            let raw = feats.row(i);
            // checked for every bitwidth (16-bit skips quantize_row) so a
            // NaN val gradient can't poison every score silently
            if let Some(j) = raw.iter().position(|x| !x.is_finite()) {
                anyhow::bail!(
                    "non-finite validation gradient feature {} at row {i} index {j}: \
                     rejected at preparation time",
                    raw[j]
                );
            }
            let mut row: Vec<f32> = if precision.bits == 16 {
                raw.to_vec()
            } else {
                let q = quantize_row(raw, precision.bits, precision.scheme);
                if precision.bits == 1 {
                    let packed = pack_codes(&q.codes, 1, q.scale).expect("pack 1-bit");
                    sign_words.push(as_sign_words(&packed));
                }
                q.codes.iter().map(|&c| c as f32).collect()
            };
            normalize_row(&mut row);
            rows.push(row);
        }
        Ok(ValFeatures { k: feats.k, rows, sign_words })
    }

    /// Quantize raw validation gradient features with the datastore's
    /// precision, then normalize (paper: "validation gradients are
    /// quantized and normalized, yielding q̂_{z'}"). Panics on non-finite
    /// input; callers with a `Result` path should use [`Self::try_prepare`].
    pub fn prepare(feats: &FeatureMatrix, precision: Precision) -> ValFeatures {
        Self::try_prepare(feats, precision).expect("preparing validation features")
    }

    pub fn n(&self) -> usize {
        self.rows.len()
    }
}

/// Mean cosine similarity of each train row against all val rows: the
/// inner term of Eq. 7 for one checkpoint. Whole-block convenience wrapper
/// over [`scores_dense_rows`].
pub fn scores_dense(block: &CheckpointBlock, val: &ValFeatures) -> Vec<f32> {
    scores_dense_rows(&block.rows(), val)
}

/// [`scores_dense`] over any row view (block or streamed shard). Generic
/// path — works for every precision by unpacking codes to f32.
pub fn scores_dense_rows(rows: &RowsView<'_>, val: &ValFeatures) -> Vec<f32> {
    assert_eq!(rows.k, val.k);
    let nv = val.n() as f32;
    // work per row ≈ nv·k fused-multiply-adds (plus unpack)
    par_over_rows(rows.n(), (val.n() * rows.k) as u64, |i| {
        let mut row = if rows.precision.bits == 16 {
            rows.row_f32(i)
        } else {
            rows.row_codes(i).iter().map(|&c| c as f32).collect()
        };
        normalize_row(&mut row);
        let mut acc = 0f32;
        for v in &val.rows {
            acc += dot(&row, v);
        }
        acc / nv
    })
}

/// Evaluate `f(i)` for each row index in parallel (order-preserving).
///
/// `work_per_row` is an estimate of the inner-op count per row; jobs below
/// ~8M total ops stay serial — handing a 1.4ms popcount scan to the pool
/// costs more in wakeup latency than it saves (§Perf iteration 2 measured
/// the same effect with spawned threads at 2.6× worse). Larger jobs run on
/// the persistent worker pool: threads follow `QLESS_SCORE_THREADS` or the
/// machine's full parallelism (the old hard cap of 16 is gone), and rows
/// are claimed from a shared cursor so uneven rows can't straggle.
/// `QLESS_SCORE_THREADS=1` forces the serial path (before/after benches).
fn par_over_rows<F: Fn(usize) -> f32 + Sync>(n: usize, work_per_row: u64, f: F) -> Vec<f32> {
    let threads = crate::util::pool::scan_threads().min(n.max(1));
    if threads <= 1 || n < 256 || (n as u64).saturating_mul(work_per_row) < 8_000_000 {
        return (0..n).map(f).collect();
    }
    let mut out = vec![0f32; n];
    crate::util::pool::par_fill_f32(&mut out, &f);
    out
}

/// The 1-bit fast path: XNOR+popcount over packed words, no unpacking.
/// Whole-block convenience wrapper over [`scores_1bit_rows`].
pub fn scores_1bit(block: &CheckpointBlock, val: &ValFeatures) -> Vec<f32> {
    scores_1bit_rows(&block.rows(), val)
}

/// [`scores_1bit`] over any row view. Identical results to
/// [`scores_dense_rows`] on a 1-bit view (up to fp rounding of the final
/// division). Streams each row through a fixed 64-word stack window, so
/// any projection dimension is supported — the seed implementation sliced
/// a `[u64; 64]` buffer by `k/64` words and panicked for k > 4096.
pub fn scores_1bit_rows(rows: &RowsView<'_>, val: &ValFeatures) -> Vec<f32> {
    assert_eq!(rows.precision.bits, 1, "1-bit path needs a sign datastore");
    assert!(!val.sign_words.is_empty(), "val features lack sign words");
    let k = rows.k;
    let nwords = k.div_ceil(64);
    let tail = (nwords * 64 - k) as i64;
    let nv = val.sign_words.len();
    let inv_k = 1.0 / k as f32;

    // work per row ≈ nv·nwords popcount iterations (~1.4 ns each — tiny;
    // this path only crosses the parallel threshold at ≫10⁴ rows)
    par_over_rows(rows.n(), (nv * nwords) as u64, |i| {
        let row = rows.row_bytes(i);
        // Bit agreement is summed exactly in i64 across all val rows and
        // words; the per-val-row dot products are linear in agreement, so
        // one conversion at the end loses nothing:
        //   Σ_v dot_v = 2·(Σ_v agree_v − nv·tail) − nv·k
        let mut total_agree: i64 = 0;
        let mut word_base = 0usize;
        // 512-byte (64-word) window: fixed stack buffer, unbounded k
        for byte_chunk in row.chunks(512) {
            let mut words = [0u64; 64];
            let cw = byte_chunk.len().div_ceil(8);
            for (w, ch) in words.iter_mut().zip(byte_chunk.chunks(8)) {
                let mut b = [0u8; 8];
                b[..ch.len()].copy_from_slice(ch);
                *w = u64::from_le_bytes(b);
            }
            for v in &val.sign_words {
                for (a, b) in words[..cw].iter().zip(&v[word_base..word_base + cw]) {
                    total_agree += (!(a ^ b)).count_ones() as i64;
                }
            }
            word_base += cw;
        }
        // remove the always-agreeing zero tail, convert to mean cosine
        let total_dot = 2 * (total_agree - nv as i64 * tail) - (nv * k) as i64;
        (total_dot as f32 * inv_k) / nv as f32
    })
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // 4-way unrolled accumulation (autovectorizes well)
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::{Datastore, DatastoreWriter};
    use crate::quant::Scheme;
    use crate::util::Rng;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "qless_inf_{tag}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    fn feats(n: usize, k: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() }
    }

    fn make_block(bits: u8, n: usize, k: usize, seed: u64) -> CheckpointBlock {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = tmpfile(&format!("b{bits}_{seed}"));
        let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
        let f = feats(n, k, seed);
        w.begin_checkpoint(1.0).unwrap();
        for i in 0..n {
            w.append_features(f.row(i)).unwrap();
        }
        w.end_checkpoint().unwrap();
        w.finalize().unwrap();
        let ds = Datastore::open(&path).unwrap();
        let block = ds.load_checkpoint(0).unwrap();
        std::fs::remove_file(&path).ok();
        block
    }

    #[test]
    fn dense_scores_bounded_and_finite() {
        for bits in [16u8, 8, 4, 2, 1] {
            let block = make_block(bits, 12, 96, 1);
            let val = ValFeatures::prepare(
                &feats(5, 96, 2),
                Precision::new(bits, if bits == 1 { Scheme::Sign } else { Scheme::Absmax })
                    .unwrap(),
            );
            let s = scores_dense(&block, &val);
            assert_eq!(s.len(), 12);
            assert!(s.iter().all(|x| x.is_finite() && x.abs() <= 1.0 + 1e-5), "{bits}: {s:?}");
        }
    }

    #[test]
    fn popcount_matches_dense_exactly() {
        for (k, seed) in [(64usize, 3u64), (96, 4), (128, 5), (65, 6), (512, 7)] {
            let block = make_block(1, 10, k, seed);
            let val = ValFeatures::prepare(
                &feats(7, k, seed + 100),
                Precision::new(1, Scheme::Sign).unwrap(),
            );
            let dense = scores_dense(&block, &val);
            let fast = scores_1bit(&block, &val);
            for (a, b) in dense.iter().zip(&fast) {
                assert!((a - b).abs() < 1e-5, "k={k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn popcount_k8192_regression() {
        // Seed code copied each row into a fixed `[0u64; 64]` buffer and
        // sliced `words[..nwords]` — nwords = 128 at k = 8192, so the
        // release build panicked (and debug builds tripped the
        // debug_assert). The windowed kernel must handle any k and still
        // match the dense path.
        let k = 8192;
        let block = make_block(1, 4, k, 42);
        let val =
            ValFeatures::prepare(&feats(3, k, 43), Precision::new(1, Scheme::Sign).unwrap());
        let dense = scores_dense(&block, &val);
        let fast = scores_1bit(&block, &val);
        assert_eq!(fast.len(), 4);
        for (a, b) in dense.iter().zip(&fast) {
            assert!((a - b).abs() < 1e-5, "k=8192: {a} vs {b}");
        }
    }

    #[test]
    fn shard_views_score_identically_to_block() {
        // The kernels take a RowsView; a sub-view over the same bytes must
        // give bit-identical scores to the whole block's rows.
        for bits in [16u8, 8, 1] {
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let block = make_block(bits, 12, 96, 8);
            let val = ValFeatures::prepare(&feats(5, 96, 9), Precision::new(bits, scheme).unwrap());
            let whole = if bits == 1 {
                scores_1bit(&block, &val)
            } else {
                scores_dense(&block, &val)
            };
            // split the block's rows into two shard-like views
            let full = block.rows();
            let split = 5usize;
            for (start, end) in [(0usize, split), (split, 12)] {
                let view = RowsView {
                    precision: full.precision,
                    k: full.k,
                    row_stride: full.row_stride,
                    scales: if bits == 16 {
                        full.scales
                    } else {
                        &full.scales[start..end]
                    },
                    data: &full.data[start * full.row_stride..end * full.row_stride],
                };
                let part = if bits == 1 {
                    scores_1bit_rows(&view, &val)
                } else {
                    scores_dense_rows(&view, &val)
                };
                assert_eq!(part.as_slice(), &whole[start..end], "bits {bits} [{start},{end})");
            }
        }
    }

    #[test]
    fn self_similarity_ranks_first() {
        // A train row identical to the single val row must get score 1.
        let k = 128;
        let f = feats(6, k, 9);
        let val_raw = FeatureMatrix { n: 1, k, data: f.row(3).to_vec() };
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let block = make_block(8, 6, k, 9);
        let val = ValFeatures::prepare(&val_raw, p);
        let s = scores_dense(&block, &val);
        let best = s.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap();
        assert_eq!(best.0, 3);
        assert!(*best.1 > 0.99, "{s:?}");
    }

    #[test]
    fn scale_cancels_in_scoring() {
        // Scaling raw val features must not change prepared rows.
        let k = 64;
        let f = feats(3, k, 11);
        let scaled = FeatureMatrix { n: 3, k, data: f.data.iter().map(|x| x * 123.0).collect() };
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let a = ValFeatures::prepare(&f, p);
        let b = ValFeatures::prepare(&scaled, p);
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            for (x, y) in ra.iter().zip(rb) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(12);
        let a: Vec<f32> = (0..103).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..103).map(|_| rng.normal() as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }
}
