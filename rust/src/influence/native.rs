//! Native influence paths: generic f32 cosine and the packed 1-bit
//! XNOR+popcount kernel.
//!
//! The popcount path is the performance centerpiece: for ±1 codes, cosine
//! similarity reduces to bit agreement,
//! `cos = (2·agree − k)/k`, computable at 64 dims per instruction over the
//! datastore's packed words with no dequantization, no normalization and
//! 1/32 the memory traffic of f32 — see EXPERIMENTS.md §Perf.

use crate::datastore::CheckpointBlock;
use crate::grads::FeatureMatrix;
use crate::quant::pack::{as_sign_words, pack_codes};
use crate::quant::scheme::{normalize_row, quantize_row};
use crate::quant::Precision;

/// Validation-side features prepared for scoring at a given precision:
/// quantized-normalized f32 rows, plus packed sign words at 1-bit.
#[derive(Debug, Clone)]
pub struct ValFeatures {
    pub k: usize,
    /// `[n_val][k]` quantized → normalized rows.
    pub rows: Vec<Vec<f32>>,
    /// Packed sign words per row (populated only at 1-bit).
    pub sign_words: Vec<Vec<u64>>,
}

impl ValFeatures {
    /// Quantize raw validation gradient features with the datastore's
    /// precision, then normalize (paper: "validation gradients are
    /// quantized and normalized, yielding q̂_{z'}").
    pub fn prepare(feats: &FeatureMatrix, precision: Precision) -> ValFeatures {
        let mut rows = Vec::with_capacity(feats.n);
        let mut sign_words = Vec::new();
        for i in 0..feats.n {
            let raw = feats.row(i);
            let mut row: Vec<f32> = if precision.bits == 16 {
                raw.to_vec()
            } else {
                let q = quantize_row(raw, precision.bits, precision.scheme);
                if precision.bits == 1 {
                    let packed = pack_codes(&q.codes, 1, q.scale).expect("pack 1-bit");
                    sign_words.push(as_sign_words(&packed));
                }
                q.codes.iter().map(|&c| c as f32).collect()
            };
            normalize_row(&mut row);
            rows.push(row);
        }
        ValFeatures { k: feats.k, rows, sign_words }
    }

    pub fn n(&self) -> usize {
        self.rows.len()
    }
}

/// Mean cosine similarity of each train row in `block` against all val
/// rows: the inner term of Eq. 7 for one checkpoint. Generic path — works
/// for every precision by unpacking codes to f32. Row-parallel across a
/// thread pool (§Perf iteration 1: 1 → N cores on the scan).
pub fn scores_dense(block: &CheckpointBlock, val: &ValFeatures) -> Vec<f32> {
    assert_eq!(block.k, val.k);
    let nv = val.n() as f32;
    // work per row ≈ nv·k fused-multiply-adds (plus unpack)
    par_over_rows(block.n, (val.n() * block.k) as u64, |i| {
        let mut row = if block.precision.bits == 16 {
            block.row_f32(i)
        } else {
            block.row_codes(i).iter().map(|&c| c as f32).collect()
        };
        normalize_row(&mut row);
        let mut acc = 0f32;
        for v in &val.rows {
            acc += dot(&row, v);
        }
        acc / nv
    })
}

/// Evaluate `f(i)` for each row index in parallel chunks (order-preserving).
///
/// `work_per_row` is an estimate of the inner-op count per row; jobs below
/// ~8M total ops stay serial — thread-scope spawn costs ~100µs/thread,
/// which §Perf iteration 2 found *regresses* the 1-bit popcount path
/// (1.4ms of work) by 2.6× when parallelized unconditionally.
/// `QLESS_SCORE_THREADS=1` forces the serial path (before/after benches).
fn par_over_rows<F: Fn(usize) -> f32 + Sync>(n: usize, work_per_row: u64, f: F) -> Vec<f32> {
    let threads = std::env::var("QLESS_SCORE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
        })
        .max(1)
        .min(16)
        .min(n.max(1));
    if threads <= 1 || n < 256 || (n as u64) * work_per_row < 8_000_000 {
        return (0..n).map(f).collect();
    }
    let mut out = vec![0f32; n];
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let start = t * chunk;
                for (j, o) in slice.iter_mut().enumerate() {
                    *o = f(start + j);
                }
            });
        }
    });
    out
}

/// The 1-bit fast path: XNOR+popcount over packed words, no unpacking.
/// Identical results to [`scores_dense`] on a 1-bit block (up to fp
/// rounding of the final division).
pub fn scores_1bit(block: &CheckpointBlock, val: &ValFeatures) -> Vec<f32> {
    assert_eq!(block.precision.bits, 1, "1-bit path needs a sign datastore");
    assert!(!val.sign_words.is_empty(), "val features lack sign words");
    let k = block.k;
    let nwords = k.div_ceil(64);
    let tail = (nwords * 64 - k) as i64;
    let nv = val.sign_words.len() as f32;
    let inv_k = 1.0 / k as f32;

    // work per row ≈ nv·nwords popcount iterations (~1.4 ns each — tiny;
    // this path only crosses the parallel threshold at ≫10⁴ rows)
    par_over_rows(block.n, (val.sign_words.len() * nwords) as u64, |i| {
        let row = block.row_bytes(i);
        // view row bytes as u64 words (little-endian, zero tail)
        let mut words = [0u64; 64]; // k ≤ 4096 in practice
        debug_assert!(nwords <= 64);
        for (w, chunk) in words.iter_mut().zip(row.chunks(8)) {
            let mut b = [0u8; 8];
            b[..chunk.len()].copy_from_slice(chunk);
            *w = u64::from_le_bytes(b);
        }
        let mut acc = 0f32;
        for v in &val.sign_words {
            let mut agree: i64 = 0;
            for (a, b) in words[..nwords].iter().zip(v) {
                agree += (!(a ^ b)).count_ones() as i64;
            }
            // remove always-agreeing zero tail, convert to dot product
            let dot = 2 * (agree - tail) - k as i64;
            acc += dot as f32 * inv_k;
        }
        acc / nv
    })
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // 4-way unrolled accumulation (autovectorizes well)
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::{Datastore, DatastoreWriter};
    use crate::quant::Scheme;
    use crate::util::Rng;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "qless_inf_{tag}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    fn feats(n: usize, k: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() }
    }

    fn make_block(bits: u8, n: usize, k: usize, seed: u64) -> CheckpointBlock {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = tmpfile(&format!("b{bits}_{seed}"));
        let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
        let f = feats(n, k, seed);
        w.begin_checkpoint(1.0).unwrap();
        for i in 0..n {
            w.append_features(f.row(i)).unwrap();
        }
        w.end_checkpoint().unwrap();
        w.finalize().unwrap();
        let ds = Datastore::open(&path).unwrap();
        let block = ds.load_checkpoint(0).unwrap();
        std::fs::remove_file(&path).ok();
        block
    }

    #[test]
    fn dense_scores_bounded_and_finite() {
        for bits in [16u8, 8, 4, 2, 1] {
            let block = make_block(bits, 12, 96, 1);
            let val = ValFeatures::prepare(
                &feats(5, 96, 2),
                Precision::new(bits, if bits == 1 { Scheme::Sign } else { Scheme::Absmax })
                    .unwrap(),
            );
            let s = scores_dense(&block, &val);
            assert_eq!(s.len(), 12);
            assert!(s.iter().all(|x| x.is_finite() && x.abs() <= 1.0 + 1e-5), "{bits}: {s:?}");
        }
    }

    #[test]
    fn popcount_matches_dense_exactly() {
        for (k, seed) in [(64usize, 3u64), (96, 4), (128, 5), (65, 6), (512, 7)] {
            let block = make_block(1, 10, k, seed);
            let val = ValFeatures::prepare(
                &feats(7, k, seed + 100),
                Precision::new(1, Scheme::Sign).unwrap(),
            );
            let dense = scores_dense(&block, &val);
            let fast = scores_1bit(&block, &val);
            for (a, b) in dense.iter().zip(&fast) {
                assert!((a - b).abs() < 1e-5, "k={k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn self_similarity_ranks_first() {
        // A train row identical to the single val row must get score 1.
        let k = 128;
        let f = feats(6, k, 9);
        let val_raw = FeatureMatrix { n: 1, k, data: f.row(3).to_vec() };
        let p = Precision::new(8, Scheme::Absmax).unwrap();
        let block = make_block(8, 6, k, 9);
        let val = ValFeatures::prepare(&val_raw, p);
        let s = scores_dense(&block, &val);
        let best = s.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap();
        assert_eq!(best.0, 3);
        assert!(*best.1 > 0.99, "{s:?}");
    }

    #[test]
    fn scale_cancels_in_scoring() {
        // Scaling raw val features must not change prepared rows.
        let k = 64;
        let f = feats(3, k, 11);
        let scaled = FeatureMatrix { n: 3, k, data: f.data.iter().map(|x| x * 123.0).collect() };
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let a = ValFeatures::prepare(&f, p);
        let b = ValFeatures::prepare(&scaled, p);
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            for (x, y) in ra.iter().zip(rb) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(12);
        let a: Vec<f32> = (0..103).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..103).map(|_| rng.normal() as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }
}
