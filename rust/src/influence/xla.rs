//! XLA influence path: drives the L1 Pallas cosine tile
//! (`influence.hlo.txt`, compiled at `[tile_q × k] · [k × tile_v]`) over the
//! full train × val grid, padding tail tiles with zero rows (zero rows
//! normalize to zero and contribute zero similarity — sliced off on read).

use anyhow::Result;

use crate::datastore::{CheckpointBlock, RowsView};
use crate::influence::native::ValFeatures;
use crate::runtime::{Arg, ModelInfo, Runtime};

/// Validation rows packed into zero-padded `[tile_v × k]` kernel tiles —
/// built **once per checkpoint** and reused by every shard of its scan
/// (rebuilding per shard would be an O(nv·k) copy per shard).
pub struct ValTiles {
    nv: usize,
    tiles: Vec<Vec<f32>>,
}

/// Pack prepared val features into kernel tiles for [`scores_xla_rows`].
pub fn pack_val_tiles(info: &ModelInfo, val: &ValFeatures) -> ValTiles {
    assert_eq!(val.k, info.proj_dim);
    let (tv, k) = (info.tile_v, info.proj_dim);
    let nv = val.n();
    let mut tiles = vec![vec![0f32; tv * k]; nv.div_ceil(tv)];
    for (j, row) in val.rows.iter().enumerate() {
        tiles[j / tv][(j % tv) * k..(j % tv + 1) * k].copy_from_slice(row);
    }
    ValTiles { nv, tiles }
}

/// Mean cosine of each train row against all val rows via the AOT kernel.
/// Whole-block convenience wrapper over [`scores_xla_rows`].
pub fn scores_xla(
    rt: &Runtime,
    info: &ModelInfo,
    block: &CheckpointBlock,
    val: &ValFeatures,
) -> Result<Vec<f32>> {
    scores_xla_rows(rt, info, &block.rows(), &pack_val_tiles(info, val))
}

/// [`scores_xla`] over any row view (block or streamed shard). Same
/// contract as [`native::scores_dense_rows`](super::native::scores_dense_rows).
pub fn scores_xla_rows(
    rt: &Runtime,
    info: &ModelInfo,
    rows_view: &RowsView<'_>,
    val_tiles: &ValTiles,
) -> Result<Vec<f32>> {
    assert_eq!(rows_view.k, info.proj_dim);
    let exec = rt.exec(info, "influence")?;
    let (tq, tv, k) = (info.tile_q, info.tile_v, info.proj_dim);
    let nv = val_tiles.nv;
    let n = rows_view.n();

    let mut scores = vec![0f32; n];
    let mut qt = vec![0f32; tq * k];
    for tile_start in (0..n).step_by(tq) {
        let rows = (n - tile_start).min(tq);
        qt.iter_mut().for_each(|x| *x = 0.0);
        for r in 0..rows {
            let row = rows_view.row_f32(tile_start + r); // codes×scale — scale cancels
            qt[r * k..(r + 1) * k].copy_from_slice(&row);
        }
        for (jt, vt) in val_tiles.tiles.iter().enumerate() {
            let out = exec.run(&[Arg::F32(&qt, &[tq, k]), Arg::F32(vt, &[tv, k])])?;
            let sims = &out[0]; // [tq, tv]
            let val_rows = (nv - jt * tv).min(tv);
            for r in 0..rows {
                let mut acc = 0f32;
                for c in 0..val_rows {
                    acc += sims[r * tv + c];
                }
                scores[tile_start + r] += acc;
            }
        }
    }
    let inv = 1.0 / nv as f32;
    scores.iter_mut().for_each(|s| *s *= inv);
    Ok(scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::DatastoreWriter;
    use crate::grads::FeatureMatrix;
    use crate::quant::{Precision, Scheme};
    use crate::util::Rng;
    use std::path::PathBuf;

    fn rt() -> Option<Runtime> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Runtime::new(&p).unwrap())
    }

    #[test]
    fn xla_matches_native_dense() {
        let Some(rt) = rt() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let info = rt.model("tiny").unwrap();
        let k = info.proj_dim;
        // n deliberately NOT a multiple of tile_q; nv not a multiple of tile_v
        let (n, nv) = (info.tile_q + 7, info.tile_v + 3);
        let mut rng = Rng::new(21);
        let f = FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() };
        let vf = FeatureMatrix { n: nv, k, data: (0..nv * k).map(|_| rng.normal() as f32).collect() };
        let p = Precision::new(8, Scheme::Absmax).unwrap();

        let path = std::env::temp_dir().join(format!("qless_xla_{}.qlds", std::process::id()));
        let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
        w.begin_checkpoint(1.0).unwrap();
        for i in 0..n {
            w.append_features(f.row(i)).unwrap();
        }
        w.end_checkpoint().unwrap();
        w.finalize().unwrap();
        let block = crate::datastore::Datastore::open(&path).unwrap().load_checkpoint(0).unwrap();
        std::fs::remove_file(&path).ok();

        let val = ValFeatures::prepare(&vf, p);
        let native = crate::influence::native::scores_dense(&block, &val);
        let xla = scores_xla(&rt, &info, &block, &val).unwrap();
        assert_eq!(native.len(), xla.len());
        for (i, (a, b)) in native.iter().zip(&xla).enumerate() {
            assert!((a - b).abs() < 1e-4, "row {i}: native {a} xla {b}");
        }
    }
}
