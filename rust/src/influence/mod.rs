//! Influence scoring — paper Eq. 7:
//!
//! Inf(z) = Σ_i η_i · mean_{z'∈D_val} ⟨ q̂_{z,i}, q̂_{z',i} ⟩
//!
//! Both sides are quantized-then-normalized (QLESS §3.2); the quantization
//! scale cancels under normalization, so scoring operates on integer codes
//! directly. Three execution paths, all bit-identical in ranking:
//!
//! * [`native`] — dequantize-free f32 cosine over unpacked codes, plus the
//!   1-bit **XNOR+popcount** fast path over packed sign words (the compute
//!   analogue of the paper's 16× storage saving).
//! * [`xla`]    — the L1 Pallas `influence` tile artifact via PJRT, chunked
//!   and padded to the compiled tile shape.
//! * [`aggregate`] — the streaming checkpoint loop: shards of each
//!   datastore block are scored under a memory budget with the chosen
//!   path, weighted by η_i, and accumulated into per-sample totals —
//!   peak resident memory is `O(shard)`, not `O(block)`.

pub mod aggregate;
pub mod native;
pub mod xla;

pub use aggregate::{score_datastore, ScoreOpts};
pub use native::ValFeatures;
