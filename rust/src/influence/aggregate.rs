//! Checkpoint aggregation (the outer sum of Eq. 7):
//! Inf(z) = Σ_i η_i · mean_{z'} ⟨q̂_{z,i}, q̂_{z',i}⟩.
//!
//! For each warmup checkpoint: load its datastore block, prepare the same-
//! checkpoint validation features at the datastore's precision, score with
//! the fastest applicable path (popcount at 1-bit, dense otherwise, or the
//! XLA kernel when requested), weight by the checkpoint's η_i, accumulate.

use anyhow::Result;

use crate::datastore::Datastore;
use crate::grads::FeatureMatrix;
use crate::influence::native::{scores_1bit, scores_dense, ValFeatures};
use crate::influence::xla::scores_xla;
use crate::info;
use crate::runtime::{ModelInfo, Runtime};

#[derive(Debug, Clone, Copy, Default)]
pub struct ScoreOpts {
    /// Route the per-checkpoint scoring through the AOT Pallas kernel.
    pub use_xla: bool,
}

/// Score every training sample in `ds` against per-checkpoint validation
/// features `val_per_ckpt` (raw, unquantized — quantization to the
/// datastore's precision happens here, mirroring §3.2).
///
/// `rt`/`info` are only needed for the XLA path and may be `None` otherwise.
pub fn score_datastore(
    ds: &Datastore,
    val_per_ckpt: &[FeatureMatrix],
    opts: ScoreOpts,
    rt_info: Option<(&Runtime, &ModelInfo)>,
) -> Result<Vec<f32>> {
    let c = ds.n_checkpoints();
    anyhow::ensure!(
        val_per_ckpt.len() == c,
        "validation features for {} checkpoints, datastore has {c}",
        val_per_ckpt.len()
    );
    let n = ds.n_samples();
    let mut total = vec![0f32; n];
    for ci in 0..c {
        let block = ds.load_checkpoint(ci)?;
        let val = ValFeatures::prepare(&val_per_ckpt[ci], block.precision);
        let t0 = std::time::Instant::now();
        let scores = if opts.use_xla {
            let (rt, info) =
                rt_info.ok_or_else(|| anyhow::anyhow!("XLA scoring requires a runtime"))?;
            scores_xla(rt, info, &block, &val)?
        } else if block.precision.bits == 1 {
            scores_1bit(&block, &val)
        } else {
            scores_dense(&block, &val)
        };
        info!(
            "scored checkpoint {ci} (η={:.2e}, {}×{} vs {} val) in {:.2}s",
            block.eta,
            n,
            block.k,
            val.n(),
            t0.elapsed().as_secs_f64()
        );
        for (t, s) in total.iter_mut().zip(&scores) {
            *t += block.eta * s;
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::DatastoreWriter;
    use crate::quant::{Precision, Scheme};
    use crate::util::Rng;

    fn feats(n: usize, k: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() }
    }

    /// Build a datastore and keep its file alive (Datastore reads lazily).
    fn build_ds_keep(bits: u8, etas: &[f32], n: usize, k: usize) -> (Datastore, std::path::PathBuf) {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = std::env::temp_dir().join(format!(
            "qless_aggk_{bits}_e{}_c{}_{}_{:?}.qlds",
            etas[0],
            etas.len(),
            std::process::id(),
            std::thread::current().id()
        ));
        let mut w = DatastoreWriter::create(&path, p, n, k, etas.len()).unwrap();
        for (ci, &eta) in etas.iter().enumerate() {
            let f = feats(n, k, ci as u64);
            w.begin_checkpoint(eta).unwrap();
            for i in 0..n {
                w.append_features(f.row(i)).unwrap();
            }
            w.end_checkpoint().unwrap();
        }
        w.finalize().unwrap();
        (Datastore::open(&path).unwrap(), path)
    }

    #[test]
    fn eta_weights_scale_scores() {
        let (n, k) = (8, 64);
        let (ds1, p1) = build_ds_keep(8, &[1.0], n, k);
        let (ds2, p2) = build_ds_keep(8, &[2.0], n, k);
        let val = vec![feats(4, k, 99)];
        let a = score_datastore(&ds1, &val, ScoreOpts::default(), None).unwrap();
        let b = score_datastore(&ds2, &val, ScoreOpts::default(), None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((2.0 * x - y).abs() < 1e-5, "{x} {y}");
        }
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn multi_checkpoint_sums() {
        let (n, k) = (6, 64);
        let (ds, p) = build_ds_keep(4, &[0.5, 0.25], n, k);
        let vals = vec![feats(3, k, 50), feats(3, k, 51)];
        let s = score_datastore(&ds, &vals, ScoreOpts::default(), None).unwrap();
        assert_eq!(s.len(), n);
        assert!(s.iter().all(|x| x.is_finite() && x.abs() <= 0.75 + 1e-5));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn checkpoint_count_mismatch_errors() {
        let (ds, p) = build_ds_keep(8, &[1.0, 1.0], 4, 64);
        let vals = vec![feats(2, 64, 1)];
        assert!(score_datastore(&ds, &vals, ScoreOpts::default(), None).is_err());
        std::fs::remove_file(p).ok();
    }
}
