//! Fixed-shape batching for the static-shape AOT graphs.
//!
//! Every HLO artifact has a compiled batch size `B`; the batcher flattens
//! encoded samples into `[B*S]` token / mask buffers and pads the final
//! partial batch with zero-mask rows (zero mask ⇒ zero loss ⇒ zero
//! gradient, so padded rows are inert in both training and extraction —
//! the extractor additionally drops their features by index).

use super::Dataset;
use crate::util::Rng;

/// One fixed-shape batch ready for upload.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Row-major `[b * seq]` token ids.
    pub tokens: Vec<i32>,
    /// Row-major `[b * seq]` loss weights.
    pub masks: Vec<f32>,
    /// Dataset indices of the real (non-padding) rows, in row order.
    pub indices: Vec<usize>,
    /// Compiled batch size (rows incl. padding).
    pub b: usize,
    pub seq: usize,
}

impl Batch {
    pub fn real_rows(&self) -> usize {
        self.indices.len()
    }
}

/// Iterator over fixed-shape batches of a dataset (optionally shuffled
/// per-epoch with a seeded RNG — the training loop's access pattern).
pub struct Batcher<'a> {
    data: &'a Dataset,
    order: Vec<usize>,
    pos: usize,
    b: usize,
}

impl<'a> Batcher<'a> {
    pub fn sequential(data: &'a Dataset, b: usize) -> Batcher<'a> {
        Batcher { data, order: (0..data.len()).collect(), pos: 0, b }
    }

    pub fn shuffled(data: &'a Dataset, b: usize, rng: &mut Rng) -> Batcher<'a> {
        let mut order: Vec<usize> = (0..data.len()).collect();
        rng.shuffle(&mut order);
        Batcher { data, order, pos: 0, b }
    }

    /// Restrict to a contiguous index range (worker shards).
    pub fn range(data: &'a Dataset, b: usize, range: std::ops::Range<usize>) -> Batcher<'a> {
        Batcher { data, order: range.collect(), pos: 0, b }
    }

    pub fn num_batches(&self) -> usize {
        self.order.len().div_ceil(self.b)
    }
}

impl<'a> Iterator for Batcher<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.pos >= self.order.len() {
            return None;
        }
        let seq = self.data.seq;
        let take = (self.order.len() - self.pos).min(self.b);
        let mut tokens = Vec::with_capacity(self.b * seq);
        let mut masks = Vec::with_capacity(self.b * seq);
        let mut indices = Vec::with_capacity(take);
        for k in 0..take {
            let idx = self.order[self.pos + k];
            let e = &self.data.encoded[idx];
            tokens.extend_from_slice(&e.tokens);
            masks.extend_from_slice(&e.loss_mask);
            indices.push(idx);
        }
        // pad remaining rows with inert zero-mask rows
        for _ in take..self.b {
            tokens.extend(std::iter::repeat_n(0i32, seq));
            masks.extend(std::iter::repeat_n(0f32, seq));
        }
        self.pos += take;
        Some(Batch { tokens, masks, indices, b: self.b, seq })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, Tokenizer};

    fn ds(n: usize) -> Dataset {
        let tok = Tokenizer::default();
        Dataset::encode(generate_corpus(n, 8, &tok, 96), &tok, 96)
    }

    #[test]
    fn batches_have_fixed_shape() {
        let d = ds(10);
        for batch in Batcher::sequential(&d, 4) {
            assert_eq!(batch.tokens.len(), 4 * 96);
            assert_eq!(batch.masks.len(), 4 * 96);
            assert_eq!(batch.b, 4);
        }
    }

    #[test]
    fn covers_all_rows_once() {
        let d = ds(10);
        let batcher = Batcher::sequential(&d, 4);
        assert_eq!(batcher.num_batches(), 3);
        let mut seen: Vec<usize> = batcher.flat_map(|b| b.indices).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn padding_rows_are_inert() {
        let d = ds(5);
        let last = Batcher::sequential(&d, 4).last().unwrap();
        assert_eq!(last.real_rows(), 1);
        // padded rows: all-zero masks
        let pad_masks = &last.masks[96..];
        assert!(pad_masks.iter().all(|&m| m == 0.0));
        let pad_tokens = &last.tokens[96..];
        assert!(pad_tokens.iter().all(|&t| t == 0));
    }

    #[test]
    fn shuffled_is_permutation_and_seed_stable() {
        let d = ds(20);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let a: Vec<usize> = Batcher::shuffled(&d, 6, &mut r1).flat_map(|b| b.indices).collect();
        let b: Vec<usize> = Batcher::shuffled(&d, 6, &mut r2).flat_map(|b| b.indices).collect();
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn range_batcher_stays_in_shard() {
        let d = ds(20);
        let idx: Vec<usize> = Batcher::range(&d, 4, 5..12).flat_map(|b| b.indices).collect();
        assert_eq!(idx, (5..12).collect::<Vec<_>>());
    }

    #[test]
    fn batch_content_matches_dataset() {
        let d = ds(4);
        let b = Batcher::sequential(&d, 4).next().unwrap();
        for (row, &idx) in b.indices.iter().enumerate() {
            assert_eq!(&b.tokens[row * 96..(row + 1) * 96], &d.encoded[idx].tokens[..]);
        }
    }

    #[test]
    fn empty_dataset_yields_nothing() {
        let tok = Tokenizer::default();
        let d = Dataset::encode(vec![], &tok, 96);
        assert_eq!(Batcher::sequential(&d, 4).count(), 0);
    }
}
