//! Dataset handling: encoded stores, fixed-shape batching, sharding, and a
//! bounded-channel streaming pipeline with backpressure — the L3 orchestration
//! substrate the gradient-extraction and training stages run on.

pub mod batcher;
pub mod stream;

pub use batcher::{Batch, Batcher};

use crate::corpus::{EncodedSample, Sample, Tokenizer};

/// A set of samples pre-encoded to the model's static `[seq]` shape.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub samples: Vec<Sample>,
    pub encoded: Vec<EncodedSample>,
    pub seq: usize,
}

impl Dataset {
    pub fn encode(samples: Vec<Sample>, tok: &Tokenizer, seq: usize) -> Dataset {
        let encoded = samples.iter().map(|s| s.encode(tok, seq)).collect();
        Dataset { samples, encoded, seq }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// View over a subset of indices (clones the rows — subsets are small).
    pub fn subset(&self, indices: &[usize]) -> Dataset {
        Dataset {
            samples: indices.iter().map(|&i| self.samples[i].clone()).collect(),
            encoded: indices.iter().map(|&i| self.encoded[i].clone()).collect(),
            seq: self.seq,
        }
    }

    /// Split `0..len` into `n` contiguous shards whose sizes differ by ≤ 1
    /// (extraction workers each own one shard).
    pub fn shard_ranges(len: usize, n: usize) -> Vec<std::ops::Range<usize>> {
        assert!(n > 0);
        let base = len / n;
        let extra = len % n;
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        for i in 0..n {
            let size = base + usize::from(i < extra);
            out.push(start..start + size);
            start += size;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, Source};

    fn ds(n: usize) -> Dataset {
        let tok = Tokenizer::default();
        Dataset::encode(generate_corpus(n, 5, &tok, 96), &tok, 96)
    }

    #[test]
    fn encode_keeps_order_and_len() {
        let d = ds(50);
        assert_eq!(d.len(), 50);
        assert_eq!(d.encoded.len(), 50);
        for e in &d.encoded {
            assert_eq!(e.tokens.len(), 96);
            assert_eq!(e.loss_mask.len(), 96);
        }
    }

    #[test]
    fn subset_selects_rows() {
        let d = ds(20);
        let s = d.subset(&[3, 7, 7]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.samples[0].prompt, d.samples[3].prompt);
        assert_eq!(s.samples[1].prompt, d.samples[7].prompt);
        assert_eq!(s.samples[2].prompt, d.samples[7].prompt);
    }

    #[test]
    fn shards_cover_and_balance() {
        for (len, n) in [(10, 3), (7, 7), (5, 8), (100, 4), (0, 2)] {
            let shards = Dataset::shard_ranges(len, n);
            assert_eq!(shards.len(), n);
            let total: usize = shards.iter().map(|r| r.len()).sum();
            assert_eq!(total, len);
            let sizes: Vec<usize> = shards.iter().map(|r| r.len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "{len} {n} {sizes:?}");
            // contiguous
            let mut pos = 0;
            for r in &shards {
                assert_eq!(r.start, pos);
                pos = r.end;
            }
        }
    }

    #[test]
    fn subset_source_composition_preserved() {
        let d = ds(100);
        let idx: Vec<usize> = (0..d.len())
            .filter(|&i| d.samples[i].source == Source::SynCot)
            .collect();
        let s = d.subset(&idx);
        assert!(s.samples.iter().all(|x| x.source == Source::SynCot));
    }
}
