//! Bounded-channel streaming pipeline with backpressure.
//!
//! The extraction stage is a classic producer → N workers → consumer
//! topology: batches are encoded on one thread, fanned out to PJRT workers,
//! and their features funneled to the datastore writer. `sync_channel`
//! bounds give backpressure so encoding can never run unboundedly ahead of
//! compute, and compute never runs ahead of the writer (the paper's A100
//! pipeline has the same property via GPU queue depth).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread;

/// Run a `producer → n_workers × work → consumer` pipeline over items of
/// type `T` producing `U`s. Returns the consumer's accumulated result.
///
/// Ordering: the consumer receives results in completion order, each tagged
/// with its item sequence number, so order-sensitive consumers can reorder.
pub fn pipeline<T, U, P, W, C, R>(
    n_workers: usize,
    queue_depth: usize,
    producer: P,
    work: W,
    consumer: C,
) -> R
where
    T: Send,
    U: Send,
    P: FnOnce(&SyncSender<(usize, T)>) + Send,
    W: Fn(usize, T) -> U + Sync,
    C: FnOnce(Receiver<(usize, U)>) -> R + Send,
    R: Send,
{
    assert!(n_workers > 0);
    let (in_tx, in_rx) = sync_channel::<(usize, T)>(queue_depth);
    let (out_tx, out_rx) = sync_channel::<(usize, U)>(queue_depth);
    // mpsc Receiver is !Sync; share it behind a mutex for the worker pool.
    let in_rx = std::sync::Mutex::new(in_rx);

    thread::scope(|s| {
        let work = &work;
        let in_rx = &in_rx;
        for _ in 0..n_workers {
            let out_tx = out_tx.clone();
            s.spawn(move || loop {
                let msg = { in_rx.lock().unwrap().recv() };
                match msg {
                    Ok((seq, item)) => {
                        if out_tx.send((seq, work(seq, item))).is_err() {
                            return; // consumer gone
                        }
                    }
                    Err(_) => return, // producer done
                }
            });
        }
        drop(out_tx); // workers hold the remaining clones

        let consumer_handle = s.spawn(move || consumer(out_rx));
        producer(&in_tx);
        drop(in_tx);
        consumer_handle.join().expect("pipeline consumer panicked")
    })
}

/// Reorder helper for consumers that need results in sequence order:
/// buffers out-of-order arrivals and invokes `f` strictly in order 0,1,2…
pub struct Reorderer<U> {
    next: usize,
    pending: std::collections::BTreeMap<usize, U>,
}

impl<U> Reorderer<U> {
    pub fn new() -> Self {
        Reorderer { next: 0, pending: std::collections::BTreeMap::new() }
    }

    pub fn push<F: FnMut(usize, U)>(&mut self, seq: usize, item: U, mut f: F) {
        self.pending.insert(seq, item);
        while let Some(item) = self.pending.remove(&self.next) {
            f(self.next, item);
            self.next += 1;
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

impl<U> Default for Reorderer<U> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn computes_all_items() {
        let sum = pipeline(
            4,
            2,
            |tx| {
                for i in 0..100usize {
                    tx.send((i, i)).unwrap();
                }
            },
            |_, x| x * 2,
            |rx| rx.into_iter().map(|(_, v)| v).sum::<usize>(),
        );
        assert_eq!(sum, (0..100).map(|x| x * 2).sum());
    }

    #[test]
    fn single_worker_preserves_order() {
        let got = pipeline(
            1,
            1,
            |tx| {
                for i in 0..20usize {
                    tx.send((i, i)).unwrap();
                }
            },
            |_, x| x,
            |rx| rx.into_iter().map(|(s, _)| s).collect::<Vec<_>>(),
        );
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn backpressure_bounds_inflight() {
        // With queue depth 1 and a slow consumer, the producer cannot run
        // far ahead: track max (produced - consumed).
        let produced = AtomicUsize::new(0);
        let consumed = AtomicUsize::new(0);
        let max_gap = AtomicUsize::new(0);
        pipeline(
            1,
            1,
            |tx| {
                for i in 0..30usize {
                    tx.send((i, i)).unwrap();
                    let gap = produced.fetch_add(1, Ordering::SeqCst) + 1
                        - consumed.load(Ordering::SeqCst);
                    max_gap.fetch_max(gap, Ordering::SeqCst);
                }
            },
            |_, x| x,
            |rx| {
                for _ in rx {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                    consumed.fetch_add(1, Ordering::SeqCst);
                }
            },
        );
        // depth 1 in + depth 1 out + 1 in-flight per worker + 1 in hand
        assert!(max_gap.load(Ordering::SeqCst) <= 5, "{max_gap:?}");
    }

    #[test]
    fn reorderer_emits_in_sequence() {
        let mut r = Reorderer::new();
        let mut out = Vec::new();
        for (seq, v) in [(2, 'c'), (0, 'a'), (1, 'b'), (3, 'd')] {
            r.push(seq, v, |s, v| out.push((s, v)));
        }
        assert_eq!(out, vec![(0, 'a'), (1, 'b'), (2, 'c'), (3, 'd')]);
        assert_eq!(r.pending_len(), 0);
    }

    #[test]
    fn parallel_workers_speed_up_latency_bound_work() {
        // Smoke check that independent workers overlap sleeps.
        let t = std::time::Instant::now();
        pipeline(
            8,
            8,
            |tx| {
                for i in 0..16usize {
                    tx.send((i, ())).unwrap();
                }
            },
            |_, ()| std::thread::sleep(std::time::Duration::from_millis(10)),
            |rx| rx.into_iter().count(),
        );
        assert!(t.elapsed().as_millis() < 120, "{:?}", t.elapsed());
    }
}
