//! Hand-rolled CLI argument parser: `qless <subcommand> [--key value]...`
//!
//! Flags map 1:1 onto [`Config`] keys plus a few parser-level options
//! (`--config <file>` loads before overrides; `-v`/`-q` set verbosity;
//! `--fast` shrinks workloads for smoke runs). Unknown flags error with the
//! list of valid keys rather than being silently ignored.

use anyhow::{bail, Result};

use super::Config;
use crate::util::{set_verbosity, Level};

#[derive(Debug, Clone)]
pub struct Cli {
    pub command: String,
    /// Positional args after the subcommand (e.g. `xp table1`).
    pub positional: Vec<String>,
    pub config: Config,
    /// `--fast`: shrink workloads (used by `make tables` smoke runs).
    pub fast: bool,
}

pub const USAGE: &str = "\
qless — Quantized Low-rank Gradient Similarity Search (paper reproduction)

USAGE: qless <command> [args] [--key value ...]

COMMANDS
  pipeline            end-to-end: warmup → extract → score → select → finetune → eval
  gen-corpus          generate + print corpus statistics
  warmup              warmup-train and write checkpoints
  extract             build the (quantized) gradient datastore from checkpoints
  score               compute influence scores against validation gradients
  select              pick top select_frac and report composition
  eval                evaluate a checkpoint on the three benchmarks
  xp <id>             reproduce a paper table/figure:
                      table1 table2 table3 fig1 fig3 fig4 fig5
  list-artifacts      show what the manifest provides

OPTIONS (all Config keys work as --key value):
  --config FILE       load key=value file first
  --model NAME        tiny | small | base
  --bits N            16 | 8 | 4 | 2 | 1      --scheme S   absmax | absmean
  --model-bits N      16 | 8 | 4 (QLoRA ablation)
  --corpus-size N     --seed N   --select-frac F   --workers N
  --shard-rows N      rows per influence-scan shard (0 = from budget)
  --mem-budget-mb N   influence-scan memory budget (default 64 MiB)
  --multi-scan B      score all benchmarks in one datastore pass (default true)
  --run-dir DIR       --artifacts DIR
  --fast              shrink workloads        -v / -q      verbosity
";

pub fn parse_args<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
    let mut it = args.into_iter().peekable();
    let command = match it.next() {
        Some(c) if !c.starts_with('-') => c,
        Some(c) if c == "--help" || c == "-h" => {
            return Ok(Cli { command: "help".into(), positional: vec![], config: Config::default(), fast: false })
        }
        _ => bail!("missing subcommand\n\n{USAGE}"),
    };
    let mut cli = Cli { command, positional: Vec::new(), config: Config::default(), fast: false };

    // two passes: collect (key, value) pairs, apply --config first
    let mut pairs: Vec<(String, String)> = Vec::new();
    while let Some(arg) = it.next() {
        if let Some(key) = arg.strip_prefix("--") {
            match key {
                "fast" => cli.fast = true,
                "help" => {
                    cli.command = "help".into();
                }
                _ => {
                    let val = match it.next() {
                        Some(v) => v,
                        None => bail!("flag --{key} needs a value\n\n{USAGE}"),
                    };
                    pairs.push((key.to_string(), val));
                }
            }
        } else if arg == "-v" {
            set_verbosity(Level::Debug);
        } else if arg == "-q" {
            set_verbosity(Level::Warn);
        } else if arg.starts_with('-') {
            bail!("unknown flag '{arg}'\n\n{USAGE}");
        } else {
            cli.positional.push(arg);
        }
    }

    for (k, v) in pairs.iter().filter(|(k, _)| k == "config") {
        let _ = k;
        cli.config.load_file(std::path::Path::new(v))?;
    }
    for (k, v) in pairs.iter().filter(|(k, _)| k != "config") {
        cli.config.set(k, v)?;
    }
    cli.config.validate()?;
    Ok(cli)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Result<Cli> {
        parse_args(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let c = p(&["pipeline", "--bits", "4", "--scheme", "absmean", "--fast"]).unwrap();
        assert_eq!(c.command, "pipeline");
        assert_eq!(c.config.bits, 4);
        assert!(c.fast);
    }

    #[test]
    fn positional_after_command() {
        let c = p(&["xp", "table1", "--seed", "3"]).unwrap();
        assert_eq!(c.positional, vec!["table1"]);
        assert_eq!(c.config.seed, 3);
    }

    #[test]
    fn scan_flags_parse() {
        let c = p(&["score", "--shard-rows", "2048", "--mem-budget-mb", "32"]).unwrap();
        assert_eq!(c.config.shard_rows, 2048);
        assert_eq!(c.config.mem_budget_mb, 32);
        assert!(p(&["score", "--mem-budget-mb", "0"]).is_err()); // validate()
    }

    #[test]
    fn multi_scan_flag_parses() {
        assert!(p(&["score"]).unwrap().config.multi_scan); // default on
        let c = p(&["score", "--multi-scan", "false"]).unwrap();
        assert!(!c.config.multi_scan);
        assert!(p(&["score", "--multi-scan", "maybe"]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(p(&["pipeline", "--bits"]).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(p(&["pipeline", "--bogus", "1"]).is_err());
        assert!(p(&["pipeline", "-x"]).is_err());
    }

    #[test]
    fn validation_applied() {
        assert!(p(&["pipeline", "--bits", "5"]).is_err());
    }

    #[test]
    fn config_file_then_overrides() {
        let dir = std::env::temp_dir().join(format!("qless_cli_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("c.cfg");
        std::fs::write(&f, "bits = 8\ncorpus_size = 500\n").unwrap();
        let c = p(&["pipeline", "--config", f.to_str().unwrap(), "--bits", "2"]).unwrap();
        assert_eq!(c.config.bits, 2); // CLI wins
        assert_eq!(c.config.corpus_size, 500); // file applies
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn help() {
        assert_eq!(p(&["--help"]).unwrap().command, "help");
    }
}
