//! Hand-rolled CLI argument parser: `qless <subcommand> [--key value]...`
//!
//! Flags map 1:1 onto [`Config`] keys plus a few parser-level options
//! (`--config <file>` loads before overrides; `-v`/`-q` set verbosity;
//! `--fast` shrinks workloads for smoke runs). Unknown flags error with the
//! list of valid keys rather than being silently ignored, a repeated flag
//! is an error rather than silently last-wins (`--config` excepted — files
//! layer in order), and parse errors append the *subcommand's* usage via
//! [`usage_for`] (`qless serve --help` prints the serve flags).

use anyhow::{anyhow, bail, Result};

use super::Config;
use crate::util::{set_verbosity, Level};

#[derive(Debug, Clone)]
pub struct Cli {
    pub command: String,
    /// Positional args after the subcommand (e.g. `xp table1`).
    pub positional: Vec<String>,
    pub config: Config,
    /// `--fast`: shrink workloads (used by `make tables` smoke runs).
    pub fast: bool,
    /// `--traces`: under `serve`, collect spans into the in-process ring
    /// (scraped via the `metrics` verb); under `stats`, also fetch and
    /// print the server's recent spans.
    pub traces: bool,
}

pub const USAGE: &str = "\
qless — Quantized Low-rank Gradient Similarity Search (paper reproduction)

USAGE: qless <command> [args] [--key value ...]

COMMANDS
  pipeline            end-to-end: warmup → extract → score → select → finetune → eval
  gen-corpus          generate + print corpus statistics
  warmup              warmup-train and write checkpoints
  extract             build the (quantized) gradient datastore from checkpoints
  ingest              append new corpus rows to the existing datastores as a
                      new generation (--ingest-rows N; existing bytes untouched,
                      a running `qless serve` picks it up without restart)
  score               compute influence scores against validation gradients
  select              pick top select_frac and report composition
  reindex             (re)build the Hamming-clustered IVF sidecar (.qidx)
                      next to each precision store in the run dir
                      (--nclusters C; a running `qless serve` picks the
                      fresh sidecar up on its next indexed query)
  serve               resident influence query service over TCP
                      (`qless serve --help` for the serve flags;
                      --traces records per-query spans for `stats`)
  stats               scrape a running server's metrics (counters, gauges,
                      latency histograms) and render them as tables
                      (--serve-addr H:P picks the server; --watch N
                      refreshes every N s; --traces dumps recent spans)
  eval                evaluate a checkpoint on the three benchmarks
  xp <id>             reproduce a paper table/figure or analysis:
                      table1 table2 table3 fig1 fig3 fig4 fig5 cascade
  list-artifacts      show what the manifest provides

OPTIONS (all Config keys work as --key value):
  --config FILE       load key=value file first (may repeat; files layer)
  --model NAME        tiny | small | base
  --bits N[,N...]     16 | 8 | 4 | 2 | 1; a comma list (e.g. 1,2,4,8,16)
                      builds every precision in ONE extraction pass
  --scheme S          absmax | absmean
  --model-bits N      16 | 8 | 4 (QLoRA ablation)
  --corpus-size N     --seed N   --select-frac F   --workers N
  --warmup-frac F     --warmup-epochs N   (checkpoints = warmup epochs)
  --finetune-epochs N --lr F     --lr-warmup-frac F
  --val-per-task N    --eval-per-task N   --xla-score B
  --shard-rows N      rows per influence-scan shard (0 = from budget)
  --mem-budget-mb N   influence-scan memory budget (default 64 MiB)
  --build-mem-budget-mb N  streaming-builder window budget (default 64 MiB;
                      bounds peak build memory independent of corpus size)
  --build-workers N   quantize-stage worker cap for builds (0 = all cores)
  --ingest-rows N     rows `qless ingest` appends as one new generation
  --multi-scan B      score all benchmarks in one datastore pass (default true)
  --cascade P,R       two-stage precision cascade for score/select: probe
                      EVERY row at P bits, re-score only the top candidates
                      at R bits (e.g. 1,8; both must be in the run's --bits
                      build list; empty = exhaustive scan at --bits)
  --cascade-mult C    cascade candidate multiplier: the probe keeps C·k
                      candidates per task for the rerank (default 8;
                      C·k >= n rows makes the cascade exact)
  --nclusters C       `qless reindex` cluster count (0 = auto ceil(sqrt(n)))
  --nprobe P          score via the .qidx sidecar, scanning only the P
                      clusters nearest each task (0 = exhaustive scan;
                      P >= nclusters is byte-identical to exhaustive)
  --run-dir DIR       --artifacts DIR
  --watch N           `qless stats` refresh interval in seconds (0 = once)
  --traces            serve: record spans / stats: fetch the span ring
  --fast              shrink workloads        -v / -q      verbosity
";

/// `qless serve` usage — printed by `qless serve --help` and appended to
/// serve-related parse errors.
pub const SERVE_USAGE: &str = "\
qless serve — resident influence query service (JSON-lines over TCP)

USAGE: qless serve [--key value ...]

  --datastore FILE        datastore file to serve (default: the pipeline's
                          <run-dir>/datastore_<bits>b_<scheme>.qlds)
  --serve-addr H:P        bind address (default 127.0.0.1:7411; port 0 = ephemeral)
  --batch-window-ms N     admission window: concurrent queries arriving
                          within N ms coalesce into ONE fused datastore
                          pass (default 2)
  --max-batch-tasks N     cap on tasks fused per pass (default 16)
  --score-cache-entries N score-cache slots — identical queries answer from
                          cache without a scan (default 64; 0 disables)
  --mem-budget-mb N       shard-cache byte budget in MiB; warm shards are
                          served from RAM, not disk (default 64)
  --shard-rows N          rows per scan/cache shard (0 = derive from budget)
  --workers N             connection-handler threads (default: cores ≤ 8)
  --traces                record per-query spans into the in-process ring
                          (scrape with `qless stats --traces`)
  --bits N / --scheme S / --run-dir DIR    select the default datastore path

SCATTER-GATHER (distributed serving; same protocol, same answers)
  --local-workers N       spawn N in-process scan workers on ephemeral
                          loopback ports and serve through a coordinator
                          that splits every scan across them (0 = off)
  --worker-addrs LIST     comma-separated host:port of already-running
                          remote workers to coordinate instead (mutually
                          exclusive with --local-workers)
  --worker-deadline-ms N  per-worker round-trip deadline; a worker that
                          misses it is failed and its row range re-issued
                          (default 2000)
  --worker-retries N      re-issue rounds for a failed row range before
                          the query degrades to an error (default 2)

Wire protocol: one JSON object per line (spec:
rust/crates/qless-service/PROTOCOL.md; example exchange: README.md
§serve). Served datastores are live: a `qless ingest` into the same
run-dir is picked up without restart (responses carry the generation;
`since_gen` ranks only newer rows). Score requests may carry a
`cascade` object (PROTOCOL.md §Cascade) to probe at a cheap precision
and rerank candidates at a higher one — the run-dir's sibling
precision stores are opened on demand.
";

/// The usage text for a subcommand: serve has its own flag set; everything
/// else shares the global [`USAGE`].
pub fn usage_for(command: &str) -> &'static str {
    match command {
        "serve" => SERVE_USAGE,
        _ => USAGE,
    }
}

pub fn parse_args<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
    let mut it = args.into_iter().peekable();
    let command = match it.next() {
        Some(c) if !c.starts_with('-') => c,
        Some(c) if c == "--help" || c == "-h" => {
            return Ok(Cli {
                command: "help".into(),
                positional: vec![],
                config: Config::default(),
                fast: false,
                traces: false,
            })
        }
        _ => bail!("missing subcommand\n\n{USAGE}"),
    };
    let mut cli = Cli {
        command,
        positional: Vec::new(),
        config: Config::default(),
        fast: false,
        traces: false,
    };

    // two passes: collect (key, value) pairs, apply --config first
    let mut pairs: Vec<(String, String)> = Vec::new();
    let mut seen: Vec<String> = Vec::new();
    while let Some(arg) = it.next() {
        if let Some(key) = arg.strip_prefix("--") {
            match key {
                "fast" => cli.fast = true,
                "traces" => cli.traces = true,
                "help" => {
                    // per-subcommand help: short-circuit so `qless serve
                    // --help` prints the serve flags, never a parse error
                    return Ok(Cli {
                        positional: vec![cli.command],
                        command: "help".into(),
                        config: Config::default(),
                        fast: false,
                        traces: false,
                    });
                }
                _ => {
                    // dashes and underscores name the same flag; repeats
                    // are an error, not a silent last-wins (--config is
                    // exempt: files layer in order)
                    let norm = key.replace('-', "_");
                    if norm != "config" && seen.contains(&norm) {
                        bail!(
                            "duplicate flag --{key}\n\n{}",
                            usage_for(&cli.command)
                        );
                    }
                    seen.push(norm);
                    let val = match it.next() {
                        Some(v) => v,
                        None => bail!(
                            "flag --{key} needs a value\n\n{}",
                            usage_for(&cli.command)
                        ),
                    };
                    pairs.push((key.to_string(), val));
                }
            }
        } else if arg == "-v" {
            set_verbosity(Level::Debug);
        } else if arg == "-q" {
            set_verbosity(Level::Warn);
        } else if arg.starts_with('-') {
            bail!("unknown flag '{arg}'\n\n{}", usage_for(&cli.command));
        } else {
            cli.positional.push(arg);
        }
    }

    for (k, v) in pairs.iter().filter(|(k, _)| k == "config") {
        let _ = k;
        cli.config.load_file(std::path::Path::new(v))?;
    }
    for (k, v) in pairs.iter().filter(|(k, _)| k != "config") {
        cli.config
            .set(k, v)
            .map_err(|e| anyhow!("{e:#}\n\n{}", usage_for(&cli.command)))?;
    }
    cli.config.validate()?;
    Ok(cli)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Result<Cli> {
        parse_args(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let c = p(&["pipeline", "--bits", "4", "--scheme", "absmean", "--fast"]).unwrap();
        assert_eq!(c.command, "pipeline");
        assert_eq!(c.config.bits, 4);
        assert!(c.fast);
    }

    #[test]
    fn positional_after_command() {
        let c = p(&["xp", "table1", "--seed", "3"]).unwrap();
        assert_eq!(c.positional, vec!["table1"]);
        assert_eq!(c.config.seed, 3);
    }

    #[test]
    fn bits_list_and_build_flags_parse() {
        let c = p(&[
            "extract",
            "--bits",
            "1,2,4,8,16",
            "--build-mem-budget-mb",
            "32",
            "--build-workers",
            "4",
        ])
        .unwrap();
        assert_eq!(c.config.build_bits, vec![1, 2, 4, 8, 16]);
        assert_eq!(c.config.bits, 1);
        assert_eq!(c.config.build_mem_budget_mb, 32);
        assert_eq!(c.config.build_workers, 4);
        assert!(p(&["extract", "--bits", "1,3"]).is_err());
        assert!(p(&["extract", "--build-mem-budget-mb", "0"]).is_err()); // validate()
    }

    #[test]
    fn scan_flags_parse() {
        let c = p(&["score", "--shard-rows", "2048", "--mem-budget-mb", "32"]).unwrap();
        assert_eq!(c.config.shard_rows, 2048);
        assert_eq!(c.config.mem_budget_mb, 32);
        assert!(p(&["score", "--mem-budget-mb", "0"]).is_err()); // validate()
    }

    #[test]
    fn multi_scan_flag_parses() {
        assert!(p(&["score"]).unwrap().config.multi_scan); // default on
        let c = p(&["score", "--multi-scan", "false"]).unwrap();
        assert!(!c.config.multi_scan);
        assert!(p(&["score", "--multi-scan", "maybe"]).is_err());
    }

    #[test]
    fn cascade_flags_parse() {
        let c = p(&["score", "--cascade", "1,8", "--cascade-mult", "4"]).unwrap();
        assert_eq!(c.config.cascade, "1,8");
        assert_eq!(c.config.cascade_mult, 4);
        let (probe, rerank) = c.config.cascade_precisions().unwrap().unwrap();
        assert_eq!((probe.bits, rerank.bits), (1, 8));
        assert!(p(&["score"]).unwrap().config.cascade.is_empty()); // default off
        assert!(p(&["score", "--cascade", "8"]).is_err()); // validate()
        assert!(p(&["score", "--cascade", "8,1"]).is_err()); // probe > rerank
        assert!(p(&["score", "--cascade", "1,8", "--cascade-mult", "0"]).is_err());
    }

    #[test]
    fn index_flags_parse() {
        let c = p(&["reindex", "--nclusters", "64"]).unwrap();
        assert_eq!(c.command, "reindex");
        assert_eq!(c.config.nclusters, 64);
        let c2 = p(&["score", "--nprobe", "6"]).unwrap();
        assert_eq!(c2.config.nprobe, 6);
        assert_eq!(p(&["score"]).unwrap().config.nprobe, 0); // default: exhaustive
        assert!(p(&["score", "--nprobe", "many"]).is_err());
        assert!(usage_for("reindex").contains("--nclusters"));
        assert!(usage_for("score").contains("--nprobe"));
    }

    #[test]
    fn stats_flags_parse() {
        let c = p(&["stats", "--serve-addr", "127.0.0.1:7411", "--watch", "2", "--traces"]).unwrap();
        assert_eq!(c.command, "stats");
        assert_eq!(c.config.watch, 2);
        assert!(c.traces);
        assert!(!p(&["stats"]).unwrap().traces); // valueless flag, default off
        assert!(p(&["stats", "--watch"]).is_err()); // --watch needs a value
    }

    #[test]
    fn missing_value_errors() {
        assert!(p(&["pipeline", "--bits"]).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(p(&["pipeline", "--bogus", "1"]).is_err());
        assert!(p(&["pipeline", "-x"]).is_err());
    }

    #[test]
    fn validation_applied() {
        assert!(p(&["pipeline", "--bits", "5"]).is_err());
    }

    #[test]
    fn config_file_then_overrides() {
        let dir = std::env::temp_dir().join(format!("qless_cli_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("c.cfg");
        std::fs::write(&f, "bits = 8\ncorpus_size = 500\n").unwrap();
        let c = p(&["pipeline", "--config", f.to_str().unwrap(), "--bits", "2"]).unwrap();
        assert_eq!(c.config.bits, 2); // CLI wins
        assert_eq!(c.config.corpus_size, 500); // file applies
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn help() {
        assert_eq!(p(&["--help"]).unwrap().command, "help");
    }

    #[test]
    fn duplicate_flags_rejected() {
        let err = p(&["score", "--bits", "4", "--bits", "8"]).unwrap_err().to_string();
        assert!(err.contains("duplicate flag --bits"), "{err}");
        // dash and underscore spellings are the same flag
        assert!(p(&["score", "--mem-budget-mb", "4", "--mem_budget_mb", "8"]).is_err());
        // distinct flags still fine
        assert!(p(&["score", "--bits", "4", "--seed", "8"]).is_ok());
    }

    #[test]
    fn repeated_config_files_layer_in_order() {
        let dir = std::env::temp_dir().join(format!("qless_cli_dup_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let a = dir.join("a.cfg");
        let b = dir.join("b.cfg");
        std::fs::write(&a, "bits = 8\ncorpus_size = 500\n").unwrap();
        std::fs::write(&b, "bits = 2\n").unwrap();
        let c = p(&[
            "pipeline",
            "--config",
            a.to_str().unwrap(),
            "--config",
            b.to_str().unwrap(),
        ])
        .unwrap();
        assert_eq!(c.config.bits, 2, "later file wins");
        assert_eq!(c.config.corpus_size, 500, "earlier file still applies");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_flags_parse() {
        let c = p(&[
            "serve",
            "--serve-addr",
            "127.0.0.1:0",
            "--batch-window-ms",
            "5",
            "--max-batch-tasks",
            "8",
            "--score-cache-entries",
            "16",
            "--datastore",
            "runs/x/ds.qlds",
        ])
        .unwrap();
        assert_eq!(c.command, "serve");
        assert_eq!(c.config.serve_addr, "127.0.0.1:0");
        assert_eq!(c.config.batch_window_ms, 5);
        assert_eq!(c.config.max_batch_tasks, 8);
        assert_eq!(c.config.score_cache_entries, 16);
        assert_eq!(c.config.datastore, "runs/x/ds.qlds");
        assert!(p(&["serve", "--max-batch-tasks", "0"]).is_err()); // validate()
    }

    #[test]
    fn scatter_gather_flags_parse() {
        let c = p(&[
            "serve",
            "--local-workers",
            "3",
            "--worker-deadline-ms",
            "500",
            "--worker-retries",
            "1",
        ])
        .unwrap();
        assert_eq!(c.config.local_workers, 3);
        assert_eq!(c.config.worker_deadline_ms, 500);
        assert_eq!(c.config.worker_retries, 1);
        let c2 = p(&["serve", "--worker-addrs", "10.0.0.1:7411,10.0.0.2:7411"]).unwrap();
        assert_eq!(c2.config.worker_addr_list().len(), 2);
        // mutually exclusive (validate())
        assert!(p(&["serve", "--local-workers", "2", "--worker-addrs", "h:1"]).is_err());
        assert!(usage_for("serve").contains("--local-workers"));
    }

    #[test]
    fn subcommand_help_routes_to_its_usage() {
        let c = p(&["serve", "--help"]).unwrap();
        assert_eq!(c.command, "help");
        assert_eq!(c.positional, vec!["serve"]);
        // --help short-circuits: later junk flags must not error
        let c2 = p(&["serve", "--help", "--bogus"]).unwrap();
        assert_eq!(c2.command, "help");
        assert!(usage_for("serve").contains("--batch-window-ms"));
        assert!(usage_for("pipeline").contains("COMMANDS"));
        assert!(usage_for("").contains("COMMANDS"));
    }

    #[test]
    fn serve_errors_print_serve_flags() {
        let err = p(&["serve", "--batch-window-ms"]).unwrap_err().to_string();
        assert!(err.contains("needs a value"), "{err}");
        assert!(err.contains("--max-batch-tasks"), "serve usage attached: {err}");
        // unknown config keys under serve also point at the serve flags
        let err2 = p(&["serve", "--bogus-key", "1"]).unwrap_err().to_string();
        assert!(err2.contains("qless serve"), "{err2}");
        // other subcommands keep the global usage
        let err3 = p(&["score", "--bogus-key", "1"]).unwrap_err().to_string();
        assert!(err3.contains("COMMANDS"), "{err3}");
    }
}
